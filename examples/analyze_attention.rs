//! Attention-pattern analysis walk-through: trains the `analysis` variant
//! briefly, then (a) reproduces the Table-6 JSD measurement over the
//! trained model and (b) renders a Figure-1 style routing pattern from
//! content-dependent vectors, next to local/strided patterns.
//!
//! Run: `cargo run --release --example analyze_attention -- [steps]`

use std::sync::Arc;

use anyhow::Result;
use routing_transformer::analysis;
use routing_transformer::attention::{
    dense_masked_attention, sparse_attention, AttentionSpec, BatchedAttention, EpochCache,
    Execution, PatternCache, RouteSlot, RoutingSession, ShardedPattern, WorkerPool,
};
use routing_transformer::coordinator::{train_batcher, LrSchedule, TrainOptions, Trainer};
use routing_transformer::data;
use routing_transformer::kmeans::{layernorm_nsb, SphericalKMeans};
use routing_transformer::runtime::{execute_tuple, i32_literal, to_f32_vec, Artifacts, Runtime};
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::Table;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let root = routing_transformer::bench::artifacts_root();
    let rt = Runtime::cpu()?;
    let art = Artifacts::load(&root, "analysis")?;
    let manifest = art.manifest.clone();
    let cfg = &manifest.config;

    println!("training analysis model for {steps} steps on the needle corpus...");
    let mut trainer = Trainer::new(&rt, &art)?;
    let mut batcher = train_batcher(&manifest, "needle", 0)?;
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: steps.max(8) as u32 / 8 },
        log_every: (steps / 4).max(1),
        ..Default::default()
    };
    trainer.train(&mut batcher, &manifest, &opts)?;
    let state = trainer.state;

    // -------------------------------------------------- Table 6 JSD study
    let exe = art.executable(&rt, "attn_probs")?;
    let runs = 10;
    let t = cfg.seq_len;
    let mut rng = Rng::new(0);
    let mut ll = vec![Vec::new(); cfg.n_layers];
    let mut lr_ = vec![Vec::new(); cfg.n_layers];
    let mut rr = vec![Vec::new(); cfg.n_layers];
    for run in 0..runs {
        let mut src =
            data::source_by_name("needle", cfg.vocab_size, t, cfg.window, 900 + run as u64)?;
        let tokens = data::take(src.as_mut(), t);
        let lit = i32_literal(&tokens, &[1, t])?;
        let mut inputs: Vec<&xla::Literal> = state.params.iter().collect();
        inputs.push(&lit);
        let probs = to_f32_vec(&execute_tuple(&exe, &inputs)?[0])?;
        for layer in 0..cfg.n_layers {
            let plan = &cfg.plan[layer];
            let local = plan.heads_of("local");
            let routing = plan.heads_of("routing");
            if let Some(d) =
                analysis::sample_pair_jsd(&probs, cfg.n_heads, t, layer, &local, &local, &mut rng)
            {
                ll[layer].push(d);
            }
            if let Some(d) = analysis::sample_pair_jsd(
                &probs, cfg.n_heads, t, layer, &local, &routing, &mut rng,
            ) {
                lr_[layer].push(d);
            }
            if let Some(d) = analysis::sample_pair_jsd(
                &probs, cfg.n_heads, t, layer, &routing, &routing, &mut rng,
            ) {
                rr[layer].push(d);
            }
        }
    }
    println!("\nTable 6 (trained model) — JSD, upper bound {:.4}:", analysis::JSD_MAX);
    let mut table = Table::new(&["layer", "local‖local", "local‖routing", "routing‖routing"]);
    let cell = |xs: &[f64]| {
        let (m, s) = analysis::mean_std(xs);
        format!("{m:.4} ± {s:.4}")
    };
    for layer in 0..cfg.n_layers {
        table.row(&[format!("{layer}"), cell(&ll[layer]), cell(&lr_[layer]), cell(&rr[layer])]);
    }
    table.print();
    let (m_ll, _) = analysis::mean_std(&ll.concat());
    let (m_lr, _) = analysis::mean_std(&lr_.concat());
    let (m_rr, _) = analysis::mean_std(&rr.concat());
    println!(
        "\nordering: local‖local ({m_ll:.3}) < routing‖routing ({m_rr:.3}) < local‖routing ({m_lr:.3})"
    );
    assert!(m_ll < m_lr, "local-vs-routing should diverge most from local-local");

    // --------------------------------- Figure 1 with content clustering
    let n = 64;
    let dim = cfg.d_model / cfg.n_heads;
    let mut src = data::source_by_name("needle", cfg.vocab_size, t, cfg.window, 77)?;
    let toks = data::take(src.as_mut(), n);
    // content-dependent routing vectors: token-id-hashed embeddings,
    // layernormed (a stand-in for q-projections; repeated tokens land in
    // the same cluster — the needle payloads route together)
    let mut xs = vec![0f32; n * dim];
    for (i, &tok) in toks.iter().enumerate() {
        let mut h = Rng::new(tok as u64 * 7919);
        let v: Vec<f32> = (0..dim).map(|_| h.normal() as f32).collect();
        xs[i * dim..(i + 1) * dim].copy_from_slice(&layernorm_nsb(&v));
    }
    let k = 8;
    let mut km = SphericalKMeans::new(k, dim, 0.5, 3);
    for _ in 0..20 {
        km.update(&xs, n);
    }
    let spec = km.routing_spec(&xs, n, n / k);
    let routing = spec.compile(n);
    println!("\nFigure 1 — routing pattern over {n} needle-corpus tokens (letters = clusters):");
    println!("{}", routing.render_ascii());
    let local = AttentionSpec::local(8)?.compile(n);
    println!(
        "densities: routing {:.3} vs local {:.3} vs full 1.0",
        routing.density(),
        local.density()
    );
    println!(
        "analytic uniform-pattern JSD local‖routing: {:.4} (bound {:.4})",
        analysis::mean_pattern_jsd(&local, &routing),
        analysis::JSD_MAX
    );

    // ----------------------- engine: cached, sharded pattern execution
    // The serving path: one compile shared across simulated heads via the
    // PatternCache (reusing the routing spec clustered above), split across
    // shard workers, executed by the host sparse-attention kernel, and
    // checked against the dense masked oracle.
    let mut cache = PatternCache::new();
    for _head in 0..8 {
        cache.get_or_compile(&spec, n);
    }
    let pattern = cache.get_or_compile(&spec, n);
    let sharded = ShardedPattern::balanced(pattern.clone(), 2)?;
    // routing q/k/v stand-ins: the layernormed content vectors themselves
    let sparse = sharded.attention(&xs, &xs, &xs, dim)?;
    let dense = dense_masked_attention(&xs, &xs, &xs, dim, &pattern)?;
    let max_diff = sparse
        .iter()
        .zip(&dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "sparse kernel must match the dense oracle (got {max_diff})");
    let stats = cache.stats();
    println!(
        "\nengine: {} pattern lookups -> {} compile ({:.0}% hits); \
         shard nnz split {:?}; sparse vs dense max |diff| = {max_diff:.2e}",
        stats.lookups(),
        stats.misses,
        stats.hit_rate() * 100.0,
        sharded.shards().iter().map(|s| s.nnz).collect::<Vec<_>>()
    );

    // ------------------- decode: epoch-keyed eviction + batched requests
    // A decode loop re-fits the routing k-means as content changes; the
    // EpochCache serves the compiled pattern while the cluster epoch is
    // current and evicts it the moment an update supersedes it.  Two
    // "requests" (the content vectors and a reversed copy) then run as
    // one batched worker sweep, bit-identical to two independent calls.
    let mut ecache = EpochCache::new();
    let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
    let mut epoch = 0u64;
    let p_before = ecache.get_routed(slot, epoch, n, || km.routing_spec(&xs, n, n / k));
    for _refit in 0..2 {
        km.update(&xs, n);
        epoch += 1;
    }
    let p_after = ecache.get_routed(slot, epoch, n, || km.routing_spec(&xs, n, n / k));
    assert!(
        ecache.stats().evictions >= 1,
        "the superseded epoch's compile must be evicted"
    );
    let _ = p_before;
    let mut rev = xs.clone();
    rev.reverse();
    let slot1 = RouteSlot { layer: 0, head: 0, seq: 1 };
    let p_rev = ecache.get_routed(slot1, epoch, n, || km.routing_spec(&rev, n, n / k));
    let batch = BatchedAttention::new(vec![p_after.clone(), p_rev], 2)?;
    let bq: Vec<f32> = xs.iter().chain(rev.iter()).copied().collect();
    let batched = batch.attention(&bq, &bq, &bq, dim)?;
    let solo0 = sparse_attention(&xs, &xs, &xs, dim, &p_after)?;
    assert_eq!(&batched[..n * dim], solo0.as_slice(), "batched seq 0 must be bit-identical");
    let solo1 = sparse_attention(&rev, &rev, &rev, dim, &batch.patterns()[1])?;
    assert_eq!(&batched[n * dim..], solo1.as_slice(), "batched seq 1 must be bit-identical");
    println!(
        "decode: epoch {} -> {} evictions, epoch hit rate {:.0}%; \
         2-request batch over {} workers OK",
        epoch,
        ecache.stats().evictions,
        ecache.epoch_stats().hit_rate() * 100.0,
        batch.num_workers()
    );

    // ---------------- pool execution + incremental re-routing
    // The batch above ran on the resident global WorkerPool (the default
    // execution).  The scoped spawn-per-call baseline and the inline
    // single-thread path must agree bitwise with it.
    let pool = WorkerPool::global();
    for exec in [Execution::Scoped, Execution::Inline, Execution::Pool(pool)] {
        let again = batch.attention_with(&bq, &bq, &bq, dim, exec)?;
        assert_eq!(again, batched, "every execution strategy must agree bitwise");
    }
    // Incremental flow: a RoutingSession advances a slot's assignment
    // epoch only when an update really moves a token between clusters,
    // so a stable re-fit keeps the compiled pattern live (an
    // unchanged-epoch hit) instead of evicting it.
    let mut session = RoutingSession::new(1, 1, k, dim, 0.5, 9)?;
    let mut icache = EpochCache::new();
    let islot = RouteSlot { layer: 0, head: 0, seq: 0 };
    session.update(0, 0, &xs, n);
    let p0 = session.routed_pattern(&mut icache, islot, &xs, n, n / k);
    let upd = session.update(0, 0, &xs, n);
    let p1 = session.routed_pattern(&mut icache, islot, &xs, n, n / k);
    if upd.delta.changed() {
        assert!(icache.stats().evictions >= 1, "moved tokens must evict the stale compile");
        println!(
            "incremental: re-fit moved {} tokens (dirty set {:?}) -> recompile + eviction",
            upd.delta.moved.len(),
            session.dirty_tokens(0, 0)
        );
    } else {
        assert!(Arc::ptr_eq(&p0, &p1), "a stable re-fit must keep serving the live compile");
        assert_eq!(icache.epoch_stats().unchanged_epochs, 1);
        println!("incremental: re-fit moved no tokens -> unchanged-epoch hit, no recompile");
    }
    println!(
        "pool: {} workers configured, {} spawned, {} jobs across {} batches",
        pool.workers(),
        pool.spawned_workers(),
        pool.jobs_run(),
        pool.batches()
    );
    println!("analyze_attention OK");
    Ok(())
}

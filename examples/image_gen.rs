//! Unconditional image generation (the paper's CIFAR-10 / ImageNet-64
//! domain): train the best Table-1 configuration on synthetic raster
//! images, report bits/dim, and sample an image rendered as ASCII
//! grayscale.
//!
//! Run: `cargo run --release --example image_gen -- [steps]`

use anyhow::Result;
use routing_transformer::coordinator::{
    eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions, Trainer,
};
use routing_transformer::runtime::{Artifacts, Runtime};
use routing_transformer::sampler::{Generator, SamplerConfig};

const RAMP: &[u8] = b" .:-=+*#%@";

fn render_ascii(img: &[i32], width: usize) -> String {
    let mut out = String::new();
    for row in img.chunks(width) {
        for &v in row {
            let idx = (v.clamp(0, 255) as usize * (RAMP.len() - 1)) / 255;
            out.push(RAMP[idx] as char);
            out.push(RAMP[idx] as char); // double width for aspect ratio
        }
        out.push('\n');
    }
    out
}

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let root = routing_transformer::bench::artifacts_root();
    let rt = Runtime::cpu()?;

    // Table 1's best shape at our scale: 4 routing heads, 2 routing
    // layers, the larger window.
    let art = Artifacts::load(&root, "image_r4l2w64")?;
    let manifest = art.manifest.clone();
    let side = (manifest.config.seq_len as f64).sqrt() as usize;
    println!(
        "training image model ({side}x{side} rasters, {} params) for {steps} steps",
        manifest.n_params_total
    );

    let mut trainer = Trainer::new(&rt, &art)?;
    let mut batcher = train_batcher(&manifest, "images", 0)?;
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: steps.max(8) as u32 / 8 },
        log_every: (steps / 8).max(1),
        ..Default::default()
    };
    let report = trainer.train(&mut batcher, &manifest, &opts)?;

    let evaluator = Evaluator::new(&rt, &art)?;
    let mut eval = eval_batcher(&manifest, "images", 3)?;
    let eval_report = evaluator.eval(&trainer.state, &mut eval, 4)?;
    println!(
        "eval bits/dim {:.3}  (paper ImageNet-64: routing 3.43 vs local 3.48; \
         absolute numbers differ on synthetic rasters)",
        eval_report.bits_per_dim()
    );
    assert!(report.mean_last10_loss < report.losses[0] as f64);

    // sample one image autoregressively (seeded with a mid-gray pixel)
    println!("sampling a {side}x{side} image ({} tokens)...", manifest.config.seq_len);
    let exe = art.executable(&rt, "logits")?;
    let mut generator = Generator::new(
        &exe,
        &trainer.state,
        manifest.config.seq_len,
        manifest.config.vocab_size,
        SamplerConfig { temperature: 1.0, top_p: 0.9 },
        5,
    );
    let img = generator.generate(&[128], manifest.config.seq_len - 1)?;
    println!("{}", render_ascii(&img, side));
    println!("image_gen OK");
    Ok(())
}

//! Quickstart: the 60-second tour of the whole stack.
//!
//! Loads the `quickstart` artifacts (built once by `make artifacts`),
//! trains the tiny Routing Transformer for a few dozen steps on the
//! needle corpus, evaluates held-out perplexity, saves/loads a
//! checkpoint, and samples a continuation — all from Rust via PJRT,
//! with no Python on the path.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use routing_transformer::coordinator::{
    eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions, Trainer,
};
use routing_transformer::runtime::{Artifacts, ModelState, Runtime};
use routing_transformer::sampler::{Generator, SamplerConfig};

fn main() -> Result<()> {
    let root = routing_transformer::bench::artifacts_root();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // 1. load artifacts + seeded initial state
    let art = Artifacts::load(&root, "quickstart")?;
    let manifest = art.manifest.clone();
    println!(
        "model: {} params, T={}, {} routing heads in top layer",
        manifest.n_params_total, manifest.config.seq_len, manifest.config.plan[1].routing
    );

    // 2. train for 48 steps on the needle (long-range retrieval) corpus
    let mut trainer = Trainer::new(&rt, &art)?;
    let mut batcher = train_batcher(&manifest, "needle", 0)?;
    let opts = TrainOptions {
        steps: 48,
        schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: 12 },
        log_every: 8,
        ..Default::default()
    };
    let report = trainer.train(&mut batcher, &manifest, &opts)?;
    println!(
        "trained {} steps: loss {:.3} -> {:.3} ({:.1} steps/s)",
        report.steps, report.losses[0], report.mean_last10_loss, report.steps_per_sec
    );
    assert!(report.mean_last10_loss < report.losses[0] as f64, "loss should decrease");

    // 3. evaluate held-out data
    let evaluator = Evaluator::new(&rt, &art)?;
    let mut eval = eval_batcher(&manifest, "needle", 7)?;
    let eval_report = evaluator.eval(&trainer.state, &mut eval, 4)?;
    println!(
        "eval: nll {:.4} nats, ppl {:.1}, bits/dim {:.3}",
        eval_report.mean_nll, eval_report.ppl(), eval_report.bits_per_dim()
    );

    // 4. checkpoint round-trip
    let ckpt = std::env::temp_dir().join("rtx_quickstart_ckpt");
    trainer.save(&manifest, &ckpt)?;
    let restored = ModelState::load(&manifest, &ckpt)?;
    println!("checkpoint round-trip ok (step {})", restored.step);

    // 5. sample a continuation
    let exe = art.executable(&rt, "logits")?;
    let mut generator = Generator::new(
        &exe,
        &restored,
        manifest.config.seq_len,
        manifest.config.vocab_size,
        SamplerConfig::default(),
        42,
    );
    let out = generator.generate(&[1, 17, 23], 16)?;
    println!("sampled continuation: {:?}", &out[3..]);
    println!("quickstart OK");
    Ok(())
}

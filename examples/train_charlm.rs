//! End-to-end driver (DESIGN.md deliverable): train a byte-level Routing
//! Transformer on the synthetic text corpus for several hundred steps,
//! logging the full loss curve, then evaluate bits/byte against the
//! all-local baseline and sample text.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.  Steps are
//! configurable: `cargo run --release --example train_charlm -- 300`.

use anyhow::Result;
use routing_transformer::coordinator::{
    eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions, Trainer,
};
use routing_transformer::runtime::{Artifacts, Runtime};
use routing_transformer::sampler::{Generator, SamplerConfig};
use routing_transformer::tokenizer::{ByteTokenizer, Tokenizer};

fn run_variant(
    rt: &Runtime,
    root: &std::path::Path,
    variant: &str,
    steps: usize,
    out_dir: &std::path::Path,
) -> Result<(f64, f64)> {
    let art = Artifacts::load(root, variant)?;
    let manifest = art.manifest.clone();
    println!(
        "\n=== {} ({} params, T={}) ===",
        variant, manifest.n_params_total, manifest.config.seq_len
    );
    let mut trainer = Trainer::new(rt, &art)?;
    let mut batcher = train_batcher(&manifest, "bytes", 0)?;
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: steps.max(8) as u32 / 8 },
        log_every: (steps / 10).max(1),
        ckpt_every: 0,
        ckpt_path: Some(out_dir.join(format!("{variant}_ckpt"))),
        log_csv: Some(out_dir.join(format!("{variant}_loss.csv"))),
    };
    let report = trainer.train(&mut batcher, &manifest, &opts)?;

    let evaluator = Evaluator::new(rt, &art)?;
    let mut eval = eval_batcher(&manifest, "bytes", 3)?;
    let eval_report = evaluator.eval(&trainer.state, &mut eval, 6)?;
    println!(
        "{variant}: train loss {:.3} -> {:.3} | eval bits/byte {:.3} | {:.2} steps/s",
        report.losses[0],
        report.mean_last10_loss,
        eval_report.bits_per_dim(),
        report.steps_per_sec
    );

    // sample a snippet of text from the trained model
    let exe = art.executable(rt, "logits")?;
    let mut generator = Generator::new(
        &exe,
        &trainer.state,
        manifest.config.seq_len,
        manifest.config.vocab_size,
        SamplerConfig::default(),
        11,
    );
    let prompt = ByteTokenizer.encode("the ");
    let out = generator.generate(&prompt, 48)?;
    println!("sample: {:?}", ByteTokenizer.decode(&out));
    Ok((eval_report.bits_per_dim(), report.steps_per_sec))
}

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let root = routing_transformer::bench::artifacts_root();
    let rt = Runtime::cpu()?;
    let out_dir = std::path::PathBuf::from("runs/charlm");
    std::fs::create_dir_all(&out_dir)?;

    let (routing_bits, routing_sps) = run_variant(&rt, &root, "byte_routing", steps, &out_dir)?;
    let (local_bits, local_sps) = run_variant(&rt, &root, "byte_local", steps, &out_dir)?;

    println!("\n=== summary (enwik-8 protocol, synthetic byte corpus) ===");
    println!("paper Table 3:  Routing 0.99 bpb vs Local 1.10 bpb (routing wins)");
    println!(
        "measured:       Routing {routing_bits:.3} bpb vs Local {local_bits:.3} bpb ({})",
        if routing_bits < local_bits { "routing wins" } else { "local wins at this scale" }
    );
    println!(
        "step time:      local/routing speed ratio {:.2}x (paper reports ~1.7x on PG-19)",
        local_sps / routing_sps
    );
    println!("loss curves: runs/charlm/*_loss.csv");
    Ok(())
}

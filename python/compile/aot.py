"""AOT lowering: jax model -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Each *variant* is a (ModelConfig, batch, scan_steps, artifact-set) preset;
`python -m compile.aot --out-dir ../artifacts` writes, per variant:

    artifacts/<variant>/train_block.hlo.txt   S fused train steps (hot path)
    artifacts/<variant>/train_step.hlo.txt    single step (quickstart only)
    artifacts/<variant>/eval_loss.hlo.txt     mean + per-position NLL
    artifacts/<variant>/logits.hlo.txt        forward logits (sampling)
    artifacts/<variant>/attn_probs.hlo.txt    dense attention dists (analysis)
    artifacts/<variant>/init_params.npz       seeded initial parameters
    artifacts/<variant>/manifest.json         shapes/dtypes/order contract

Python runs ONCE at build time; the Rust binary is self-contained after
`make artifacts`.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    HeadPlan,
    ModelConfig,
    init_params,
    param_specs,
    uniform_plan,
)
from .train import (
    make_attn_probs,
    make_eval_loss,
    make_logits,
    make_train_block,
    make_train_step,
)


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT preset: a model config plus execution shapes."""

    name: str
    cfg: ModelConfig
    batch: int
    scan_steps: int
    artifacts: Tuple[str, ...] = ("train_block", "eval_loss", "logits")
    group: str = "core"


def _image_cfg(routing_heads: int, routing_layers: int, window: int,
               kind: str = "routing", full: bool = False) -> ModelConfig:
    """Table 1 CIFAR stand-in: 16x16 grayscale raster => T=256, V=256.

    Paper grid: 12 layers / 8 heads / windows {512,1024} on T=3072.
    Scaled grid: 2 layers / 4 heads / windows {32,64} on T=256 (same sweep
    axes, same head-allocation rule: routing layers at the top)."""
    n_layers, n_heads = 2, 4
    if full:
        plan = tuple(HeadPlan(full=n_heads) for _ in range(n_layers))
    else:
        plan = uniform_plan(n_layers, n_heads, routing_heads, routing_layers, kind)
    return ModelConfig(
        vocab_size=256, d_model=64, n_layers=n_layers, n_heads=n_heads,
        seq_len=256, plan=plan, window=window, n_clusters=8,
        routing_window=window, strided_stride=16, seed=0,
    )


def build_variants() -> Dict[str, Variant]:
    v: Dict[str, Variant] = {}

    def add(var: Variant):
        assert var.name not in v, var.name
        v[var.name] = var

    # ---------------------------------------------------------- quickstart
    add(Variant(
        name="quickstart",
        cfg=ModelConfig(
            vocab_size=256, d_model=64, n_layers=2, n_heads=4, seq_len=128,
            plan=uniform_plan(2, 4, 2, 1), window=32, n_clusters=4,
            routing_window=32, seed=0,
        ),
        batch=8, scan_steps=4,
        artifacts=("train_block", "train_step", "eval_loss", "logits"),
    ))

    # --------------------------------------- Table 2 (Wikitext-103 stand-in)
    # word-level needle corpus; routing vs local vs full
    needle = dict(vocab_size=512, d_model=128, n_layers=3, n_heads=8,
                  seq_len=256, window=32, n_clusters=8, routing_window=32, seed=1)
    add(Variant("needle_routing",
                ModelConfig(plan=uniform_plan(3, 8, 4, 2), **needle),
                batch=8, scan_steps=4, group="table2"))
    add(Variant("needle_local",
                ModelConfig(plan=uniform_plan(3, 8, 0, 0), **needle),
                batch=8, scan_steps=4, group="table2"))
    add(Variant("needle_full",
                ModelConfig(plan=tuple(HeadPlan(full=8) for _ in range(3)), **needle),
                batch=8, scan_steps=4, group="table2"))

    # --------------------------------------------- Table 3 (enwik-8 stand-in)
    byte = dict(vocab_size=256, d_model=128, n_layers=3, n_heads=8,
                seq_len=512, window=64, n_clusters=16, routing_window=32, seed=2)
    add(Variant("byte_routing",
                ModelConfig(plan=uniform_plan(3, 8, 4, 2), **byte),
                batch=4, scan_steps=4, group="table3"))
    add(Variant("byte_local",
                ModelConfig(plan=uniform_plan(3, 8, 0, 0), **byte),
                batch=4, scan_steps=4, group="table3"))

    # ------------------------------------ Table 1 ablation grid + Table 4
    for w in (32, 64):
        add(Variant(f"image_local_w{w}", _image_cfg(0, 0, w),
                    batch=4, scan_steps=4, group="table1"))
    add(Variant("image_full", _image_cfg(0, 0, 64, full=True),
                batch=4, scan_steps=4, group="table1"))
    add(Variant("image_random_w32", _image_cfg(2, 2, 32, kind="random"),
                batch=4, scan_steps=4, group="table1"))
    for rh in (2, 4):
        for rl in (1, 2):
            for w in (32, 64):
                add(Variant(f"image_r{rh}l{rl}w{w}", _image_cfg(rh, rl, w),
                            batch=4, scan_steps=4, group="table1"))
    # Table 4 (ImageNet-64 stand-in): strided baseline on the image domain
    add(Variant("image_strided",
                ModelConfig(
                    vocab_size=256, d_model=64, n_layers=2, n_heads=4,
                    seq_len=256,
                    plan=tuple(HeadPlan(local=2, strided=2) for _ in range(2)),
                    window=64, n_clusters=8, routing_window=64,
                    strided_stride=16, seed=0),
                batch=4, scan_steps=4, group="table4"))

    # ------------------------------------------------ Table 5/7 (PG-19)
    pg = dict(vocab_size=1024, d_model=128, n_layers=4, n_heads=8,
              seq_len=1024, window=128, n_clusters=32, routing_window=32, seed=3)
    # paper's PG-19 plan: 2 routing heads, last 2 layers only
    add(Variant("pg19_routing",
                ModelConfig(plan=uniform_plan(4, 8, 2, 2), **pg),
                batch=2, scan_steps=2, group="table5"))
    add(Variant("pg19_local",
                ModelConfig(plan=uniform_plan(4, 8, 0, 0), **pg),
                batch=2, scan_steps=2, group="table5"))

    # ------------------------------------------------ Table 6 (JSD analysis)
    add(Variant("analysis",
                ModelConfig(plan=uniform_plan(3, 8, 4, 3),
                            **{**needle, "seed": 4}),
                batch=2, scan_steps=4,
                artifacts=("train_block", "eval_loss", "logits", "attn_probs"),
                group="table6"))

    return v


VARIANTS = build_variants()


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_shape_structs(cfg: ModelConfig):
    return [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape, _ in param_specs(cfg)]


def lower_variant(var: Variant, out_dir: Path, force: bool = False) -> None:
    cfg = var.cfg
    vdir = out_dir / var.name
    manifest_path = vdir / "manifest.json"
    if manifest_path.exists() and not force:
        print(f"  [skip] {var.name} (exists)")
        return
    vdir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()

    pstructs = _param_shape_structs(cfg)
    P = len(pstructs)
    step_s = jax.ShapeDtypeStruct((), jnp.int32)
    lr_s = jax.ShapeDtypeStruct((), jnp.float32)
    tok_s = jax.ShapeDtypeStruct((var.batch, cfg.seq_len), jnp.int32)
    tok_blk_s = jax.ShapeDtypeStruct((var.scan_steps, var.batch, cfg.seq_len), jnp.int32)

    arts: Dict[str, Dict] = {}

    def lower(name: str, fn, args):
        # keep_unused=True: jax would otherwise prune parameters an
        # artifact doesn't read (e.g. attn_probs never touches w_out),
        # breaking the uniform "P params first" calling convention the
        # Rust runtime relies on.
        text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
        fname = f"{name}.hlo.txt"
        (vdir / fname).write_text(text)
        return fname

    if "train_block" in var.artifacts:
        f = lower("train_block", make_train_block(cfg, var.scan_steps),
                  pstructs * 3 + [step_s, lr_s, tok_blk_s])
        arts["train_block"] = {
            "file": f, "scan_steps": var.scan_steps,
            "inputs": f"{P} params, {P} m, {P} v, step i32[], lr f32[], tokens i32[{var.scan_steps},{var.batch},{cfg.seq_len}]",
            "outputs": f"{P} params, {P} m, {P} v, losses f32[{var.scan_steps}]",
        }
    if "train_step" in var.artifacts:
        f = lower("train_step", make_train_step(cfg),
                  pstructs * 3 + [step_s, lr_s, tok_s])
        arts["train_step"] = {
            "file": f,
            "inputs": f"{P} params, {P} m, {P} v, step i32[], lr f32[], tokens i32[{var.batch},{cfg.seq_len}]",
            "outputs": f"{P} params, {P} m, {P} v, loss f32[]",
        }
    if "eval_loss" in var.artifacts:
        f = lower("eval_loss", make_eval_loss(cfg), pstructs + [tok_s])
        arts["eval_loss"] = {
            "file": f,
            "inputs": f"{P} params, tokens i32[{var.batch},{cfg.seq_len}]",
            "outputs": f"mean nll f32[], nll f32[{var.batch},{cfg.seq_len - 1}]",
        }
    if "logits" in var.artifacts:
        # logits artifact uses batch=1 (sampling path)
        tok1_s = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
        f = lower("logits", make_logits(cfg), pstructs + [tok1_s])
        arts["logits"] = {
            "file": f, "batch": 1,
            "inputs": f"{P} params, tokens i32[1,{cfg.seq_len}]",
            "outputs": f"logits f32[1,{cfg.seq_len},{cfg.vocab_size}]",
        }
    if "attn_probs" in var.artifacts:
        tok1_s = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
        f = lower("attn_probs", make_attn_probs(cfg), pstructs + [tok1_s])
        arts["attn_probs"] = {
            "file": f, "batch": 1,
            "inputs": f"{P} params, tokens i32[1,{cfg.seq_len}]",
            "outputs": f"probs f32[{cfg.n_layers},{cfg.n_heads},{cfg.seq_len},{cfg.seq_len}]",
        }

    # seeded initial parameters -> npz (names match param_specs order)
    params = init_params(cfg)
    np.savez(vdir / "init_params.npz",
             **{name: np.asarray(params[name]) for name, _, _ in param_specs(cfg)})

    manifest = {
        "variant": var.name,
        "group": var.group,
        "config": cfg.to_json(),
        "batch": var.batch,
        "scan_steps": var.scan_steps,
        "n_params": cfg.n_params(),
        "head_kind_order": ["local", "routing", "full", "random", "strided"],
        "params": [
            {"name": n, "shape": list(s), "dtype": d} for n, s, d in param_specs(cfg)
        ],
        "artifacts": arts,
    }
    manifest_path.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"  [ok]   {var.name}: {len(arts)} artifacts, "
          f"{cfg.n_params():,} params, {time.time() - t0:.1f}s")


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="all",
                    help="comma-separated variant names, a group name "
                         "(core/table1/..), or 'all'")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name, var in VARIANTS.items():
            print(f"{name:24s} group={var.group:8s} T={var.cfg.seq_len:5d} "
                  f"params={var.cfg.n_params():,}")
        return

    if args.variants == "all":
        selected = list(VARIANTS.values())
    else:
        sel = set(args.variants.split(","))
        selected = [v for v in VARIANTS.values() if v.name in sel or v.group in sel]
        unknown = sel - {v.name for v in selected} - {v.group for v in selected}
        if unknown:
            sys.exit(f"unknown variants/groups: {unknown}")

    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    print(f"lowering {len(selected)} variants -> {out}")
    t0 = time.time()
    for var in selected:
        lower_variant(var, out, force=args.force)
    (out / ".stamp").write_text(str(time.time()))
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

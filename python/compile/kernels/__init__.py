"""Layer-1 Pallas kernels for the Routing Transformer reproduction.

All kernels run under interpret=True (see module docstrings) so they lower
to plain HLO for the CPU PJRT runtime; real-TPU execution would compile the
same BlockSpec schedule via Mosaic.
"""

from .cluster_attention import cluster_attention
from .full_attention import full_attention
from .local_attention import local_attention

__all__ = ["cluster_attention", "local_attention", "full_attention"]

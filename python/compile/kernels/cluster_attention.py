"""Pallas kernel: within-cluster masked softmax attention.

This is the flop hot-spot of Routing Transformer's Algorithm 1 (lines
22-26).  After the L2 graph has routed tokens to clusters (centroid
dot-products, per-cluster top-w, sorted gather), each cluster is a dense
[w, d] tile of queries/keys/values plus the members' original sequence
positions.  The kernel computes, per (batch · head · cluster) grid cell:

    A   = (Q K^T) / sqrt(d)         # w x w,   MXU matmul
    A   = mask(A, pos_q >= pos_k)   # causality over ORIGINAL positions
    P   = softmax(A)                # masked, numerically stable
    out = P V                       # w x d,   MXU matmul

TPU mapping (see DESIGN.md §5): the grid dimension iterates clusters; each
program's working set is 3·w·d + w² floats, VMEM-resident via BlockSpec, so
the HBM→VMEM streaming of consecutive clusters double-buffers naturally.
The gather/scatter stays in XLA (memory-bound, no MXU benefit).

Runs under interpret=True — the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode traces to plain HLO ops so the kernel lowers
into the same AOT artifact as the surrounding jax graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9


def _cluster_attention_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)  # [w, d]
    k = k_ref[0].astype(jnp.float32)  # [w, d]
    v = v_ref[0].astype(jnp.float32)  # [w, d]
    pos = pos_ref[0]  # [w] int32

    d = q.shape[-1]
    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(d))  # [w, w]
    mask = pos[:, None] >= pos[None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    unnorm = jnp.exp(scores) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(unnorm, axis=-1, keepdims=True), 1e-20)
    probs = unnorm / denom
    o_ref[0] = jnp.dot(probs, v).astype(o_ref.dtype)


def _cluster_attention_pallas(q, k, v, pos, interpret):
    g, w, d = q.shape
    assert k.shape == (g, w, d) and v.shape == (g, w, d) and pos.shape == (g, w)
    return pl.pallas_call(
        _cluster_attention_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, w, d), q.dtype),
        interpret=interpret,
    )(q, k, v, pos)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def cluster_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    pos: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched within-cluster attention.

    q, k, v: [G, w, d] (G = batch*heads*clusters flattened), pos: [G, w]
    int32 original positions.  Returns [G, w, d].

    Differentiable: the forward pass is the Pallas kernel; the backward
    pass is jax-autodiff of the jnp reference (identical math), compiled
    into the same HLO artifact.  Kernelizing the backward pass is tracked
    in DESIGN.md §Perf.
    """
    return _cluster_attention_pallas(q, k, v, pos, interpret)


def _ca_fwd(q, k, v, pos, interpret):
    return _cluster_attention_pallas(q, k, v, pos, interpret), (q, k, v, pos)


def _ca_bwd(interpret, res, g):
    q, k, v, pos = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.cluster_attention_ref(q_, k_, v_, pos), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, np.zeros(pos.shape, jax.dtypes.float0)


cluster_attention.defvjp(_ca_fwd, _ca_bwd)

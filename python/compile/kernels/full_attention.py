"""Pallas kernel: dense causal attention (the Transformer baseline).

Used by the Table 1 "Transformer" row (full attention over the whole
sequence) and by the `full` head kind.  Grid iterates (batch·heads,
T/blk_q) query blocks; each program streams the *whole* key/value tensor
for its row — a deliberate O(T²) baseline, kept blocked so the query tile
stays VMEM-resident.  For very long sequences the paper's point is exactly
that this kernel is infeasible; it exists to anchor the comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9


def _full_attention_kernel(blk_q, q_ref, k_ref, v_ref, o_ref):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # [blk_q, d]
    k = k_ref[0].astype(jnp.float32)  # [T, d]
    v = v_ref[0].astype(jnp.float32)  # [T, d]

    d = q.shape[-1]
    t = k.shape[0]
    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(d))  # [blk_q, T]
    qpos = i * blk_q + jax.lax.iota(jnp.int32, blk_q)
    kpos = jax.lax.iota(jnp.int32, t)
    mask = kpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    unnorm = jnp.exp(scores) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(unnorm, axis=-1, keepdims=True), 1e-20)
    probs = unnorm / denom
    o_ref[0] = jnp.dot(probs, v).astype(o_ref.dtype)


def _full_attention_pallas(q, k, v, blk_q, interpret):
    n, t, d = q.shape
    blk_q = min(blk_q, t)
    assert t % blk_q == 0, (t, blk_q)
    return pl.pallas_call(
        functools.partial(_full_attention_kernel, blk_q),
        grid=(n, t // blk_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def full_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    blk_q: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Dense causal attention.  q, k, v: [N, T, D] -> [N, T, D].

    Forward = Pallas kernel; backward = autodiff of the jnp reference.
    """
    return _full_attention_pallas(q, k, v, blk_q, interpret)


def _fa_fwd(q, k, v, blk_q, interpret):
    return _full_attention_pallas(q, k, v, blk_q, interpret), (q, k, v)


def _fa_bwd(blk_q, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.full_causal_attention_ref(q_, k_, v_), q, k, v)
    return vjp(g)


full_attention.defvjp(_fa_fwd, _fa_bwd)

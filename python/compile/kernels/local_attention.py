"""Pallas kernel: blocked sliding-window causal (local) attention.

The paper's strong baseline (and half the heads of every Routing
Transformer except PG-19).  Query block i attends to key blocks i-1 and i
with causal masking, i.e. each token sees between `window` and `2*window-1`
past positions.  This is the standard "blocked local attention" of
ImageTransformer / Sparse Transformer.

TPU mapping: grid = (batch·heads, T/window).  Instead of a dynamic slice
over an HBM-resident key tensor, the previous key/value block is expressed
as a *second BlockSpec view of the same operand* with a shifted index map —
both blocks are then VMEM-resident tiles the Mosaic pipeline can
double-buffer, and both matmuls hit the MXU.  For grid cell i = 0 the
"previous" view aliases block 0 and is masked out entirely by the position
check (kpos < i*window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9


def _local_attention_kernel(window, q_ref, kc_ref, kp_ref, vc_ref, vp_ref, o_ref):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # [w, d]
    kc = kc_ref[0].astype(jnp.float32)  # current key block [w, d]
    kp = kp_ref[0].astype(jnp.float32)  # previous key block [w, d]
    vc = vc_ref[0].astype(jnp.float32)
    vp = vp_ref[0].astype(jnp.float32)

    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = i * window + jax.lax.iota(jnp.int32, window)
    kcpos = qpos
    kppos = jnp.maximum(i - 1, 0) * window + jax.lax.iota(jnp.int32, window)

    # current block: causal within block
    sc = jnp.dot(q, kc.T) * scale
    mc = kcpos[None, :] <= qpos[:, None]
    # previous block: fully visible iff it really is in the past
    sp = jnp.dot(q, kp.T) * scale
    mp = jnp.broadcast_to(kppos[None, :] < i * window, sp.shape)

    scores = jnp.concatenate([sp, sc], axis=-1)  # [w, 2w]
    mask = jnp.concatenate([mp, mc], axis=-1)
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    unnorm = jnp.exp(scores) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(unnorm, axis=-1, keepdims=True), 1e-20)
    probs = unnorm / denom
    out = jnp.dot(probs[:, window:], vc) + jnp.dot(probs[:, :window], vp)
    o_ref[0] = out.astype(o_ref.dtype)


def _local_attention_pallas(q, k, v, window, interpret):
    n, t, d = q.shape
    assert t % window == 0, (t, window)
    nblk = t // window
    cur = pl.BlockSpec((1, window, d), lambda b, i: (b, i, 0))
    prv = pl.BlockSpec((1, window, d), lambda b, i: (b, jnp.maximum(i - 1, 0), 0))
    return pl.pallas_call(
        functools.partial(_local_attention_kernel, window),
        grid=(n, nblk),
        in_specs=[cur, cur, prv, cur, prv],
        out_specs=cur,
        out_shape=jax.ShapeDtypeStruct((n, t, d), q.dtype),
        interpret=interpret,
    )(q, k, k, v, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def local_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked local causal attention.

    q, k, v: [N, T, D] (N = batch*heads flattened), T % window == 0.
    Returns [N, T, D].

    Forward = Pallas kernel; backward = autodiff of the jnp reference
    (identical semantics), both compiled into the same HLO artifact.
    """
    return _local_attention_pallas(q, k, v, window, interpret)


def _la_fwd(q, k, v, window, interpret):
    return _local_attention_pallas(q, k, v, window, interpret), (q, k, v)


def _la_bwd(window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.local_attention_ref(q_, k_, v_, window), q, k, v)
    return vjp(g)


local_attention.defvjp(_la_fwd, _la_bwd)

"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here with
identical semantics (including masking, scaling and degenerate-row
handling).  pytest/hypothesis compares kernel output against these over
swept shapes and dtypes.  These functions are *never* part of the AOT
artifacts; they exist only for correctness checking.
"""

from __future__ import annotations

import jax.lax as lax
import jax.numpy as jnp

NEG_INF = -1e9


def _masked_softmax(scores: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable softmax over the last axis with a boolean mask.

    Rows where every entry is masked produce all-zero probabilities
    (instead of NaN), matching the kernel behaviour.
    """
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    unnorm = jnp.exp(scores) * mask.astype(scores.dtype)
    denom = jnp.sum(unnorm, axis=-1, keepdims=True)
    return unnorm / jnp.maximum(denom, 1e-20)


def full_causal_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Dense causal attention.  q, k, v: [..., T, D] -> [..., T, D]."""
    dtype = q.dtype
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    d = q.shape[-1]
    t = q.shape[-2]
    scores = jnp.einsum("...td,...sd->...ts", q, k) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    probs = _masked_softmax(scores, mask)
    return jnp.einsum("...ts,...sd->...td", probs, v).astype(dtype)


def full_causal_probs_ref(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Dense causal attention *distributions* [..., T, T] (for JSD analysis)."""
    q, k = q.astype(jnp.float32), k.astype(jnp.float32)
    d = q.shape[-1]
    t = q.shape[-2]
    scores = jnp.einsum("...td,...sd->...ts", q, k) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    return _masked_softmax(scores, mask)


def local_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, window: int
) -> jnp.ndarray:
    """Blocked sliding-window causal attention.

    q, k, v: [..., T, D] with T % window == 0.  Query block i attends to key
    blocks i-1 and i (causally within block i), i.e. an effective context of
    [window, 2*window) past positions — the standard "blocked local
    attention" used by ImageTransformer/Sparse Transformer style models.
    """
    dtype = q.dtype
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    t, d = q.shape[-2], q.shape[-1]
    assert t % window == 0, (t, window)
    scores = jnp.einsum("...td,...sd->...ts", q, k) / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = (kpos <= qpos) & (qpos // window - kpos // window <= 1)
    probs = _masked_softmax(scores, mask)
    return jnp.einsum("...ts,...sd->...td", probs, v).astype(dtype)


def local_probs_ref(q: jnp.ndarray, k: jnp.ndarray, window: int) -> jnp.ndarray:
    """Attention distributions of blocked local attention, [..., T, T]."""
    q, k = q.astype(jnp.float32), k.astype(jnp.float32)
    t, d = q.shape[-2], q.shape[-1]
    scores = jnp.einsum("...td,...sd->...ts", q, k) / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = (kpos <= qpos) & (qpos // window - kpos // window <= 1)
    return _masked_softmax(scores, mask)


def cluster_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    pos: jnp.ndarray,
) -> jnp.ndarray:
    """Within-cluster masked attention (Algorithm 1, lines 22-26).

    q, k, v: [..., W, D] gathered cluster members; pos: [..., W] int32
    original sequence positions of the members.  Member a attends to member
    b iff pos[b] <= pos[a] (causal over *original* positions; the diagonal
    — the token itself — is always visible).
    """
    dtype = q.dtype
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    d = q.shape[-1]
    scores = jnp.einsum("...td,...sd->...ts", q, k) / jnp.sqrt(jnp.float32(d))
    mask = pos[..., :, None] >= pos[..., None, :]
    probs = _masked_softmax(scores, mask)
    return jnp.einsum("...ts,...sd->...td", probs, v).astype(dtype)


def layernorm_nsb_ref(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """LayerNorm with scale and bias disabled (the paper's unit-ball proxy)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) / jnp.sqrt(var + eps)).astype(x.dtype)


def _gather_members(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """x: [B,H,T,D], idx: [B,H,K,w] -> [B,H,K,w,D]."""
    b, h, _, _ = x.shape
    bidx = jnp.arange(b)[:, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None]
    return x[bidx, hidx, idx]


def routing_attention_ref(
    qk: jnp.ndarray,
    v: jnp.ndarray,
    mu: jnp.ndarray,
    window: int,
):
    """Full Algorithm 1 (shared-QK causal variant) in pure jnp.

    qk : [B, H, T, D]  layer-normalized shared query/keys (unit-ball)
    v  : [B, H, T, D]  values
    mu : [H, K, D]     centroids (unit-normalized)
    window : w, members per cluster (top-w by centroid dot product)

    Returns (out [B,H,T,D], cluster_sum [H,K,D], cluster_cnt [H,K]) where
    the sums/counts are the per-centroid assignment statistics used for the
    EMA update (argmax assignment, matching Algorithm 1 lines 28-31).

    Tokens selected by several clusters contribute to each; their outputs
    are averaged (count-normalized scatter-add).  Tokens selected by no
    cluster produce zeros.
    """
    b, h, t, d = qk.shape
    qk32 = qk.astype(jnp.float32)
    # [B, H, K, T] routing scores
    scores = jnp.einsum("hkd,bhtd->bhkt", mu.astype(jnp.float32), qk32)
    # top-w per cluster, sorted ascending to preserve temporal order
    _, idx = lax.top_k(scores, window)  # [B,H,K,w]
    idx = jnp.sort(idx, axis=-1)
    gq = _gather_members(qk, idx)
    gv = _gather_members(v, idx)
    out_g = cluster_attention_ref(gq, gq, gv, idx)
    # scatter-add back with count normalization
    out = jnp.zeros((b, h, t, d), jnp.float32)
    cnt = jnp.zeros((b, h, t), jnp.float32)
    bidx = jnp.arange(b)[:, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None]
    out = out.at[bidx, hidx, idx].add(out_g.astype(jnp.float32))
    cnt = cnt.at[bidx, hidx, idx].add(1.0)
    out = out / jnp.maximum(cnt, 1.0)[..., None]
    # EMA statistics: hard argmax assignment over clusters per token
    assign = jnp.argmax(scores, axis=2)  # [B,H,T]
    onehot = (assign[..., None] == jnp.arange(mu.shape[1])).astype(jnp.float32)
    cluster_sum = jnp.einsum("bhtk,bhtd->hkd", onehot, qk32)
    cluster_cnt = jnp.sum(onehot, axis=(0, 2))  # [H,K]
    return out.astype(qk.dtype), cluster_sum, cluster_cnt


def routing_probs_ref(qk: jnp.ndarray, mu: jnp.ndarray, window: int) -> jnp.ndarray:
    """Dense [B,H,T,T] attention distributions induced by routing attention.

    Used for the Table 6 JSD study: reconstruct the full (sparse) attention
    distribution each query implicitly has over the sequence.  A query's
    row is the count-normalized average of its within-cluster softmax rows
    across all clusters that selected it; unselected queries get an empty
    (all-zero) row.
    """
    b, h, t, d = qk.shape
    qk32 = qk.astype(jnp.float32)
    scores = jnp.einsum("hkd,bhtd->bhkt", mu.astype(jnp.float32), qk32)
    _, idx = lax.top_k(scores, window)
    idx = jnp.sort(idx, axis=-1)  # [B,H,K,w]
    gq = _gather_members(qk, idx)
    att = jnp.einsum("bhkwd,bhkxd->bhkwx", gq.astype(jnp.float32), gq.astype(jnp.float32))
    att = att / jnp.sqrt(jnp.float32(d))
    mask = idx[..., :, None] >= idx[..., None, :]
    probs = _masked_softmax(att, mask)  # [B,H,K,w,w]
    dense = jnp.zeros((b, h, t, t), jnp.float32)
    cnt = jnp.zeros((b, h, t), jnp.float32)
    bidx = jnp.arange(b)[:, None, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None, None]
    qidx = idx[..., :, None]  # [B,H,K,w,1]
    kidx = idx[..., None, :]  # [B,H,K,1,w]
    dense = dense.at[bidx, hidx, qidx, kidx].add(probs)
    cnt = cnt.at[
        jnp.arange(b)[:, None, None, None],
        jnp.arange(h)[None, :, None, None],
        idx,
    ].add(1.0)
    dense = dense / jnp.maximum(cnt, 1.0)[..., None]
    return dense


def centroid_ema_ref(
    mu: jnp.ndarray, cluster_sum: jnp.ndarray, cluster_cnt: jnp.ndarray, decay: float
) -> jnp.ndarray:
    """Online spherical k-means EMA update (Algorithm 1 line 31).

    mu: [H,K,D]; cluster_sum: [H,K,D]; cluster_cnt: [H,K].
    We use the count-normalized mean of assigned vectors and re-project the
    centroid to the unit sphere after the EMA (spherical k-means; scale
    inside the EMA washes out after normalization — see DESIGN.md §3).
    Clusters with zero assigned tokens keep their centroid unchanged.
    """
    mean = cluster_sum / jnp.maximum(cluster_cnt[..., None], 1.0)
    new = decay * mu + (1.0 - decay) * mean
    new = jnp.where(cluster_cnt[..., None] > 0, new, mu)
    norm = jnp.sqrt(jnp.sum(jnp.square(new), axis=-1, keepdims=True))
    return new / jnp.maximum(norm, 1e-6)

"""Layer-2 JAX model: decoder-only Routing Transformer language model.

Build-time only — this module is traced and AOT-lowered by `aot.py` into
HLO text artifacts that the Rust coordinator executes via PJRT.  It never
runs at serving/training time.

The model implements the paper's architecture (Section 3-4):
  * token + learned absolute position embeddings (substitution for Shaw
    relative encodings — DESIGN.md §3),
  * pre-LayerNorm transformer blocks with per-layer *head plans* mixing
    attention kinds: `local`, `routing`, `full`, `random`, `strided`,
  * routing heads follow Algorithm 1: shared QK projected to the unit
    ball with scale/bias-free LayerNorm, online spherical k-means
    centroids, per-cluster balanced top-w membership, within-cluster
    attention (the L1 Pallas kernel), count-normalized scatter,
  * centroid EMA statistics surfaced as auxiliary outputs so the train
    step can apply the (non-gradient) k-means update.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.lax as lax
import jax.numpy as jnp
import numpy as np

from .kernels import cluster_attention, full_attention, local_attention

NEG_INF = -1e9
# Fixed head-kind ordering inside a layer: the slice of the head axis each
# kind owns is determined by this order (manifest records it for L3).
HEAD_KINDS = ("local", "routing", "full", "random", "strided")


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    """Number of heads of each kind within one layer."""

    local: int = 0
    routing: int = 0
    full: int = 0
    random: int = 0
    strided: int = 0

    def total(self) -> int:
        return self.local + self.routing + self.full + self.random + self.strided

    def counts(self) -> List[Tuple[str, int]]:
        return [(kind, getattr(self, kind)) for kind in HEAD_KINDS]

    def to_json(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in HEAD_KINDS if getattr(self, k) > 0}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + routing hyper-parameters.

    `plan` has one HeadPlan per layer; every plan must sum to `n_heads`.
    `window` is the local-attention block size; `routing_window` is w
    (members per cluster); `n_clusters` is k.  The paper's optimal choice
    is k = sqrt(T), w = T/k (Section 4.1).
    """

    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    plan: Tuple[HeadPlan, ...]
    window: int = 64
    n_clusters: int = 8
    routing_window: int = 64
    strided_stride: int = 16
    centroid_decay: float = 0.999
    ffw_mult: int = 4
    init_scale: float = 0.02
    seed: int = 0

    def __post_init__(self):
        assert len(self.plan) == self.n_layers, "one HeadPlan per layer"
        for p in self.plan:
            assert p.total() == self.n_heads, f"plan {p} != n_heads {self.n_heads}"
        assert self.d_model % self.n_heads == 0
        assert self.seq_len % self.window == 0
        assert self.routing_window <= self.seq_len

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s, _ in param_specs(self))

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["plan"] = [p.to_json() for p in self.plan]
        return d


def uniform_plan(n_layers: int, n_heads: int, routing_heads: int, routing_layers: int,
                 kind: str = "routing") -> Tuple[HeadPlan, ...]:
    """Paper-style plan: the *top* `routing_layers` layers get
    `routing_heads` heads of `kind` (rest local); lower layers all-local.

    "Routing layers when present are always added at the top of the model"
    (Table 1 caption);  PG-19 uses routing heads only in the last 2 layers.
    """
    plans = []
    for layer in range(n_layers):
        if layer >= n_layers - routing_layers and routing_heads > 0:
            plans.append(HeadPlan(local=n_heads - routing_heads, **{kind: routing_heads}))
        else:
            plans.append(HeadPlan(local=n_heads))
    return tuple(plans)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """(name, shape, dtype) for every parameter, in FLATTEN ORDER (sorted
    by name).  This order is the contract with the Rust runtime: manifests
    list it, npz checkpoints use the names, and the lowered HLO takes the
    arrays in exactly this order."""
    d, dh = cfg.d_model, cfg.d_head
    specs: Dict[str, Tuple[int, ...]] = {
        "tok_emb": (cfg.vocab_size, d),
        "pos_emb": (cfg.seq_len, d),
        "ln_f.scale": (d,),
        "ln_f.bias": (d,),
        "w_out": (d, cfg.vocab_size),
    }
    for layer in range(cfg.n_layers):
        p = cfg.plan[layer]
        pre = f"layer{layer:02d}."
        specs[pre + "ln1.scale"] = (d,)
        specs[pre + "ln1.bias"] = (d,)
        specs[pre + "attn.wq"] = (d, d)
        specs[pre + "attn.wk"] = (d, d)
        specs[pre + "attn.wv"] = (d, d)
        specs[pre + "attn.wo"] = (d, d)
        if p.routing > 0:
            specs[pre + "attn.centroids"] = (p.routing, cfg.n_clusters, dh)
        specs[pre + "ln2.scale"] = (d,)
        specs[pre + "ln2.bias"] = (d,)
        specs[pre + "mlp.w1"] = (d, cfg.ffw_mult * d)
        specs[pre + "mlp.b1"] = (cfg.ffw_mult * d,)
        specs[pre + "mlp.w2"] = (cfg.ffw_mult * d, d)
        specs[pre + "mlp.b2"] = (d,)
    return [(name, specs[name], "f32") for name in sorted(specs)]


def init_params(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Seeded initialization.  Centroids start as random unit vectors."""
    rng = np.random.default_rng(cfg.seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, shape, _ in param_specs(cfg):
        if name.endswith(".scale"):
            arr = np.ones(shape, np.float32)
        elif name.endswith((".bias", ".b1", ".b2")):
            arr = np.zeros(shape, np.float32)
        elif name.endswith("centroids"):
            arr = rng.normal(size=shape).astype(np.float32)
            arr /= np.maximum(np.linalg.norm(arr, axis=-1, keepdims=True), 1e-6)
        else:
            arr = (rng.normal(size=shape) * cfg.init_scale).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[name] for name, _, _ in param_specs(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    return {name: arr for (name, _, _), arr in zip(param_specs(cfg), flat)}


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def layernorm_nsb(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Scale/bias-free LayerNorm: the paper's projection onto the d-ball
    (Section 4.1) that makes MIPS equivalent to nearest-neighbor search."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    unnorm = jnp.exp(scores) * mask.astype(scores.dtype)
    return unnorm / jnp.maximum(jnp.sum(unnorm, axis=-1, keepdims=True), 1e-20)


def _gather_members(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """x: [B,Hr,T,D], idx: [B,Hr,K,w] -> [B,Hr,K,w,D]."""
    b, h = x.shape[0], x.shape[1]
    bidx = jnp.arange(b)[:, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None]
    return x[bidx, hidx, idx]


def _route_and_attend(qk: jnp.ndarray, v: jnp.ndarray, scores: jnp.ndarray, w: int):
    """Shared machinery of routing/random heads: given routing scores
    [B,Hr,K,T], select balanced top-w members per cluster, run the L1
    cluster-attention kernel, and scatter back with count normalization."""
    b, h, t, dh = qk.shape
    kk = scores.shape[2]
    # top-w per cluster via a full descending sort.  NOTE: lax.top_k
    # lowers to the `topk` HLO instruction, which xla_extension 0.5.1's
    # parser rejects, and jnp.argsort's gather path trips an incompat in
    # this jaxlib; lax.sort_key_val lowers to the classic `sort` op.
    # stop_gradient: the router only selects indices; differentiating
    # through the sort trips the jaxlib gather-transpose incompat anyway.
    scores_sg = lax.stop_gradient(scores)
    iota = lax.broadcasted_iota(jnp.int32, scores_sg.shape, len(scores_sg.shape) - 1)
    _, idx_sorted = lax.sort_key_val(-scores_sg, iota, dimension=-1)
    idx = idx_sorted[..., :w]  # [B,Hr,K,w]
    idx = jnp.sort(idx, axis=-1)  # preserve temporal order (Alg.1 line 14)
    gq = _gather_members(qk, idx)
    gv = _gather_members(v, idx)
    g = b * h * kk
    out_g = cluster_attention(
        gq.reshape(g, w, dh), gq.reshape(g, w, dh), gv.reshape(g, w, dh),
        idx.reshape(g, w).astype(jnp.int32),
    ).reshape(b, h, kk, w, dh)
    out = jnp.zeros((b, h, t, dh), jnp.float32)
    cnt = jnp.zeros((b, h, t), jnp.float32)
    bidx = jnp.arange(b)[:, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None]
    out = out.at[bidx, hidx, idx].add(out_g)
    cnt = cnt.at[bidx, hidx, idx].add(1.0)
    return out / jnp.maximum(cnt, 1.0)[..., None], idx


def routing_heads_attention(cfg: ModelConfig, qh: jnp.ndarray, vh: jnp.ndarray,
                            centroids: jnp.ndarray):
    """Algorithm 1 for the routing head group (shared QK, causal).

    qh: [B,Hr,T,dh] raw query projections; vh values; centroids [Hr,K,dh].
    Returns (out, cluster_sum, cluster_cnt).
    """
    qk = layernorm_nsb(qh)
    # centroid routing scores; stop_gradient: the router picks indices only,
    # no gradient flows into (or out of) the clustering decision.
    scores = jnp.einsum("hkd,bhtd->bhkt", centroids, lax.stop_gradient(qk))
    out, _ = _route_and_attend(qk, vh, scores, cfg.routing_window)
    # EMA statistics (Alg.1 lines 28-31) with hard argmax assignment
    qk_sg = lax.stop_gradient(qk)
    assign = jnp.argmax(scores, axis=2)  # [B,Hr,T]
    onehot = (assign[..., None] == jnp.arange(cfg.n_clusters)).astype(jnp.float32)
    cluster_sum = jnp.einsum("bhtk,bhtd->hkd", onehot, qk_sg)
    cluster_cnt = jnp.sum(onehot, axis=(0, 2))
    return out, cluster_sum, cluster_cnt


def random_heads_attention(cfg: ModelConfig, layer: int, qh: jnp.ndarray, vh: jnp.ndarray):
    """Table 1's Random Transformer control: K_idx drawn at random instead
    of by nearest-neighbor search.  Same balanced-window machinery, but the
    routing scores are a fixed random constant (baked at trace time)."""
    b, h, t, dh = qh.shape
    rng = np.random.default_rng(cfg.seed * 1000 + layer + 17)
    const_scores = jnp.asarray(
        rng.normal(size=(1, h, cfg.n_clusters, t)).astype(np.float32)
    )
    scores = jnp.broadcast_to(const_scores, (b, h, cfg.n_clusters, t))
    qk = layernorm_nsb(qh)
    out, _ = _route_and_attend(qk, vh, scores, cfg.routing_window)
    return out


def strided_heads_attention(cfg: ModelConfig, qh, kh, vh):
    """Child et al. strided attention: attend to j <= i with (i-j) % s == 0.
    Dense-masked implementation — a baseline, deliberately O(T^2)."""
    b, h, t, dh = qh.shape
    scores = jnp.einsum("bhtd,bhsd->bhts", qh, kh) / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = (kpos <= qpos) & ((qpos - kpos) % cfg.strided_stride == 0)
    probs = _masked_softmax(scores, mask)
    return jnp.einsum("bhts,bhsd->bhtd", probs, vh)


# --------------------------------------------------------------------------
# Transformer forward
# --------------------------------------------------------------------------


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def attention_layer(cfg: ModelConfig, params: Dict[str, jnp.ndarray], layer: int,
                    x: jnp.ndarray):
    """One attention module with a mixed head plan.

    Returns (out [B,T,d], aux) where aux is (cluster_sum, cluster_cnt) if
    the layer has routing heads else None.
    """
    pre = f"layer{layer:02d}."
    plan = cfg.plan[layer]
    q = _split_heads(x @ params[pre + "attn.wq"], cfg.n_heads)
    k = _split_heads(x @ params[pre + "attn.wk"], cfg.n_heads)
    v = _split_heads(x @ params[pre + "attn.wv"], cfg.n_heads)
    b, _, t, dh = q.shape

    outs: List[jnp.ndarray] = []
    aux = None
    h0 = 0
    for kind, cnt in plan.counts():
        if cnt == 0:
            continue
        sl = slice(h0, h0 + cnt)
        h0 += cnt
        qs, ks, vs = q[:, sl], k[:, sl], v[:, sl]
        if kind == "local":
            o = local_attention(
                qs.reshape(b * cnt, t, dh), ks.reshape(b * cnt, t, dh),
                vs.reshape(b * cnt, t, dh), cfg.window,
            ).reshape(b, cnt, t, dh)
        elif kind == "routing":
            o, cs, cc = routing_heads_attention(cfg, qs, vs, params[pre + "attn.centroids"])
            aux = (cs, cc)
        elif kind == "full":
            o = full_attention(
                qs.reshape(b * cnt, t, dh), ks.reshape(b * cnt, t, dh),
                vs.reshape(b * cnt, t, dh), blk_q=min(128, t),
            ).reshape(b, cnt, t, dh)
        elif kind == "random":
            o = random_heads_attention(cfg, layer, qs, vs)
        elif kind == "strided":
            o = strided_heads_attention(cfg, qs, ks, vs)
        else:  # pragma: no cover
            raise ValueError(kind)
        outs.append(o)

    merged = _merge_heads(jnp.concatenate(outs, axis=1))
    return merged @ params[pre + "attn.wo"], aux


def forward(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """tokens: [B,T] int32 -> (logits [B,T,V], aux {layer: (sum,cnt)})."""
    b, t = tokens.shape
    assert t == cfg.seq_len, (t, cfg.seq_len)
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t]
    auxes: Dict[int, Tuple[jnp.ndarray, jnp.ndarray]] = {}
    for layer in range(cfg.n_layers):
        pre = f"layer{layer:02d}."
        a_in = layernorm(h, params[pre + "ln1.scale"], params[pre + "ln1.bias"])
        a_out, aux = attention_layer(cfg, params, layer, a_in)
        if aux is not None:
            auxes[layer] = aux
        h = h + a_out
        m_in = layernorm(h, params[pre + "ln2.scale"], params[pre + "ln2.bias"])
        m = jax.nn.relu(m_in @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        h = h + m @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    h = layernorm(h, params["ln_f.scale"], params["ln_f.bias"])
    logits = h @ params["w_out"]
    return logits, auxes


def loss_fn(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """Mean next-token cross-entropy (nats).  Returns (loss, aux)."""
    logits, auxes = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), auxes


# --------------------------------------------------------------------------
# Analysis: dense attention distributions for the Table 6 JSD study
# --------------------------------------------------------------------------


def attention_probs(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """Dense per-head attention distributions [L, H, T, T] (batch element 0).

    Local and full heads get their exact distributions; routing heads get
    the count-normalized distribution induced by their cluster assignments
    (ref.routing_probs semantics); random/strided heads return zeros (not
    used by the Table 6 study)."""
    b, t = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t]
    all_probs: List[jnp.ndarray] = []
    for layer in range(cfg.n_layers):
        pre = f"layer{layer:02d}."
        plan = cfg.plan[layer]
        a_in = layernorm(h, params[pre + "ln1.scale"], params[pre + "ln1.bias"])
        q = _split_heads(a_in @ params[pre + "attn.wq"], cfg.n_heads)
        k = _split_heads(a_in @ params[pre + "attn.wk"], cfg.n_heads)
        layer_probs: List[jnp.ndarray] = []
        h0 = 0
        for kind, cnt in plan.counts():
            if cnt == 0:
                continue
            sl = slice(h0, h0 + cnt)
            h0 += cnt
            qs, ks = q[:1, sl], k[:1, sl]
            dh = qs.shape[-1]
            if kind == "local":
                scores = jnp.einsum("bhtd,bhsd->bhts", qs, ks) / jnp.sqrt(jnp.float32(dh))
                qpos = jnp.arange(t)[:, None]
                kpos = jnp.arange(t)[None, :]
                mask = (kpos <= qpos) & (qpos // cfg.window - kpos // cfg.window <= 1)
                layer_probs.append(_masked_softmax(scores, mask)[0])
            elif kind == "full":
                scores = jnp.einsum("bhtd,bhsd->bhts", qs, ks) / jnp.sqrt(jnp.float32(dh))
                mask = jnp.tril(jnp.ones((t, t), bool))
                layer_probs.append(_masked_softmax(scores, mask)[0])
            elif kind == "routing":
                qk = layernorm_nsb(qs)
                mu = params[pre + "attn.centroids"]
                layer_probs.append(_routing_probs(cfg, qk, mu)[0])
            else:
                layer_probs.append(jnp.zeros((cnt, t, t), jnp.float32))
        all_probs.append(jnp.concatenate(layer_probs, axis=0))
        # advance the residual stream with the *real* layer
        a_out, _ = attention_layer(cfg, params, layer, a_in)
        h = h + a_out
        m_in = layernorm(h, params[pre + "ln2.scale"], params[pre + "ln2.bias"])
        m = jax.nn.relu(m_in @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        h = h + m @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    return jnp.stack(all_probs)  # [L, H, T, T]


def _routing_probs(cfg: ModelConfig, qk: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Dense [B,Hr,T,T] distribution induced by routing attention."""
    b, h, t, d = qk.shape
    w = cfg.routing_window
    scores = jnp.einsum("hkd,bhtd->bhkt", mu, qk)
    scores_sg = lax.stop_gradient(scores)
    iota = lax.broadcasted_iota(jnp.int32, scores_sg.shape, len(scores_sg.shape) - 1)
    _, idx_sorted = lax.sort_key_val(-scores_sg, iota, dimension=-1)
    idx = idx_sorted[..., :w]  # see _route_and_attend re topk/argsort
    idx = jnp.sort(idx, axis=-1)
    gq = _gather_members(qk, idx)
    att = jnp.einsum("bhkwd,bhkxd->bhkwx", gq, gq) / jnp.sqrt(jnp.float32(d))
    mask = idx[..., :, None] >= idx[..., None, :]
    probs = _masked_softmax(att, mask)
    dense = jnp.zeros((b, h, t, t), jnp.float32)
    cnt = jnp.zeros((b, h, t), jnp.float32)
    bidx = jnp.arange(b)[:, None, None, None, None]
    hidx = jnp.arange(h)[None, :, None, None, None]
    dense = dense.at[bidx, hidx, idx[..., :, None], idx[..., None, :]].add(probs)
    cnt = cnt.at[
        jnp.arange(b)[:, None, None, None], jnp.arange(h)[None, :, None, None], idx
    ].add(1.0)
    return dense / jnp.maximum(cnt, 1.0)[..., None]


# --------------------------------------------------------------------------
# Config (de)serialization for manifests
# --------------------------------------------------------------------------


def config_from_json(d: Dict[str, Any]) -> ModelConfig:
    plan = tuple(HeadPlan(**p) for p in d["plan"])
    kwargs = {k: v for k, v in d.items() if k != "plan"}
    return ModelConfig(plan=plan, **kwargs)


def config_to_json_str(cfg: ModelConfig) -> str:
    return json.dumps(cfg.to_json(), indent=2, sort_keys=True)

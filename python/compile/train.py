"""Layer-2 training graph: Adam + centroid EMA, single-step and scanned.

The scanned `train_block` is the hot-path artifact: PJRT (via the published
`xla` crate) returns multi-result executions as ONE tuple-shaped buffer, so
chaining state on-device buffer-to-buffer is impossible; instead we amortize
the host round-trip over S fused steps inside one executable (a
`lax.scan`), the same trick MaxText-style trainers use to amortize dispatch.
See EXPERIMENTS.md §Perf for the measured effect.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .model import ModelConfig, loss_fn, param_specs


def centroid_ema(mu: jnp.ndarray, cluster_sum: jnp.ndarray, cluster_cnt: jnp.ndarray,
                 decay: float) -> jnp.ndarray:
    """Online spherical k-means update (Algorithm 1 line 31).

    Count-normalized mean + EMA + re-projection to the unit sphere; empty
    clusters keep their centroid (see kernels/ref.py for rationale)."""
    mean = cluster_sum / jnp.maximum(cluster_cnt[..., None], 1.0)
    new = decay * mu + (1.0 - decay) * mean
    new = jnp.where(cluster_cnt[..., None] > 0, new, mu)
    norm = jnp.sqrt(jnp.sum(jnp.square(new), axis=-1, keepdims=True))
    return new / jnp.maximum(norm, 1e-6)


def adam_update(p, g, m, v, step, lr, b1=0.9, b2=0.98, eps=1e-9):
    """Adam with the paper's betas (Section 5: b1=0.9, b2=0.98)."""
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * jnp.square(g)
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1.0 - jnp.power(b1, t))
    vhat = v / (1.0 - jnp.power(b2, t))
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def _train_step_tree(cfg: ModelConfig, params: Dict[str, jnp.ndarray],
                     m: Dict[str, jnp.ndarray], v: Dict[str, jnp.ndarray],
                     step: jnp.ndarray, lr: jnp.ndarray, tokens: jnp.ndarray):
    """One optimization step over dict-structured state."""
    (loss, auxes), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens), has_aux=True
    )(params)
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        if name.endswith("centroids"):
            # k-means EMA instead of a gradient step (no gradient reaches
            # centroids anyway: they only select indices).
            layer = int(name[len("layer"):len("layer") + 2])
            cs, cc = auxes[layer]
            new_p[name] = centroid_ema(params[name], cs, cc, cfg.centroid_decay)
            new_m[name] = m[name]
            new_v[name] = v[name]
        else:
            new_p[name], new_m[name], new_v[name] = adam_update(
                params[name], grads[name], m[name], v[name], step, lr
            )
    return new_p, new_m, new_v, loss


def make_train_step(cfg: ModelConfig):
    """Flat-argument single train step, the shape the HLO artifact exposes:

        (P params, P m, P v, step i32[], lr f32[], tokens i32[B,T])
            -> (P params', P m', P v', loss f32[])
    """
    names = [n for n, _, _ in param_specs(cfg)]
    P = len(names)

    def train_step(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P : 2 * P]))
        v = dict(zip(names, args[2 * P : 3 * P]))
        step, lr, tokens = args[3 * P], args[3 * P + 1], args[3 * P + 2]
        new_p, new_m, new_v, loss = _train_step_tree(cfg, params, m, v, step, lr, tokens)
        return tuple(
            [new_p[n] for n in names] + [new_m[n] for n in names] + [new_v[n] for n in names]
            + [loss]
        )

    return train_step


def make_train_block(cfg: ModelConfig, scan_steps: int):
    """S fused train steps via lax.scan — the hot-path artifact:

        (P params, P m, P v, step i32[], lr f32[], tokens i32[S,B,T])
            -> (P params', P m', P v', losses f32[S])
    """
    names = [n for n, _, _ in param_specs(cfg)]
    P = len(names)

    def train_block(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P : 2 * P]))
        v = dict(zip(names, args[2 * P : 3 * P]))
        step, lr, tokens = args[3 * P], args[3 * P + 1], args[3 * P + 2]

        def body(carry, batch):
            params, m, v, step = carry
            new_p, new_m, new_v, loss = _train_step_tree(cfg, params, m, v, step, lr, batch)
            return (new_p, new_m, new_v, step + 1), loss

        (params, m, v, _), losses = jax.lax.scan(
            body, (params, m, v, step), tokens, length=scan_steps
        )
        return tuple(
            [params[n] for n in names] + [m[n] for n in names] + [v[n] for n in names]
            + [losses]
        )

    return train_block


def make_logits(cfg: ModelConfig):
    """(P params, tokens i32[B,T]) -> logits f32[B,T,V]."""
    from .model import forward

    names = [n for n, _, _ in param_specs(cfg)]
    P = len(names)

    def logits_fn(*args):
        params = dict(zip(names, args[:P]))
        tokens = args[P]
        logits, _ = forward(cfg, params, tokens)
        return (logits,)

    return logits_fn


def make_eval_loss(cfg: ModelConfig):
    """(P params, tokens i32[B,T]) -> (mean nll f32[], per-position nll f32[B,T-1])."""
    from .model import forward

    names = [n for n, _, _ in param_specs(cfg)]
    P = len(names)

    def eval_fn(*args):
        params = dict(zip(names, args[:P]))
        tokens = args[P]
        logits, _ = forward(cfg, params, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return (jnp.mean(nll), nll)

    return eval_fn


def make_attn_probs(cfg: ModelConfig):
    """(P params, tokens i32[B,T]) -> probs f32[L,H,T,T]  (analysis only)."""
    from .model import attention_probs

    names = [n for n, _, _ in param_specs(cfg)]
    P = len(names)

    def probs_fn(*args):
        params = dict(zip(names, args[:P]))
        tokens = args[P]
        return (attention_probs(cfg, params, tokens),)

    return probs_fn

"""AOT contract tests: variant presets, HLO text lowering, manifest.

Checks that every preset is internally consistent, that lowering produces
parseable HLO text with the *full* parameter signature (keep_unused), and
that no variant emits the `topk` HLO instruction xla_extension 0.5.1
cannot parse.
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile.aot import VARIANTS, lower_variant, to_hlo_text
from compile.model import param_specs
from compile.train import make_eval_loss


def test_variant_presets_consistent():
    assert "quickstart" in VARIANTS
    groups = {v.group for v in VARIANTS.values()}
    # every paper table with a dedicated workload has variants
    for g in ["table1", "table2", "table3", "table4", "table5", "table6"]:
        assert g in groups, f"missing variants for {g}"
    for var in VARIANTS.values():
        cfg = var.cfg
        assert cfg.seq_len % cfg.window == 0
        assert cfg.routing_window <= cfg.seq_len
        for plan in cfg.plan:
            assert plan.total() == cfg.n_heads
    # the PG-19 preset follows the paper: 2 routing heads, last 2 layers
    pg = VARIANTS["pg19_routing"].cfg
    assert pg.plan[-1].routing == 2 and pg.plan[-2].routing == 2
    assert pg.plan[0].routing == 0


def test_lowering_keeps_full_signature_and_no_topk(tmp_path):
    var = VARIANTS["quickstart"]
    cfg = var.cfg
    P = len(param_specs(cfg))
    pstructs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in param_specs(cfg)]
    tok = jax.ShapeDtypeStruct((var.batch, cfg.seq_len), jnp.int32)
    text = to_hlo_text(
        jax.jit(make_eval_loss(cfg), keep_unused=True).lower(*pstructs, tok)
    )
    assert text.startswith("HloModule")
    assert " topk(" not in text, "topk breaks xla_extension 0.5.1's parser"
    # entry layout mentions P+1 parameters
    header = text.splitlines()[0]
    assert header.count("f32[") + header.count("s32[") >= P + 1


def test_lower_variant_writes_complete_artifact(tmp_path):
    lower_variant(VARIANTS["quickstart"], tmp_path, force=True)
    vdir = tmp_path / "quickstart"
    manifest = json.loads((vdir / "manifest.json").read_text())
    for art in manifest["artifacts"].values():
        assert (vdir / art["file"]).exists()
    assert (vdir / "init_params.npz").exists()
    # manifest params match the model's specs exactly (names + shapes)
    cfg = VARIANTS["quickstart"].cfg
    specs = [(n, list(s)) for n, s, _ in param_specs(cfg)]
    got = [(p["name"], p["shape"]) for p in manifest["params"]]
    assert specs == got
    # idempotence: second call without --force skips
    lower_variant(VARIANTS["quickstart"], tmp_path, force=False)


def test_init_params_npz_matches_manifest(tmp_path):
    import numpy as np

    lower_variant(VARIANTS["quickstart"], tmp_path, force=True)
    vdir = tmp_path / "quickstart"
    manifest = json.loads((vdir / "manifest.json").read_text())
    npz = np.load(vdir / "init_params.npz")
    for p in manifest["params"]:
        assert p["name"] in npz.files
        assert list(npz[p["name"]].shape) == p["shape"]
    # centroids are unit-norm at init
    cents = [f for f in npz.files if f.endswith("centroids")]
    assert cents
    for c in cents:
        norms = np.linalg.norm(npz[c], axis=-1)
        np.testing.assert_allclose(norms, 1.0, rtol=1e-5)

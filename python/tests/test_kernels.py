"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the core
correctness signal for everything that ends up inside the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cluster_attention, full_attention, local_attention
from compile.kernels import ref

TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------- cluster


@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(1, 8),
    w=st.sampled_from([1, 2, 4, 8, 16]),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cluster_attention_matches_ref(g, w, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, (g, w, d))
    k = rand(rng, (g, w, d))
    v = rand(rng, (g, w, d))
    pos = jnp.asarray(rng.integers(0, 4 * w, size=(g, w)), jnp.int32)
    pos = jnp.sort(pos, axis=-1)
    out = cluster_attention(q, k, v, pos)
    expect = ref.cluster_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.array(out), np.array(expect), **TOL)


def test_cluster_attention_bf16():
    rng = np.random.default_rng(7)
    g, w, d = 4, 8, 16
    q = rand(rng, (g, w, d), jnp.bfloat16)
    v = rand(rng, (g, w, d), jnp.bfloat16)
    pos = jnp.sort(jnp.asarray(rng.integers(0, 64, size=(g, w)), jnp.int32), axis=-1)
    out = cluster_attention(q, q, v, pos)
    expect = ref.cluster_attention_ref(q, q, v, pos)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.array(out, np.float32), np.array(expect, np.float32), **BF16_TOL
    )


def test_cluster_attention_causality():
    """Perturbing a member never changes outputs of earlier positions."""
    rng = np.random.default_rng(3)
    g, w, d = 1, 8, 8
    q = rand(rng, (g, w, d))
    v = rand(rng, (g, w, d))
    pos = jnp.asarray(np.arange(w)[None, :], jnp.int32)
    base = np.array(cluster_attention(q, q, v, pos))
    v2 = v.at[0, -1].add(100.0)
    pert = np.array(cluster_attention(q, q, v2, pos))
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], **TOL)
    assert not np.allclose(base[0, -1], pert[0, -1])


def test_cluster_attention_duplicate_positions_see_each_other():
    """Members with equal positions attend to one another (>= mask)."""
    rng = np.random.default_rng(4)
    g, w, d = 1, 4, 8
    q = rand(rng, (g, w, d))
    v = rand(rng, (g, w, d))
    pos = jnp.asarray([[5, 5, 5, 5]], jnp.int32)
    out = np.array(cluster_attention(q, q, v, pos))
    expect = np.array(ref.cluster_attention_ref(q, q, v, pos))
    np.testing.assert_allclose(out, expect, **TOL)
    # every row is a full softmax over all four members -> rows differ from v
    assert np.isfinite(out).all()


# ------------------------------------------------------------------ local


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    nblk=st.integers(1, 6),
    window=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_local_attention_matches_ref(n, nblk, window, d, seed):
    rng = np.random.default_rng(seed)
    t = nblk * window
    q = rand(rng, (n, t, d))
    k = rand(rng, (n, t, d))
    v = rand(rng, (n, t, d))
    out = local_attention(q, k, v, window)
    expect = ref.local_attention_ref(q, k, v, window)
    np.testing.assert_allclose(np.array(out), np.array(expect), **TOL)


def test_local_attention_first_block_is_strictly_causal():
    """Block 0 has no previous block; token 0 attends only to itself."""
    rng = np.random.default_rng(11)
    n, t, d, w = 1, 32, 8, 8
    q = rand(rng, (n, t, d))
    k = rand(rng, (n, t, d))
    v = rand(rng, (n, t, d))
    out = np.array(local_attention(q, k, v, w))
    np.testing.assert_allclose(out[0, 0], np.array(v[0, 0]), **TOL)


def test_local_attention_window_bound():
    """Keys further than 2*window-1 in the past never influence a query."""
    rng = np.random.default_rng(12)
    n, t, d, w = 1, 64, 8, 8
    q = rand(rng, (n, t, d))
    k = rand(rng, (n, t, d))
    v = rand(rng, (n, t, d))
    base = np.array(local_attention(q, k, v, w))
    # perturb position 0; queries at positions >= 2w must not change
    v2 = v.at[0, 0].add(1000.0)
    k2 = k.at[0, 0].add(1000.0)
    pert = np.array(local_attention(q, k2, v2, w))
    np.testing.assert_allclose(base[0, 2 * w :], pert[0, 2 * w :], **TOL)


def test_local_attention_bf16():
    rng = np.random.default_rng(13)
    n, t, d, w = 2, 32, 16, 8
    q = rand(rng, (n, t, d), jnp.bfloat16)
    k = rand(rng, (n, t, d), jnp.bfloat16)
    v = rand(rng, (n, t, d), jnp.bfloat16)
    out = local_attention(q, k, v, w)
    expect = ref.local_attention_ref(q, k, v, w)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.array(out, np.float32), np.array(expect, np.float32), **BF16_TOL
    )


# ------------------------------------------------------------------- full


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 4),
    t=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([4, 16]),
    blk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_full_attention_matches_ref(n, t, d, blk, seed):
    if t % blk != 0:
        blk = t
    rng = np.random.default_rng(seed)
    q = rand(rng, (n, t, d))
    k = rand(rng, (n, t, d))
    v = rand(rng, (n, t, d))
    out = full_attention(q, k, v, blk_q=blk)
    expect = ref.full_causal_attention_ref(q, k, v)
    np.testing.assert_allclose(np.array(out), np.array(expect), **TOL)


def test_full_attention_rows_are_distributions():
    rng = np.random.default_rng(21)
    n, t = 1, 16
    q = rand(rng, (n, t, t))
    k = rand(rng, (n, t, t))
    # v = identity basis: output row i == attention distribution over keys
    v = jnp.eye(t)[None].astype(jnp.float32)
    out = np.array(full_attention(q, k, v, blk_q=16))
    sums = out.sum(-1)
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-5, atol=1e-5)
    # causal: strictly-future entries are zero
    assert abs(out[0][np.triu_indices(t, 1)]).max() < 1e-6


# ---------------------------------------------------------------- routing


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    t=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16]),
    k=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_routing_ref_invariants(b, h, t, d, k, seed):
    """Paper invariants of Algorithm 1 on the reference implementation."""
    rng = np.random.default_rng(seed)
    w = t // k
    qk = ref.layernorm_nsb_ref(rand(rng, (b, h, t, d)))
    v = rand(rng, (b, h, t, d))
    mu = rand(rng, (h, k, d))
    mu = mu / jnp.linalg.norm(mu, axis=-1, keepdims=True)
    out, cs, cc = ref.routing_attention_ref(qk, v, mu, w)
    assert out.shape == (b, h, t, d)
    assert np.isfinite(np.array(out)).all()
    # every token is argmax-assigned to exactly one cluster
    assert float(np.array(cc).sum()) == pytest.approx(b * h * t)
    # balanced top-w membership: each cluster gathers exactly w members
    scores = jnp.einsum("hkd,bhtd->bhkt", mu, qk)
    import jax.lax as lax

    _, idx = lax.top_k(scores, w)
    assert idx.shape == (b, h, k, w)


def test_routing_prefers_high_dot_product_keys():
    """MIPS property: a query's cluster contains its highest-dot keys when
    centroids are well separated."""
    rng = np.random.default_rng(5)
    d = 16
    # two well-separated directions
    mu = np.zeros((1, 2, d), np.float32)
    mu[0, 0, 0] = 1.0
    mu[0, 1, 1] = 1.0
    t = 16
    x = np.zeros((1, 1, t, d), np.float32)
    half = t // 2
    x[0, 0, :half, 0] = 1.0  # first half aligned with centroid 0
    x[0, 0, half:, 1] = 1.0  # second half aligned with centroid 1
    x += rng.normal(size=x.shape).astype(np.float32) * 0.05
    qk = ref.layernorm_nsb_ref(jnp.asarray(x))
    scores = jnp.einsum("hkd,bhtd->bhkt", jnp.asarray(mu), qk)
    import jax.lax as lax

    _, idx = lax.top_k(scores, half)
    idx = np.array(jnp.sort(idx, axis=-1))
    np.testing.assert_array_equal(idx[0, 0, 0], np.arange(half))
    np.testing.assert_array_equal(idx[0, 0, 1], np.arange(half, t))


def test_centroid_ema_moves_toward_assigned_mean():
    rng = np.random.default_rng(6)
    h, k, d = 1, 2, 8
    mu = rng.normal(size=(h, k, d)).astype(np.float32)
    mu /= np.linalg.norm(mu, axis=-1, keepdims=True)
    target = rng.normal(size=(h, k, d)).astype(np.float32)
    cnt = np.full((h, k), 4.0, np.float32)
    new = np.array(ref.centroid_ema_ref(jnp.asarray(mu), jnp.asarray(target * 4), jnp.asarray(cnt), 0.5))
    # unit norm preserved
    np.testing.assert_allclose(np.linalg.norm(new, axis=-1), 1.0, rtol=1e-5)
    # moved toward target direction
    tn = target / np.linalg.norm(target, axis=-1, keepdims=True)
    assert (np.sum(new * tn, -1) > np.sum(mu * tn, -1) - 1e-6).all()


def test_centroid_ema_empty_cluster_unchanged():
    mu = np.array([[[1.0, 0.0], [0.0, 1.0]]], np.float32)
    cs = np.zeros((1, 2, 2), np.float32)
    cc = np.array([[0.0, 3.0]], np.float32)
    cs[0, 1] = [3.0, 0.0]
    new = np.array(ref.centroid_ema_ref(jnp.asarray(mu), jnp.asarray(cs), jnp.asarray(cc), 0.9))
    np.testing.assert_allclose(new[0, 0], mu[0, 0], rtol=1e-6)
    assert new[0, 1, 0] > 0.0  # moved toward the assigned mass


def test_layernorm_nsb_unit_ball():
    """LN without scale/bias gives (approx) constant-norm vectors: the
    paper's projection to the d-ball making MIPS ≡ NNS."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4, 32), scale=10.0), jnp.float32)
    y = np.array(ref.layernorm_nsb_ref(x))
    norms = np.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(norms, np.sqrt(32.0), rtol=1e-3)


def test_routing_probs_rows_sum_to_one_or_zero():
    rng = np.random.default_rng(9)
    b, h, t, d, k = 1, 1, 32, 8, 4
    w = t // k
    qk = ref.layernorm_nsb_ref(rand(rng, (b, h, t, d)))
    mu = rand(rng, (h, k, d))
    mu = mu / jnp.linalg.norm(mu, axis=-1, keepdims=True)
    dense = np.array(ref.routing_probs_ref(qk, mu, w))
    sums = dense.sum(-1)
    ok = np.isclose(sums, 1.0, atol=1e-4) | np.isclose(sums, 0.0, atol=1e-6)
    assert ok.all()
    # causality over original positions
    assert abs(dense[0, 0][np.triu_indices(t, 1)]).max() < 1e-6

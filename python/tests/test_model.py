"""L2 model tests: shapes, head plans, routing semantics, analysis probs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    HeadPlan,
    ModelConfig,
    attention_probs,
    config_from_json,
    forward,
    init_params,
    layernorm_nsb,
    loss_fn,
    param_specs,
    routing_heads_attention,
    uniform_plan,
)


def tiny_cfg(**kw):
    base = dict(
        vocab_size=64, d_model=32, n_layers=2, n_heads=4, seq_len=64,
        plan=uniform_plan(2, 4, 2, 1), window=16, n_clusters=4,
        routing_window=16, seed=0,
    )
    base.update(kw)
    return ModelConfig(**base)


def toks(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len)), jnp.int32)


def test_param_specs_sorted_and_complete():
    cfg = tiny_cfg()
    names = [n for n, _, _ in param_specs(cfg)]
    assert names == sorted(names), "flatten order must be sorted by name"
    assert "layer01.attn.centroids" in names
    assert "layer00.attn.centroids" not in names  # layer 0 is all-local
    assert "tok_emb" in names and "w_out" in names


def test_n_params_counts_scalars():
    cfg = tiny_cfg()
    params = init_params(cfg)
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == cfg.n_params()


def test_forward_shapes_and_finite():
    cfg = tiny_cfg()
    params = init_params(cfg)
    logits, aux = forward(cfg, params, toks(cfg))
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert np.isfinite(np.array(logits)).all()
    assert list(aux) == [1]  # only layer 1 has routing heads
    cs, cc = aux[1]
    assert cs.shape == (2, cfg.n_clusters, cfg.d_head)
    assert cc.shape == (2, cfg.n_clusters)


def test_forward_causality_local_only():
    """Perturbing a future token must not change earlier logits for
    local/full attention models (strict value causality)."""
    cfg = tiny_cfg(plan=uniform_plan(2, 4, 0, 0))
    params = init_params(cfg)
    t1 = toks(cfg, b=1, seed=1)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
    l1, _ = forward(cfg, params, t1)
    l2, _ = forward(cfg, params, t2)
    np.testing.assert_allclose(
        np.array(l1[0, :-1]), np.array(l2[0, :-1]), rtol=1e-5, atol=1e-5
    )


def test_routing_membership_is_full_sequence():
    """Algorithm 1 caveat (shared with the paper's implementation): the
    balanced top-w cluster membership is computed over the FULL sequence,
    so a future token can change which *past* tokens share a cluster (the
    causal mask applies within clusters, to attention values only).  This
    test documents that property: attention VALUES remain causal (past
    keys only), but earlier logits may shift when membership changes."""
    cfg = tiny_cfg()
    params = init_params(cfg)
    t1 = toks(cfg, b=1, seed=1)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
    l1, _ = forward(cfg, params, t1)
    l2, _ = forward(cfg, params, t2)
    # the perturbation reaches earlier positions only through membership:
    # the shift must be bounded (no direct value flow from the future)
    delta = np.abs(np.array(l1[0, :-1]) - np.array(l2[0, :-1])).max()
    base = np.abs(np.array(l1[0, :-1])).max()
    assert delta < 0.5 * base, f"membership-only effect expected, delta={delta}"


@pytest.mark.parametrize("kind", ["full", "random", "strided"])
def test_alternative_head_kinds_forward(kind):
    plan = (
        HeadPlan(local=4),
        HeadPlan(**{"local": 2, kind: 2}),
    )
    cfg = tiny_cfg(plan=plan)
    params = init_params(cfg)
    logits, _ = forward(cfg, params, toks(cfg))
    assert np.isfinite(np.array(logits)).all()


def test_loss_near_uniform_at_init():
    cfg = tiny_cfg()
    params = init_params(cfg)
    loss, _ = loss_fn(cfg, params, toks(cfg))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5


def test_routing_heads_attention_matches_ref():
    """Model routing (Pallas inner kernel) vs the pure-jnp oracle."""
    from compile.kernels import ref

    cfg = tiny_cfg()
    rng = np.random.default_rng(3)
    b, h, t, dh = 2, 2, cfg.seq_len, cfg.d_head
    q = jnp.asarray(rng.normal(size=(b, h, t, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dh)), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(h, cfg.n_clusters, dh)), jnp.float32)
    mu = mu / jnp.linalg.norm(mu, axis=-1, keepdims=True)

    out, cs, cc = routing_heads_attention(cfg, q, v, mu)
    qk = layernorm_nsb(q)
    out_ref, cs_ref, cc_ref = ref.routing_attention_ref(qk, v, mu, cfg.routing_window)
    np.testing.assert_allclose(np.array(out), np.array(out_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(cs), np.array(cs_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(cc), np.array(cc_ref), rtol=1e-6, atol=0)


def test_centroids_receive_no_gradient():
    cfg = tiny_cfg()
    params = init_params(cfg)
    grads = jax.grad(lambda p: loss_fn(cfg, p, toks(cfg))[0])(params)
    g = np.array(grads["layer01.attn.centroids"])
    assert np.abs(g).max() == 0.0, "no gradient may reach the centroids"
    # while e.g. wq of the same layer does get gradient
    assert np.abs(np.array(grads["layer01.attn.wq"])).max() > 0.0


def test_attention_probs_rows_are_distributions():
    cfg = tiny_cfg()
    params = init_params(cfg)
    probs = attention_probs(cfg, params, toks(cfg, b=1))
    p = np.array(probs)
    assert p.shape == (cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.seq_len)
    sums = p.sum(-1)
    ok = np.isclose(sums, 1.0, atol=1e-4) | np.isclose(sums, 0.0, atol=1e-5)
    assert ok.all()
    # strictly-causal: no mass above the diagonal
    triu = np.triu_indices(cfg.seq_len, 1)
    assert abs(p[..., triu[0], triu[1]]).max() < 1e-6


def test_config_json_roundtrip():
    cfg = tiny_cfg()
    back = config_from_json(cfg.to_json())
    assert back == cfg

"""L2 training-graph tests: Adam, centroid EMA, scan block semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelConfig, init_params, param_specs, uniform_plan
from compile.train import (
    adam_update,
    centroid_ema,
    make_eval_loss,
    make_logits,
    make_train_block,
    make_train_step,
)


def tiny_cfg():
    return ModelConfig(
        vocab_size=64, d_model=32, n_layers=2, n_heads=4, seq_len=64,
        plan=uniform_plan(2, 4, 2, 1), window=16, n_clusters=4,
        routing_window=16, seed=0,
    )


def flat_state(cfg):
    params = init_params(cfg)
    names = [n for n, _, _ in param_specs(cfg)]
    flat = [params[n] for n in names]
    zeros = [jnp.zeros_like(p) for p in flat]
    return names, flat, zeros


def toks(cfg, shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=shape), jnp.int32)


def test_adam_moves_against_gradient():
    p = jnp.ones((4,))
    g = jnp.ones((4,))
    m = jnp.zeros((4,))
    v = jnp.zeros((4,))
    newp, newm, newv = adam_update(p, g, m, v, jnp.int32(0), jnp.float32(0.1))
    assert (np.array(newp) < 1.0).all()
    assert (np.array(newm) > 0).all()
    assert (np.array(newv) > 0).all()


def test_adam_bias_correction_first_step_size():
    # at step 0 with eps small, |update| ~ lr regardless of gradient scale
    for scale in [0.01, 1.0, 100.0]:
        p = jnp.zeros((1,))
        g = jnp.full((1,), scale)
        newp, _, _ = adam_update(p, g, jnp.zeros((1,)), jnp.zeros((1,)),
                                 jnp.int32(0), jnp.float32(0.1))
        assert abs(abs(float(newp[0])) - 0.1) < 1e-3


def test_centroid_ema_unit_norm_and_empty_freeze():
    mu = jnp.asarray([[[1.0, 0.0], [0.0, 1.0]]], jnp.float32)
    cs = jnp.asarray([[[0.0, 4.0], [0.0, 0.0]]], jnp.float32)
    cc = jnp.asarray([[4.0, 0.0]], jnp.float32)
    new = np.array(centroid_ema(mu, cs, cc, 0.5))
    np.testing.assert_allclose(np.linalg.norm(new, axis=-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(new[0, 1], [0.0, 1.0], atol=1e-7)  # empty frozen
    assert new[0, 0, 1] > 0.0  # moved toward assigned mean


def test_train_step_loss_decreases_on_repeated_batch():
    cfg = tiny_cfg()
    names, flat, zeros = flat_state(cfg)
    step_fn = jax.jit(make_train_step(cfg))
    batch = toks(cfg, (4, cfg.seq_len))
    p, m, v = flat, zeros, [jnp.zeros_like(x) for x in flat]
    losses = []
    for i in range(6):
        out = step_fn(*p, *m, *v, jnp.int32(i), jnp.float32(2e-3), batch)
        P = len(flat)
        p, m, v = list(out[:P]), list(out[P:2*P]), list(out[2*P:3*P])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0]


def test_train_block_equals_repeated_train_step():
    cfg = tiny_cfg()
    names, flat, zeros = flat_state(cfg)
    P = len(flat)
    S = 3
    batch = toks(cfg, (S, 2, cfg.seq_len), seed=5)

    block_fn = jax.jit(make_train_block(cfg, S))
    out_block = block_fn(*flat, *zeros, *zeros, jnp.int32(0), jnp.float32(1e-3), batch)
    losses_block = np.array(out_block[-1])

    step_fn = jax.jit(make_train_step(cfg))
    p, m, v = flat, zeros, zeros
    losses_step = []
    for s in range(S):
        out = step_fn(*p, *m, *v, jnp.int32(s), jnp.float32(1e-3), batch[s])
        p, m, v = list(out[:P]), list(out[P:2*P]), list(out[2*P:3*P])
        losses_step.append(float(out[-1]))
    np.testing.assert_allclose(losses_block, losses_step, rtol=1e-5, atol=1e-6)
    # final params agree too
    for a, b in zip(out_block[:P], p):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-5)


def test_eval_loss_and_logits_consistent():
    cfg = tiny_cfg()
    names, flat, _ = flat_state(cfg)
    batch = toks(cfg, (2, cfg.seq_len), seed=9)
    mean_nll, nll = jax.jit(make_eval_loss(cfg))(*flat, batch)
    assert nll.shape == (2, cfg.seq_len - 1)
    np.testing.assert_allclose(float(mean_nll), float(np.array(nll).mean()), rtol=1e-6)

    (logits,) = jax.jit(make_logits(cfg))(*flat, batch[:1])
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    manual = -np.take_along_axis(
        np.array(logp), np.array(batch[:1, 1:])[..., None], axis=-1
    )[..., 0]
    np.testing.assert_allclose(manual, np.array(nll)[:1], rtol=1e-4, atol=1e-5)

//! Complexity sweep — Section 4.1's O(n^1.5 d) claim.
//!
//! Two parts: (1) the analytic cost model swept over sequence length,
//! showing the full/local/routing crossovers and that k* = √n minimizes
//! routing cost; (2) measured host-side routing cost (k-means assign +
//! top-w membership, the part the model adds over plain attention) vs n.

use routing_transformer::attention::{attention_flops, optimal_clusters, AttentionKind};
use routing_transformer::kmeans::SphericalKMeans;
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::{time_fn, Table};

fn main() {
    println!("Section 4.1 — complexity model sweep (d = 64)\n");
    let d = 64;
    let mut table = Table::new(&[
        "n", "k*=sqrt(2n)", "full MACs", "local(w=256)", "routing(k*)", "routing/full",
    ]);
    for &n in &[1024usize, 2048, 4096, 8192, 16384, 32768] {
        let k = optimal_clusters(n);
        let full = attention_flops(AttentionKind::Full, n, d);
        let local = attention_flops(AttentionKind::Local { window: 256 }, n, d);
        let routing = attention_flops(AttentionKind::Routing { clusters: k }, n, d);
        table.row(&[
            n.to_string(),
            k.to_string(),
            format!("{:.2e}", full as f64),
            format!("{:.2e}", local as f64),
            format!("{:.2e}", routing as f64),
            format!("{:.3}", routing as f64 / full as f64),
        ]);
    }
    table.print();

    // n^1.5 scaling check: routing cost ratio for 4x n should be ~8x
    let c1 = attention_flops(
        AttentionKind::Routing { clusters: optimal_clusters(4096) }, 4096, d);
    let c2 = attention_flops(
        AttentionKind::Routing { clusters: optimal_clusters(16384) }, 16384, d);
    println!("\nscaling: cost(4n)/cost(n) = {:.2} (n^1.5 predicts 8.0)\n", c2 as f64 / c1 as f64);

    // measured host-side routing overhead (assignment + top-w) vs n
    println!("measured routing overhead (k-means assign + balanced top-w), d = 64:");
    let mut table = Table::new(&["n", "k", "mean ms", "ms/n (µs)"]);
    for &n in &[256usize, 1024, 4096] {
        let k = optimal_clusters(n);
        let mut rng = Rng::new(7);
        let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let km = SphericalKMeans::new(k, d, 0.5, 1);
        let stats = time_fn(1, 5, || {
            let members = km.top_w_members(&xs, n, n / k);
            std::hint::black_box(members);
        });
        table.row(&[
            n.to_string(),
            k.to_string(),
            format!("{:.3}", stats.mean * 1e3),
            format!("{:.2}", stats.mean * 1e6 / n as f64),
        ]);
    }
    table.print();
    println!("\nbench_complexity OK");
}

//! Complexity sweep — Section 4.1's O(n^1.5 d) claim.
//!
//! Thirteen parts: (1) the analytic `AttentionSpec::flops_estimate` model
//! swept over sequence length, showing the full/local/routing crossovers
//! and that k* = √n minimizes routing cost; (2) measured host-side routing
//! cost (k-means assign + top-w membership + pattern compile, the part the
//! model adds over plain attention) vs n; (3) compiled CSR vs the old
//! `Vec::contains`-scan pattern evaluation at n = 512, k = √n — the
//! redesign must be >= 10x faster end to end (compile + nnz query);
//! (4) `PatternCache` multi-head compile amortization over a heads x
//! layers x steps serving sweep — cached must be >= 5x over uncached;
//! (5) cross-request batching — B = 8 independent sequences through one
//! `BatchedAttention` worker sweep vs 8 sequential single-thread kernel
//! calls, bit-identical outputs required and batched must be >= 2x (the
//! speedup pin is gated on >= 4 cores; 2 cores cap the ceiling at 2.0x);
//! (6) the resident `WorkerPool` vs scoped spawn-per-call over a
//! decode-shaped loop (B = 8 small sequences, 64 steps, so the per-call
//! thread spawns dominate) — bit-identical outputs required and the pool
//! must be >= 1.3x (gated on >= 4 cores like part 5);
//! (7) the cache-blocked host backend vs the scalar reference kernel at
//! n = 2048, d = 64 — bit-identical outputs required and `Blocked` must
//! be >= 1.5x (single-thread ILP, so no core gate);
//! (8) the lane-widened `Simd` fast-math backend over the same shape —
//! its outputs must match `Reference` within exactly its *declared*
//! `Ulps(k)` budget (never bitwise, never a silently wider tolerance)
//! and it must be >= 3x over the reference kernel (single-thread like
//! part 7, so no core gate);
//! (9) incremental (dirty-cluster-only) spec regeneration — a sparse
//! k-means step must re-rank exactly the delta-touched clusters
//! (counter-verified) and still produce the from-scratch spec;
//! (10) the continuous-batching serve loop end to end — a seeded
//! open-loop workload must resolve every request exactly once, drain its
//! routed compiles via retirement GC, replay bit-deterministically, and
//! report p50/p99 step latency (liveness pins only — wall-clock serve
//! latency is tracked across PRs in `BENCH_serve.json`, not pinned here);
//! (11) memory-bounded banded compilation — `ChunkedPattern` streaming
//! 512-row bands against a 4 MiB `MemoryBudget` must stay bit-identical
//! to the monolithic compile for Local and Routing specs at
//! n ∈ {8192, 65536}, with peak resident pattern bytes bounded by
//! budget + one band and growing sublinearly in n (n grows 8x, peak must
//! grow <= 4x) while the monolithic footprint grows linearly;
//! (12) multi-process coordination overhead — the part-10 serve workload
//! re-run through a 2-worker `Coordinator` over the in-memory
//! `SimTransport` must be bit-identical (output digest + outcome ledger)
//! with a conserved grant ledger; the protocol overhead is printed, not
//! pinned (it is a BENCH_serve.json trajectory concern);
//! (13) quality vs nnz across the content-based spec families — on a
//! skewed token layout, token-choice routing, expert-choice, and the
//! score-threshold family are compared at matched nnz (JSD against full
//! causal attention as the support-divergence proxy), and the pin is
//! load balance: expert-choice's per-cluster capacity bound must keep a
//! 2-way nnz-balanced shard split no more imbalanced than routing's.

use std::sync::Arc;

use routing_transformer::attention::{
    assert_outputs_match, optimal_clusters, run_serve, run_serve_coordinated, sparse_attention,
    ArrivalConfig, AttentionSpec, Backend, BatchedAttention, Blocked, ChunkedPattern,
    CompiledPattern, Coordinator, CoordinatorConfig, Exactness, Execution, MemberCache,
    MemoryBudget, PatternCache, Reference, RoutingSession, ServeOptions, Simd, SimTransport,
    WorkerPool,
};
use routing_transformer::analysis;
use routing_transformer::kmeans::{dot, SphericalKMeans};
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::{time_fn, Table};

/// The pre-redesign reference path: answer "may i attend to j" by scanning
/// cluster membership lists with `Vec::contains` for every causal (i, j)
/// pair — O(n² · k · w) for an nnz count.
fn contains_scan_nnz(n: usize, clusters: &[Vec<usize>]) -> usize {
    let mut nnz = 0usize;
    for i in 0..n {
        for j in 0..=i {
            if clusters.iter().any(|m| m.contains(&i) && m.contains(&j)) {
                nnz += 1;
            }
        }
    }
    nnz
}

fn main() {
    println!("Section 4.1 — complexity model sweep (d = 64)\n");
    let d = 64;
    let mut table = Table::new(&[
        "n", "k*=sqrt(2n)", "full MACs", "local(w=256)", "routing(k*)", "routing/full",
    ]);
    let local = AttentionSpec::local(256).unwrap();
    for &n in &[1024usize, 2048, 4096, 8192, 16384, 32768] {
        let k = optimal_clusters(n);
        let full = AttentionSpec::Full.flops_estimate(n, d);
        let loc = local.flops_estimate(n, d);
        let routing = AttentionSpec::routing_balanced(n, k).unwrap().flops_estimate(n, d);
        table.row(&[
            n.to_string(),
            k.to_string(),
            format!("{:.2e}", full as f64),
            format!("{:.2e}", loc as f64),
            format!("{:.2e}", routing as f64),
            format!("{:.3}", routing as f64 / full as f64),
        ]);
    }
    table.print();

    // n^1.5 scaling check: routing cost ratio for 4x n should be ~8x
    let c1 = AttentionSpec::routing_balanced(4096, optimal_clusters(4096))
        .unwrap()
        .flops_estimate(4096, d);
    let c2 = AttentionSpec::routing_balanced(16384, optimal_clusters(16384))
        .unwrap()
        .flops_estimate(16384, d);
    println!("\nscaling: cost(4n)/cost(n) = {:.2} (n^1.5 predicts 8.0)\n", c2 as f64 / c1 as f64);

    // measured host-side routing overhead (assignment + top-w + compile) vs n
    println!("measured routing overhead (k-means assign + balanced top-w + compile), d = 64:");
    let mut table = Table::new(&["n", "k", "mean ms", "ms/n (µs)", "nnz"]);
    for &n in &[256usize, 1024, 4096] {
        let k = optimal_clusters(n);
        let mut rng = Rng::new(7);
        let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let km = SphericalKMeans::new(k, d, 0.5, 1);
        let mut nnz = 0usize;
        let stats = time_fn(1, 5, || {
            let pattern = km.routing_spec(&xs, n, n / k).compile(n);
            nnz = pattern.nnz();
            std::hint::black_box(&pattern);
        });
        table.row(&[
            n.to_string(),
            k.to_string(),
            format!("{:.3}", stats.mean * 1e3),
            format!("{:.2}", stats.mean * 1e6 / n as f64),
            nnz.to_string(),
        ]);
    }
    table.print();

    // compiled CSR vs the old contains-scan path: n = 512, k = √n
    let n = 512usize;
    let k = (n as f64).sqrt().round() as usize; // 23 ≈ √512, w = n/k
    let mut rng = Rng::new(11);
    let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let km = SphericalKMeans::new(k, d, 0.5, 3);
    let clusters = km.top_w_members(&xs, n, n / k);
    let spec = AttentionSpec::routing(clusters.clone());

    let mut csr_nnz = 0usize;
    let new_path = time_fn(1, 5, || {
        let pattern = spec.compile(n);
        csr_nnz = std::hint::black_box(pattern.nnz());
    });
    let mut scan_nnz = 0usize;
    let old_path = time_fn(0, 2, || {
        scan_nnz = std::hint::black_box(contains_scan_nnz(n, &clusters));
    });
    assert_eq!(csr_nnz, scan_nnz, "CSR and contains-scan must count the same set");
    let speedup = old_path.mean / new_path.mean;
    println!(
        "\ncompile+nnz vs contains-scan at n={n}, k={k}: {:.3} ms vs {:.3} ms ({speedup:.0}x)",
        new_path.mean * 1e3,
        old_path.mean * 1e3
    );
    assert!(
        speedup >= 10.0,
        "compiled path must be >= 10x faster than the contains-scan path (got {speedup:.1}x)"
    );

    // cached vs uncached multi-head pattern compilation: a serving-shaped
    // heads x layers x steps sweep over a Sec.-4.2 head plan (per-layer
    // local windows + one shared routing spec). The cache turns repeated
    // compiles into hash lookups and must amortize >= 5x end to end.
    let (heads, layers, steps) = (8usize, 4usize, 4usize);
    let n = 512usize;
    let k = optimal_clusters(n);
    let routing = AttentionSpec::routing_balanced(n, k).unwrap();
    let plan: Vec<AttentionSpec> = (0..layers)
        .flat_map(|l| {
            let routing = routing.clone();
            (0..heads).map(move |h| {
                if h % 2 == 0 {
                    AttentionSpec::local(8 * (l + 1)).unwrap()
                } else {
                    routing.clone()
                }
            })
        })
        .collect();
    let mut cache = PatternCache::new();
    let mut cached_nnz = 0u64;
    let cached = time_fn(1, 5, || {
        cache.clear();
        cached_nnz = 0;
        for _ in 0..steps {
            for spec in &plan {
                cached_nnz += cache.get_or_compile(spec, n).nnz() as u64;
            }
        }
    });
    let mut fresh_nnz = 0u64;
    let fresh = time_fn(1, 5, || {
        fresh_nnz = 0;
        for _ in 0..steps {
            for spec in &plan {
                fresh_nnz += std::hint::black_box(spec.compile(n)).nnz() as u64;
            }
        }
    });
    assert_eq!(cached_nnz, fresh_nnz, "cached and fresh compiles must count the same sets");
    let stats = cache.stats();
    let cache_speedup = fresh.mean / cached.mean;
    println!(
        "\ncached vs uncached compile over {} lookups ({} distinct specs, {:.1}% hits): \
         {:.3} ms vs {:.3} ms ({cache_speedup:.1}x)",
        stats.lookups(),
        cache.len(),
        stats.hit_rate() * 100.0,
        cached.mean * 1e3,
        fresh.mean * 1e3
    );
    assert!(
        cache_speedup >= 5.0,
        "cached multi-head compilation must be >= 5x over uncached (got {cache_speedup:.1}x)"
    );

    // cross-request batching: B = 8 sequences with (mildly different)
    // mixed local+routing patterns, one nnz-balanced worker sweep vs B
    // independent single-thread kernel calls.
    let b = 8usize;
    let n = 1024usize;
    let k = optimal_clusters(n);
    // 0 = unknown: available_parallelism() can fail in restricted
    // containers, and an unknown host must not arm the >= 2x pin below
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(0);
    let workers = cores.clamp(2, 8);
    let patterns: Vec<Arc<CompiledPattern>> = (0..b)
        .map(|s| {
            let spec = AttentionSpec::union(vec![
                AttentionSpec::local(64).unwrap(),
                AttentionSpec::routing_balanced(n, k + s % 3).unwrap(),
            ])
            .unwrap();
            Arc::new(spec.compile(n))
        })
        .collect();
    let mut rng = Rng::new(23);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..b * n * d).map(|_| rng.normal() as f32).collect()
    };
    let q = mk(&mut rng);
    let kv = mk(&mut rng);
    let v = mk(&mut rng);
    let batch = BatchedAttention::new(patterns.clone(), workers).unwrap();

    // row-for-row agreement first: batched must be bit-identical
    let batched_out = batch.attention(&q, &kv, &v, d).unwrap();
    let mut sequential_out = Vec::with_capacity(b * n * d);
    for (s, p) in patterns.iter().enumerate() {
        let lo = s * n * d;
        let hi = lo + n * d;
        sequential_out
            .extend(sparse_attention(&q[lo..hi], &kv[lo..hi], &v[lo..hi], d, p).unwrap());
    }
    assert_outputs_match(&sequential_out, &batched_out, Exactness::Bitwise, "batched vs sequential")
        .unwrap();

    let batched = time_fn(1, 3, || {
        std::hint::black_box(batch.attention(&q, &kv, &v, d).unwrap());
    });
    let sequential = time_fn(1, 3, || {
        for (s, p) in patterns.iter().enumerate() {
            let lo = s * n * d;
            let hi = lo + n * d;
            std::hint::black_box(
                sparse_attention(&q[lo..hi], &kv[lo..hi], &v[lo..hi], d, p).unwrap(),
            );
        }
    });
    let batch_speedup = sequential.mean / batched.mean;
    println!(
        "\nbatched vs sequential attention at B={b}, n={n}, d={d} ({workers} workers): \
         {:.3} ms vs {:.3} ms ({batch_speedup:.1}x)",
        batched.mean * 1e3,
        sequential.mean * 1e3
    );
    if cores >= 4 {
        assert!(
            batch_speedup >= 2.0,
            "batched sweep must be >= 2x over sequential at B = {b} (got {batch_speedup:.1}x)"
        );
    } else {
        // a 2-core host caps the theoretical speedup at exactly 2.0x, so
        // the hard pin would fail on correct code; report instead
        println!(
            "({} cores: >= 2x pin skipped, needs >= 4 cores for headroom)",
            if cores == 0 { "unknown".to_string() } else { cores.to_string() }
        );
    }

    // resident pool vs scoped spawn-per-call: a decode-shaped loop of 64
    // small batched steps (B = 8, n = 64), where the kernel work per call
    // is small enough that the scoped path's (workers - 1) thread spawns
    // per call are the dominant overhead — exactly the residual per-step
    // cost the pool exists to amortize.
    let b = 8usize;
    let n = 64usize;
    let d = 32usize;
    let steps = 64usize;
    let k = optimal_clusters(n);
    let patterns: Vec<Arc<CompiledPattern>> = (0..b)
        .map(|s| {
            let spec = AttentionSpec::union(vec![
                AttentionSpec::local(8).unwrap(),
                AttentionSpec::routing_balanced(n, (k + s % 3).max(1)).unwrap(),
            ])
            .unwrap();
            Arc::new(spec.compile(n))
        })
        .collect();
    let mut rng = Rng::new(29);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..b * n * d).map(|_| rng.normal() as f32).collect()
    };
    let q = mk(&mut rng);
    let kv = mk(&mut rng);
    let v = mk(&mut rng);
    let batch = BatchedAttention::new(patterns, workers).unwrap();
    let pool = WorkerPool::global();

    // row-for-row equality across all three execution paths first
    let inline_out = batch.attention_with(&q, &kv, &v, d, Execution::Inline).unwrap();
    let pool_out = batch.attention_with(&q, &kv, &v, d, Execution::Pool(pool)).unwrap();
    let scoped_out = batch.attention_with(&q, &kv, &v, d, Execution::Scoped).unwrap();
    assert_outputs_match(&inline_out, &pool_out, Exactness::Bitwise, "pool vs inline").unwrap();
    assert_outputs_match(&inline_out, &scoped_out, Exactness::Bitwise, "scoped vs inline").unwrap();

    let pooled = time_fn(1, 3, || {
        for _ in 0..steps {
            std::hint::black_box(
                batch.attention_with(&q, &kv, &v, d, Execution::Pool(pool)).unwrap(),
            );
        }
    });
    let scoped = time_fn(1, 3, || {
        for _ in 0..steps {
            std::hint::black_box(
                batch.attention_with(&q, &kv, &v, d, Execution::Scoped).unwrap(),
            );
        }
    });
    let rows = (steps * b * n) as f64;
    let pool_speedup = scoped.mean / pooled.mean;
    println!(
        "\npool vs scoped-spawn at B={b}, n={n}, d={d}, steps={steps} ({workers} workers): \
         {:.3} ms vs {:.3} ms ({:.3e} vs {:.3e} rows/sec, {pool_speedup:.2}x)",
        pooled.mean * 1e3,
        scoped.mean * 1e3,
        rows / pooled.mean,
        rows / scoped.mean
    );
    if cores >= 4 {
        assert!(
            pool_speedup >= 1.3,
            "resident pool must be >= 1.3x over spawn-per-call at steps = {steps} \
             (got {pool_speedup:.2}x)"
        );
    } else {
        println!(
            "({} cores: >= 1.3x pool pin skipped, needs >= 4 cores for headroom)",
            if cores == 0 { "unknown".to_string() } else { cores.to_string() }
        );
    }
    // blocked host backend vs the scalar reference kernel: single-thread,
    // same f64 math in the same order (bit-identical), but the blocked
    // kernel's 4-wide key tiles keep independent accumulator chains in
    // flight where the reference fold stalls on one — pure ILP, so the
    // pin holds regardless of core count.
    let n = 2048usize;
    let d = 64usize;
    let k = optimal_clusters(n);
    let spec = AttentionSpec::union(vec![
        AttentionSpec::local(256).unwrap(),
        AttentionSpec::routing_balanced(n, k).unwrap(),
    ])
    .unwrap();
    let pattern = spec.compile(n);
    let mut rng = Rng::new(31);
    let mk1 = |rng: &mut Rng| -> Vec<f32> { (0..n * d).map(|_| rng.normal() as f32).collect() };
    let q = mk1(&mut rng);
    let kv = mk1(&mut rng);
    let v = mk1(&mut rng);
    let ref_out = Reference.attention(&q, &kv, &v, d, &pattern).unwrap();
    let blk_out = Blocked.attention(&q, &kv, &v, d, &pattern).unwrap();
    assert_eq!(
        Blocked.exactness(),
        Exactness::Bitwise,
        "Blocked keeps the reference summation order and must declare bitwise"
    );
    assert_outputs_match(&ref_out, &blk_out, Blocked.exactness(), "blocked vs reference").unwrap();
    let reference = time_fn(1, 3, || {
        std::hint::black_box(Reference.attention(&q, &kv, &v, d, &pattern).unwrap());
    });
    let blocked = time_fn(1, 3, || {
        std::hint::black_box(Blocked.attention(&q, &kv, &v, d, &pattern).unwrap());
    });
    let backend_speedup = reference.mean / blocked.mean;
    println!(
        "\nblocked vs reference backend at n={n}, d={d} (nnz={}): \
         {:.3} ms vs {:.3} ms ({backend_speedup:.2}x)",
        pattern.nnz(),
        blocked.mean * 1e3,
        reference.mean * 1e3
    );
    assert!(
        backend_speedup >= 1.5,
        "blocked backend must be >= 1.5x over the reference kernel (got {backend_speedup:.2}x)"
    );

    // simd fast-math backend vs the scalar reference kernel over the same
    // n = 2048, d = 64 problem: the lane-widened f32 kernel abandons the
    // reference's f64 accumulation order, so it is held to exactly its
    // *declared* ulps budget — never bitwise, never a silently wider
    // tolerance — and must buy >= 3x for that trade (single-thread, so
    // no core gate).
    let simd_exactness = Simd.exactness();
    assert!(
        matches!(simd_exactness, Exactness::Ulps(_)),
        "the fast-math tier must declare a finite ulps budget, got {simd_exactness}"
    );
    let simd_out = Simd.attention(&q, &kv, &v, d, &pattern).unwrap();
    assert_outputs_match(&ref_out, &simd_out, simd_exactness, "simd vs reference").unwrap();
    let simd = time_fn(1, 3, || {
        std::hint::black_box(Simd.attention(&q, &kv, &v, d, &pattern).unwrap());
    });
    let simd_speedup = reference.mean / simd.mean;
    println!(
        "\nsimd vs reference backend at n={n}, d={d} ({simd_exactness}): \
         {:.3} ms vs {:.3} ms ({simd_speedup:.2}x)",
        simd.mean * 1e3,
        reference.mean * 1e3
    );
    assert!(
        simd_speedup >= 3.0,
        "simd backend must be >= 3x over the reference kernel (got {simd_speedup:.2}x)"
    );

    // incremental spec regeneration: a one-vector online k-means step
    // touches exactly the clusters it assigned to, so the member cache
    // must re-rank only those lists and still emit the from-scratch spec.
    let n = 1024usize;
    let d = 64usize;
    let k = optimal_clusters(n);
    let mut session = RoutingSession::new(1, 1, k, d, 0.5, 41).expect("valid session shape");
    let mut members = MemberCache::new();
    let mut rng = Rng::new(43);
    let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let w = n / k;
    // prime the cache, then a sparse step: one new token's vector
    session.routing_spec_cached(0, 0, &mut members, &xs, n, w);
    let upd = session.update(0, 0, &xs[0..d], 1);
    let touched = upd.delta.counts.iter().filter(|&&c| c > 0).count();
    assert_eq!(touched, 1, "a single finite vector assigns to exactly one cluster");
    let before = members.stats();
    let inc_spec = session.routing_spec_cached(0, 0, &mut members, &xs, n, w);
    let after = members.stats();
    assert_eq!(
        after.regenerated - before.regenerated,
        touched as u64,
        "incremental regeneration must recompute only the delta-touched clusters"
    );
    assert_eq!(after.reused - before.reused, (k - touched) as u64);
    assert_eq!(
        inc_spec,
        session.routing_spec(0, 0, &xs, n, w),
        "incremental spec must equal the from-scratch spec"
    );
    // like-for-like timing on the now-settled state: repeated cached
    // regenerations (all lists reused) vs repeated from-scratch builds,
    // both warmed, both over identical centroids and vectors
    let cached_regen = time_fn(1, 3, || {
        std::hint::black_box(session.routing_spec_cached(0, 0, &mut members, &xs, n, w));
    });
    let full = time_fn(1, 3, || {
        std::hint::black_box(session.routing_spec(0, 0, &xs, n, w));
    });
    println!(
        "\ncached vs from-scratch spec regeneration at n={n}, k={k}: {:.3} ms vs {:.3} ms \
         (sparse update re-ranked {touched}/{k} clusters)",
        cached_regen.mean * 1e3,
        full.mean * 1e3
    );

    // continuous-batching serve loop: an open-loop seeded workload through
    // the full admit -> decode -> retire -> GC arc.  Liveness pins only —
    // every request resolves exactly once, retirement GC drains every
    // routed compile, and the whole run replays bit-deterministically.
    // No wall-clock pin: serve latency is a trajectory (BENCH_serve.json),
    // not a floor.
    let opts = ServeOptions {
        n: 128,
        d: 32,
        layers: 2,
        heads: 4,
        window: 16,
        clusters: 8,
        top_w: 16,
        workers,
        capacity: 4,
        route_every: 4,
        arrivals: ArrivalConfig {
            requests: 32,
            rate: 1.5,
            contents: 8,
            zipf_s: 1.1,
            work: (2, 8),
            slack: (4, 32),
            seed: 47,
        },
        seed: 47,
        ..ServeOptions::default()
    };
    let summary = run_serve(&opts, &Blocked).expect("serve loop must complete");
    let s = summary.stats;
    assert_eq!(
        s.completed + s.rejected + s.shed,
        s.submitted,
        "every submitted request must reach exactly one terminal state"
    );
    assert_eq!(s.submitted, 32);
    assert!(s.completed >= 1, "a sane open-loop config completes requests");
    assert_eq!(
        summary.live_patterns_after_gc, 1,
        "after drain only the pinned static pattern survives retirement GC"
    );
    assert_eq!(summary.step_us.count(), s.steps - s.idle_steps);
    let replay = run_serve(&opts, &Blocked).expect("serve loop must complete");
    assert_eq!(replay.stats, s, "serve schedule must be seed-deterministic");
    assert_eq!(replay.outcomes, summary.outcomes);
    assert_eq!(replay.macs, summary.macs);
    println!(
        "\nserve loop at n={}, capacity={}, {} requests ({} completed / {} rejected / {} shed, \
         peak batch {}): p50/p99 step {:.0}/{:.0} µs, {:.3e} rows/sec",
        opts.n,
        opts.capacity,
        s.submitted,
        s.completed,
        s.rejected,
        s.shed,
        s.peak_active,
        summary.step_us.p50(),
        summary.step_us.p99(),
        summary.rows_per_sec()
    );

    // memory-bounded banded compilation: `ChunkedPattern` streams 512-row
    // bands against a 4 MiB shared budget.  Outputs must be bit-identical
    // to the unbudgeted monolithic path, and peak resident pattern bytes
    // must be bounded by budget + one band — so as n grows 8x (and the
    // monolithic CSR footprint grows with it), peak grows <= 4x.
    let d = 8usize;
    let band_rows = 512usize;
    let budget_bytes = 1usize << 22; // 4 MiB
    println!(
        "\nmemory-bounded banded compilation (band_rows={band_rows}, budget={budget_bytes} B):"
    );
    let mut table = Table::new(&[
        "spec", "n", "monolithic B", "peak B", "peak/mono", "band compiles", "evicted B",
    ]);
    for family in ["local", "routing"] {
        let mut peaks: Vec<(usize, usize)> = Vec::new();
        for &n in &[8192usize, 65536] {
            let spec = match family {
                "local" => AttentionSpec::local(128).unwrap(),
                _ => AttentionSpec::routing_balanced(n, optimal_clusters(n)).unwrap(),
            };
            let pattern = spec.compile(n);
            let mono_bytes = pattern.heap_bytes();
            let mut rng = Rng::new(53);
            let mk = |rng: &mut Rng| -> Vec<f32> {
                (0..n * d).map(|_| rng.normal() as f32).collect()
            };
            let q = mk(&mut rng);
            let kv = mk(&mut rng);
            let v = mk(&mut rng);
            let mono_out = Reference.attention(&q, &kv, &v, d, &pattern).unwrap();

            let budget = MemoryBudget::bytes(budget_bytes);
            let mut chunked = ChunkedPattern::new(spec.clone(), n, band_rows, budget.clone());
            let banded_out = chunked.attention_backend(&q, &kv, &v, d, &Reference).unwrap();
            assert_outputs_match(
                &mono_out,
                &banded_out,
                Exactness::Bitwise,
                &format!("budgeted banded vs monolithic ({family}, n={n})"),
            )
            .unwrap();
            assert_eq!(chunked.nnz(), pattern.nnz(), "band nnz must sum to the monolithic nnz");

            let max_band = (0..n.div_ceil(band_rows))
                .map(|b| {
                    spec.compile_band(n, b * band_rows..((b + 1) * band_rows).min(n)).heap_bytes()
                })
                .max()
                .unwrap_or(0);
            let peak = budget.peak();
            assert!(
                peak <= budget_bytes + max_band,
                "peak resident bytes must never exceed budget + one in-flight band \
                 ({family}, n={n}: peak {peak}, budget {budget_bytes}, max band {max_band})"
            );
            if mono_bytes > budget_bytes {
                assert!(
                    chunked.bytes_evicted() > 0,
                    "a {mono_bytes}-byte {family} pattern must spill under a \
                     {budget_bytes}-byte budget (n={n})"
                );
            }
            table.row(&[
                family.to_string(),
                n.to_string(),
                mono_bytes.to_string(),
                peak.to_string(),
                format!("{:.3}", peak as f64 / mono_bytes as f64),
                chunked.band_compiles().to_string(),
                chunked.bytes_evicted().to_string(),
            ]);
            peaks.push((n, peak));
        }
        let (_, peak_small) = peaks[0];
        let (_, peak_big) = peaks[1];
        assert!(
            peak_big <= peak_small * 4,
            "peak resident bytes must grow sublinearly: n grew 8x but {family} peak went \
             {peak_small} -> {peak_big} (> 4x)"
        );
    }
    table.print();

    // multi-process coordination overhead: the same serve workload once
    // in-process and once through a 2-worker Coordinator over the
    // in-memory SimTransport (protocol + state-replication cost without
    // OS pipe noise).  Informational timing only — the pin is
    // bit-identity (output digest, outcome ledger) and a conserved grant
    // ledger; wall-clock overhead is a trajectory concern
    // (BENCH_serve.json), not a floor.
    let coord_cfg = CoordinatorConfig {
        n: opts.n,
        d: opts.d,
        layers: opts.layers,
        heads: opts.heads,
        window: opts.window,
        clusters: opts.clusters,
        top_w: opts.top_w,
        capacity: opts.capacity,
        seed: opts.seed,
        backend: "blocked".to_string(),
        max_regrants: 8,
        spec_family: opts.spec_family,
    };
    let mut coord = Coordinator::new(coord_cfg, SimTransport::new())
        .expect("valid coordinator config");
    coord.spawn_worker().expect("sim spawn");
    coord.spawn_worker().expect("sim spawn");
    let coordinated =
        run_serve_coordinated(&opts, &mut coord).expect("coordinated serve must complete");
    coord.shutdown();
    assert_eq!(
        coordinated.output_digest, summary.output_digest,
        "coordinated serve must be bit-identical to in-process (digest)"
    );
    assert_eq!(coordinated.outcomes, summary.outcomes);
    assert_eq!(coordinated.stats, summary.stats);
    let co = coordinated.coord.expect("coordinated run reports its ledger");
    assert!(co.conserved(), "grant ledger must conserve: {co:?}");
    assert_eq!(co.crashes, 0, "no faults injected, so no crashes");
    println!(
        "\ncoordinated serve (2 sim workers) vs in-process: {:.3} ms vs {:.3} ms attention \
         wall-clock ({} worker rows / {} inline, {} grants, digest {:016x})",
        coordinated.elapsed_sec * 1e3,
        summary.elapsed_sec * 1e3,
        co.worker_rows,
        co.inline_rows,
        co.grants,
        coordinated.output_digest
    );

    // quality vs nnz across the content-based spec families: a skewed
    // token layout (70% of tokens collapse onto one dominant direction)
    // drives token-choice routing, expert-choice, and the score-threshold
    // family, each tuned to roughly the same nnz via its own knob
    // (top-w / capacity / floor).  mean_pattern_jsd against full causal
    // attention is the support-divergence-per-nnz proxy; the pin is load
    // balance — expert-choice bounds every row by its capacity, so a
    // 2-way nnz-balanced shard split of a B=4 batch must come out no
    // more imbalanced than routing's on the same layout.
    let n = 256usize;
    let dim = 16usize;
    let k = 8usize;
    let w = 32usize;
    let max_knob = 48usize; // caps the balancing granularity (max row nnz)
    let mut rng = Rng::new(59);
    let dominant: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let mut xs = Vec::with_capacity(n * dim);
    for i in 0..n {
        if i % 10 < 7 {
            xs.extend(dominant.iter().map(|&v| v + 0.05 * rng.normal() as f32));
        } else {
            xs.extend((0..dim).map(|_| rng.normal() as f32));
        }
    }
    let mut km = SphericalKMeans::new(k, dim, 0.5, 61);
    for _ in 0..4 {
        km.update(&xs, n);
    }
    let full = Arc::new(AttentionSpec::full().compile(n));
    let routing = Arc::new(km.routing_spec(&xs, n, w).compile(n));
    let target = routing.nnz();
    let expert = (1..=max_knob)
        .map(|cap| Arc::new(km.expert_choice_spec(&xs, n, cap).compile(n)))
        .min_by_key(|p| p.nnz().abs_diff(target))
        .unwrap();
    let mut scores = vec![f32::NEG_INFINITY; n * n];
    for i in 0..n {
        for j in 0..=i {
            scores[i * n + j] = dot(&xs[i * dim..(i + 1) * dim], &xs[j * dim..(j + 1) * dim]);
        }
    }
    // an unreachable cut turns the floor into a per-row top-k by content
    // score — the threshold family's nnz knob
    let threshold = (1..=max_knob)
        .map(|floor| {
            Arc::new(
                AttentionSpec::threshold_from_scores(&scores, n, f32::MAX, floor)
                    .unwrap()
                    .compile(n),
            )
        })
        .min_by_key(|p| p.nnz().abs_diff(target))
        .unwrap();

    let shard_split = |p: &Arc<CompiledPattern>| -> (usize, usize) {
        let batch =
            BatchedAttention::new(vec![Arc::clone(p); 4], 2).expect("2-way split of a B=4 batch");
        let nnz = batch.worker_nnz();
        (*nnz.iter().max().unwrap(), *nnz.iter().min().unwrap())
    };
    println!("\nquality vs nnz at matched budgets (skewed layout, n={n}, k={k}):");
    let mut table = Table::new(&[
        "family", "nnz", "density", "jsd vs full", "max shard nnz", "min shard nnz",
        "max cluster nnz",
    ]);
    let mut imbalance = Vec::new();
    for (name, p) in
        [("routing", &routing), ("expert-choice", &expert), ("threshold", &threshold)]
    {
        let (max_s, min_s) = shard_split(p);
        imbalance.push(max_s as f64 / min_s.max(1) as f64);
        table.row(&[
            name.to_string(),
            p.nnz().to_string(),
            format!("{:.4}", p.density()),
            format!("{:.4}", analysis::mean_pattern_jsd(p, &full)),
            max_s.to_string(),
            min_s.to_string(),
            p.max_cluster_nnz().to_string(),
        ]);
        assert!(
            p.nnz().abs_diff(target) * 10 <= target * 3,
            "{name} nnz {} must land within 30% of routing's {target}",
            p.nnz()
        );
    }
    table.print();
    let (routing_imb, expert_imb) = (imbalance[0], imbalance[1]);
    println!(
        "\nshard imbalance (max/min nnz, 2-way balanced split of a B=4 batch): \
         routing {routing_imb:.4}, expert-choice {expert_imb:.4}"
    );
    assert!(
        expert_imb <= routing_imb + 0.15,
        "expert-choice's capacity bound must keep the shard split no more imbalanced \
         than routing's on a skewed layout ({expert_imb:.4} vs {routing_imb:.4})"
    );

    println!("\nbench_complexity OK");
}

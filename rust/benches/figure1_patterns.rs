//! Figure 1 — 2-D attention schemes: local vs strided vs routing.
//!
//! Renders the three sparsity patterns of the paper's Figure 1 (rows =
//! outputs, columns = inputs; colors/letters = cluster membership for
//! routing) and writes CSVs for external plotting.  The routing pattern
//! is produced by actually clustering content vectors with the online
//! spherical k-means substrate — not hand-drawn.

use routing_transformer::attention::Pattern;
use routing_transformer::kmeans::SphericalKMeans;
use routing_transformer::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = std::env::var("RTX_FIG1_N").ok().and_then(|s| s.parse().ok()).unwrap_or(48);
    let window = 6;
    let stride = 6;
    let k = 6;
    println!("Figure 1 — attention schemes over n={n} (rows=outputs, cols=inputs)\n");

    let local = Pattern::local(n, window);
    println!("(a) local attention, window {window}:");
    println!("{}", local.render_ascii());

    let strided = Pattern::strided(n, stride);
    println!("(b) strided attention, stride {stride}:");
    println!("{}", strided.render_ascii());

    // content-clustered routing: 6 groups of correlated vectors shuffled
    // over time, clustered by online spherical k-means
    let dim = 12;
    let mut rng = Rng::new(1);
    let mut xs = vec![0f32; n * dim];
    for i in 0..n {
        let c = (i * 7 + i / 3) % k; // interleaved group structure
        for d in 0..dim {
            let base = if d == c { 3.0 } else { 0.0 };
            xs[i * dim + d] = base + rng.normal() as f32 * 0.4;
        }
    }
    let mut km = SphericalKMeans::new(k, dim, 0.3, 2);
    for _ in 0..40 {
        km.update(&xs, n);
    }
    let routing = Pattern::routing_from_vectors(n, &xs, &km, n / k);
    println!("(c) routing attention, k={k} clusters (letter = cluster):");
    println!("{}", routing.render_ascii());

    println!(
        "densities: local {:.3}, strided {:.3}, routing {:.3} (full = 1.000)",
        local.density(),
        strided.density(),
        routing.density()
    );

    let out = std::path::PathBuf::from("runs/figure1");
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("local.csv"), local.render_csv())?;
    std::fs::write(out.join("strided.csv"), strided.render_csv())?;
    std::fs::write(out.join("routing.csv"), routing.render_csv())?;
    println!("CSV patterns written to runs/figure1/");

    // figure-level shape checks
    assert!(local.is_causal() && strided.is_causal() && routing.is_causal());
    assert!(routing.density() < 1.0);
    println!("figure1 OK");
    Ok(())
}

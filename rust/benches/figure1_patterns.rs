//! Figure 1 — 2-D attention schemes: local vs strided vs routing.
//!
//! Renders the sparsity patterns of the paper's Figure 1 (rows = outputs,
//! columns = inputs; letters = cluster membership for routing) through the
//! spec→compile pipeline, plus the mixed local+routing head plan of
//! Sec. 4.2 as a `Union` spec, and writes CSVs for external plotting.
//! The routing pattern is produced by actually clustering content vectors
//! with the online spherical k-means substrate — not hand-drawn.

use routing_transformer::attention::AttentionSpec;
use routing_transformer::kmeans::SphericalKMeans;
use routing_transformer::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = std::env::var("RTX_FIG1_N").ok().and_then(|s| s.parse().ok()).unwrap_or(48);
    let window = 6;
    let stride = 6;
    let k = 6;
    println!("Figure 1 — attention schemes over n={n} (rows=outputs, cols=inputs)\n");

    let local = AttentionSpec::local(window)?.compile(n);
    println!("(a) local attention, window {window}:");
    println!("{}", local.render_ascii());

    let strided = AttentionSpec::strided(stride)?.compile(n);
    println!("(b) strided attention, stride {stride}:");
    println!("{}", strided.render_ascii());

    // content-clustered routing: 6 groups of correlated vectors shuffled
    // over time, clustered by online spherical k-means
    let dim = 12;
    let mut rng = Rng::new(1);
    let mut xs = vec![0f32; n * dim];
    for i in 0..n {
        let c = (i * 7 + i / 3) % k; // interleaved group structure
        for d in 0..dim {
            let base = if d == c { 3.0 } else { 0.0 };
            xs[i * dim + d] = base + rng.normal() as f32 * 0.4;
        }
    }
    let mut km = SphericalKMeans::new(k, dim, 0.3, 2);
    for _ in 0..40 {
        km.update(&xs, n);
    }
    let routing_spec = km.routing_spec(&xs, n, n / k);
    let routing = routing_spec.compile(n);
    println!("(c) routing attention, k={k} clusters (letter = cluster):");
    println!("{}", routing.render_ascii());

    // the paper's best configurations mix head types (Sec. 4.2)
    let mixed_spec =
        AttentionSpec::union(vec![AttentionSpec::local(window)?, routing_spec])?;
    let mixed = mixed_spec.compile(n);
    println!("(d) mixed local+routing head plan (union spec):");
    println!("{}", mixed.render_ascii());

    println!(
        "densities: local {:.3}, strided {:.3}, routing {:.3}, mixed {:.3} (full = 1.000)",
        local.density(),
        strided.density(),
        routing.density(),
        mixed.density()
    );

    let out = std::path::PathBuf::from("runs/figure1");
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("local.csv"), local.render_csv())?;
    std::fs::write(out.join("strided.csv"), strided.render_csv())?;
    std::fs::write(out.join("routing.csv"), routing.render_csv())?;
    std::fs::write(out.join("mixed.csv"), mixed.render_csv())?;
    println!("CSV patterns written to runs/figure1/");

    // figure-level shape checks
    assert!(local.is_causal() && strided.is_causal() && routing.is_causal());
    assert!(mixed.is_causal() && mixed.rows_sorted());
    assert!(routing.density() < 1.0);
    // the union admits exactly the keys of either part, never fewer/more
    assert!(mixed.nnz() >= local.nnz().max(routing.nnz()));
    assert!(mixed.nnz() <= local.nnz() + routing.nnz());
    println!("figure1 OK");
    Ok(())
}

//! Micro-benchmarks of the L3 hot-path substrates (the §Perf L3 profile):
//! data generation, batch packing, k-means routing, sampler math, JSON
//! manifest parsing, JSD.  These are the host-side costs that must stay
//! negligible next to the PJRT execute call.

use routing_transformer::analysis::jsd;
use routing_transformer::data;
use routing_transformer::kmeans::SphericalKMeans;
use routing_transformer::sampler::{nucleus_probs, SamplerConfig};
use routing_transformer::util::json::Json;
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::{time_fn, Table};

fn main() -> anyhow::Result<()> {
    println!("L3 hot-path micro-benchmarks\n");
    let mut table = Table::new(&["bench", "mean", "per-unit"]);

    // needle data generation: tokens/sec
    {
        let mut src = data::source_by_name("needle", 512, 256, 32, 1)?;
        let mut buf = vec![0i32; 8 * 256];
        let stats = time_fn(2, 20, || src.fill(&mut buf));
        table.row(&[
            "needle gen (2048 tok)".into(),
            format!("{:.1} µs", stats.mean * 1e6),
            format!("{:.1} Mtok/s", buf.len() as f64 / stats.mean / 1e6),
        ]);
    }

    // image generation
    {
        let mut src = data::source_by_name("images", 256, 256, 32, 1)?;
        let mut buf = vec![0i32; 4 * 256];
        let stats = time_fn(2, 20, || src.fill(&mut buf));
        table.row(&[
            "image gen (1024 tok)".into(),
            format!("{:.1} µs", stats.mean * 1e6),
            format!("{:.1} Mtok/s", buf.len() as f64 / stats.mean / 1e6),
        ]);
    }

    // k-means assignment (routing decision cost per token)
    {
        let d = 64;
        let k = 32;
        let km = SphericalKMeans::new(k, d, 0.5, 1);
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..1024 * d).map(|_| rng.normal() as f32).collect();
        let stats = time_fn(2, 20, || {
            let mut acc = 0usize;
            for i in 0..1024 {
                acc += km.assign(&xs[i * d..(i + 1) * d]);
            }
            std::hint::black_box(acc);
        });
        table.row(&[
            "kmeans assign (1024 x k=32)".into(),
            format!("{:.1} µs", stats.mean * 1e6),
            format!("{:.0} ns/tok", stats.mean * 1e9 / 1024.0),
        ]);
    }

    // nucleus sampling over a 1024-way vocab
    {
        let mut rng = Rng::new(3);
        let logits: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let cfg = SamplerConfig::default();
        let stats = time_fn(2, 50, || {
            std::hint::black_box(nucleus_probs(&logits, cfg));
        });
        table.row(&[
            "nucleus probs (V=1024)".into(),
            format!("{:.1} µs", stats.mean * 1e6),
            String::new(),
        ]);
    }

    // JSON manifest parse
    {
        let text = std::fs::read_to_string("artifacts/quickstart/manifest.json")
            .unwrap_or_else(|_| r#"{"variant":"x","params":[]}"#.to_string());
        let stats = time_fn(2, 50, || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
        table.row(&[
            format!("manifest parse ({} B)", text.len()),
            format!("{:.1} µs", stats.mean * 1e6),
            String::new(),
        ]);
    }

    // JSD over T=256 rows
    {
        let t = 256;
        let mut rng = Rng::new(4);
        let mk = |rng: &mut Rng| -> Vec<f64> {
            let mut v: Vec<f64> = (0..t).map(|_| rng.f64()).collect();
            let s: f64 = v.iter().sum();
            v.iter_mut().for_each(|x| *x /= s);
            v
        };
        let p = mk(&mut rng);
        let q = mk(&mut rng);
        let stats = time_fn(2, 100, || {
            std::hint::black_box(jsd(&p, &q));
        });
        table.row(&[
            "jsd (T=256)".into(),
            format!("{:.2} µs", stats.mean * 1e6),
            String::new(),
        ]);
    }

    table.print();
    println!("\nmicrobench OK");
    Ok(())
}

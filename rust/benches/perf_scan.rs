//! §Perf probe — the L3 hot-path optimization experiment.
//!
//! The published `xla` crate returns multi-result executions as ONE
//! tuple-shaped buffer, so train state must round-trip through host
//! literals every execute.  The optimization (DESIGN.md §Perf) is the
//! scanned train-block artifact: S optimizer steps fused into one
//! executable, amortizing the host round-trip + dispatch 1/S.
//!
//! This bench measures the before (single-step artifact driven S times)
//! vs after (scanned block) on the quickstart variant, plus the host-side
//! cost breakdown (literal building vs execute).

use std::time::Instant;

use routing_transformer::bench::artifacts_root;
use routing_transformer::coordinator::train_batcher;
use routing_transformer::runtime::{
    execute_tuple, i32_literal, scalar_f32, scalar_i32, Artifacts, Runtime,
};
use routing_transformer::util::timing::Table;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let art = Artifacts::load(&root, "quickstart")?;
    let manifest = art.manifest.clone();
    let s = manifest.scan_steps;
    let reps = std::env::var("RTX_PERF_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);

    println!("§Perf — scan-block amortization (variant quickstart, S = {s})\n");

    let mut batcher = train_batcher(&manifest, "needle", 0)?;
    let block = batcher.next_block();
    let state = art.init_state()?;
    let p = state.params.len();

    // ---------------- single-step path (the "before") ----------------
    let exe1 = art.executable(&rt, "train_step")?;
    let tokens0 = i32_literal(
        &block.tokens[..manifest.batch * manifest.config.seq_len],
        &[manifest.batch, manifest.config.seq_len],
    )?;
    let step_lit = scalar_i32(0);
    let lr_lit = scalar_f32(1e-3);

    let run_single = |state_params: &Vec<xla::Literal>,
                      m: &Vec<xla::Literal>,
                      v: &Vec<xla::Literal>|
     -> anyhow::Result<(Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)> {
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * p + 3);
        inputs.extend(state_params.iter());
        inputs.extend(m.iter());
        inputs.extend(v.iter());
        inputs.push(&step_lit);
        inputs.push(&lr_lit);
        inputs.push(&tokens0);
        let mut outs = execute_tuple(&exe1, &inputs)?;
        outs.pop();
        let v2 = outs.split_off(2 * p);
        let m2 = outs.split_off(p);
        Ok((outs, m2, v2))
    };

    // warmup + measure S sequential single steps, `reps` times
    let (mut sp, mut sm, mut sv) = (state.params, state.m, state.v);
    (sp, sm, sv) = run_single(&sp, &sm, &sv)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        for _ in 0..s {
            (sp, sm, sv) = run_single(&sp, &sm, &sv)?;
        }
    }
    let single_per_step = t0.elapsed().as_secs_f64() / (reps * s) as f64;

    // ---------------- scanned block path (the "after") ----------------
    let exe_s = art.executable(&rt, "train_block")?;
    let state = art.init_state()?;
    let tok_blk = i32_literal(&block.tokens, &block.dims())?;
    let run_block = |sp: &Vec<xla::Literal>, sm: &Vec<xla::Literal>, sv: &Vec<xla::Literal>|
     -> anyhow::Result<(Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)> {
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * p + 3);
        inputs.extend(sp.iter());
        inputs.extend(sm.iter());
        inputs.extend(sv.iter());
        inputs.push(&step_lit);
        inputs.push(&lr_lit);
        inputs.push(&tok_blk);
        let mut outs = execute_tuple(&exe_s, &inputs)?;
        outs.pop();
        let v2 = outs.split_off(2 * p);
        let m2 = outs.split_off(p);
        Ok((outs, m2, v2))
    };
    let (mut bp, mut bm, mut bv) = (state.params, state.m, state.v);
    (bp, bm, bv) = run_block(&bp, &bm, &bv)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        (bp, bm, bv) = run_block(&bp, &bm, &bv)?;
    }
    let block_per_step = t0.elapsed().as_secs_f64() / (reps * s) as f64;

    // ---------------- host-side overhead breakdown -------------------
    // literal construction cost for one block's tokens
    let t0 = Instant::now();
    for _ in 0..100 {
        std::hint::black_box(i32_literal(&block.tokens, &block.dims())?);
    }
    let lit_build = t0.elapsed().as_secs_f64() / 100.0;

    let mut table = Table::new(&["path", "ms/step", "speedup"]);
    table.row(&["single-step artifact (before)".into(),
                format!("{:.2}", single_per_step * 1e3), "1.00x".into()]);
    table.row(&[format!("scanned block S={s} (after)"),
                format!("{:.2}", block_per_step * 1e3),
                format!("{:.2}x", single_per_step / block_per_step)]);
    table.print();
    println!("\ntoken literal build: {:.3} ms/block ({:.1}% of block step)",
             lit_build * 1e3, 100.0 * lit_build / (block_per_step * s as f64));
    println!("perf_scan OK");
    Ok(())
}

//! Table 1 — CIFAR-10 ablation grid, reproduced at reduction scale.
//!
//! Paper: 12-layer/8-head models on 32x32x3 rasters (T=3072), sweeping
//! routing heads {2,4,8} x routing layers {2,4,8,12} x window {512,1024},
//! plus Transformer (full), Local and Random controls; reports bits/dim
//! and steps/sec on TPUv3.
//!
//! Here: 2-layer/4-head models on 16x16 synthetic rasters (T=256),
//! sweeping routing heads {2,4} x routing layers {1,2} x window {32,64}
//! plus the same three controls, on CPU PJRT.  Shape claims that should
//! hold: (a) local is the fastest, full the slowest per step;
//! (b) adding a few routing heads/layers improves bits/dim over local;
//! (c) random routing is worse than learned routing.

use routing_transformer::bench::{
    artifacts_root, bench_eval_batches, bench_steps, header, train_and_eval,
};
use routing_transformer::runtime::Runtime;
use routing_transformer::util::timing::Table;

/// (variant, paper row it mirrors, paper bits/dim, paper steps/sec)
const ROWS: &[(&str, &str, f64, f64)] = &[
    ("image_full", "Transformer (full, w=3072)", 2.983, 5.608),
    ("image_local_w32", "Local Transformer (w=512)", 3.009, 9.023),
    ("image_local_w64", "Local Transformer (w=1024)", 3.009, 9.023),
    ("image_random_w32", "Random Transformer (4h/8l, w=512)", 3.076, 5.448),
    ("image_r2l1w32", "Routing 2h 2l w=512", 3.005, 7.968),
    ("image_r4l1w32", "Routing 4h 2l w=512", 2.986, 7.409),
    ("image_r2l2w32", "Routing 2h 4l w=512", 2.995, 7.379),
    ("image_r4l2w32", "Routing 4h 4l w=512", 2.975, 6.492),
    ("image_r2l1w64", "Routing 2h 2l w=1024", 2.975, 7.344),
    ("image_r4l1w64", "Routing 4h 2l w=1024", 2.950, 6.440),
    ("image_r2l2w64", "Routing 2h 4l w=1024", 2.990, 6.389),
    ("image_r4l2w64", "Routing 4h 4l w=1024", 2.958, 5.112),
];

fn main() -> anyhow::Result<()> {
    header(
        "Table 1 — CIFAR-10 ablations (synthetic 16x16 rasters, scaled grid)",
        "paper numbers: TPUv3 bits/dim + steps/sec at full scale; \
         measured: CPU PJRT at reproduction scale",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let steps = bench_steps();

    let mut table = Table::new(&[
        "variant", "mirrors paper row", "paper b/d", "meas b/d", "paper st/s", "meas st/s",
    ]);
    let mut results = Vec::new();
    for (variant, paper_row, paper_bits, paper_sps) in ROWS {
        let r = train_and_eval(&rt, &root, variant, "images", steps, bench_eval_batches())?;
        table.row(&[
            variant.to_string(),
            paper_row.to_string(),
            format!("{paper_bits:.3}"),
            format!("{:.3}", r.bits_per_dim()),
            format!("{paper_sps:.3}"),
            format!("{:.3}", r.steps_per_sec),
        ]);
        println!("  done {variant}: {:.3} bits/dim, {:.2} steps/s", r.bits_per_dim(), r.steps_per_sec);
        results.push((variant.to_string(), r));
    }
    println!();
    table.print();

    // shape checks
    let get = |name: &str| results.iter().find(|(v, _)| v == name).map(|(_, r)| r).unwrap();
    let local = get("image_local_w32");
    let full = get("image_full");
    let random = get("image_random_w32");
    let best_routing = results
        .iter()
        .filter(|(v, _)| v.starts_with("image_r") && !v.contains("random"))
        .map(|(_, r)| r.bits_per_dim())
        .fold(f64::INFINITY, f64::min);
    println!("\nshape checks:");
    println!(
        "  local faster than full:         {} ({:.2} vs {:.2} steps/s)",
        local.steps_per_sec > full.steps_per_sec, local.steps_per_sec, full.steps_per_sec
    );
    println!(
        "  best routing <= local bits/dim: {} ({:.3} vs {:.3})",
        best_routing <= local.bits_per_dim() + 0.02, best_routing, local.bits_per_dim()
    );
    println!(
        "  random worse than best routing: {} ({:.3} vs {:.3})",
        random.bits_per_dim() > best_routing, random.bits_per_dim(), best_routing
    );
    Ok(())
}

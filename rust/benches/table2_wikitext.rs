//! Table 2 — Wikitext-103 word-level LM comparison.
//!
//! Paper: Routing Transformer 15.8 ppl beats TransformerXL 18.3 and a
//! scaled-up Local Transformer 19.8 (10 layers/16 heads at full scale).
//!
//! Here: word-level *needle* corpus (long-range payload retrieval beyond
//! the local window — the mechanism Section 6.1 credits for the win),
//! 3-layer/8-head models.  Shape claims: routing < local perplexity, and
//! routing's copy-target NLL gap over local is larger (it can actually
//! retrieve the payload).

use routing_transformer::bench::{
    artifacts_root, bench_eval_batches, bench_steps, header, train_and_eval,
};
use routing_transformer::coordinator::{eval_batcher, Evaluator};
use routing_transformer::runtime::{Artifacts, Runtime};
use routing_transformer::util::timing::Table;

const ROWS: &[(&str, &str, f64)] = &[
    ("needle_local", "Local Transformer (16L/16H)", 19.8),
    ("needle_full", "(dense upper bound; cf. TXL 18.3)", 18.3),
    ("needle_routing", "Routing Transformer (10L/16H)", 15.8),
];

fn main() -> anyhow::Result<()> {
    header(
        "Table 2 — Wikitext-103 (word-level needle corpus stand-in)",
        "paper: test ppl at full scale; measured: held-out ppl at repro scale",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let steps = bench_steps();

    let mut table =
        Table::new(&["variant", "mirrors paper row", "paper ppl", "meas ppl", "copy-nll gap"]);
    let mut measured = Vec::new();
    for (variant, paper_row, paper_ppl) in ROWS {
        let r = train_and_eval(&rt, &root, variant, "needle", steps, bench_eval_batches())?;
        // retrieval metric: copy-target NLL minus overall NLL (negative =
        // the model exploits the long-range copy)
        let art = Artifacts::load(&root, variant)?;
        let evaluator = Evaluator::new(&rt, &art)?;
        // re-train quickly?  train_and_eval discarded state; reuse its
        // final numbers for ppl and recompute retrieval from a fresh
        // short train inside train_and_eval would double cost — instead
        // evaluate retrieval with the *initial* state as a baseline
        // demonstration and rely on the integration test for the trained
        // gap.  Here: report ppl only, plus init-state retrieval gap.
        let mut b = eval_batcher(&art.manifest, "needle", 5)?;
        let payload = 4.min(art.manifest.config.seq_len / 16).max(2);
        let state = art.init_state()?;
        let (copy, all) = evaluator.eval_retrieval(&state, &mut b, 2, payload)?;
        table.row(&[
            variant.to_string(),
            paper_row.to_string(),
            format!("{paper_ppl:.1}"),
            format!("{:.2}", r.ppl()),
            format!("{:+.3} (init)", copy - all),
        ]);
        println!("  done {variant}: ppl {:.2}", r.ppl());
        measured.push((variant.to_string(), r.ppl()));
    }
    println!();
    table.print();

    let get = |name: &str| measured.iter().find(|(v, _)| v == name).map(|&(_, p)| p).unwrap();
    println!("\nshape check: routing < local ppl: {} ({:.2} vs {:.2})",
             get("needle_routing") < get("needle_local"),
             get("needle_routing"), get("needle_local"));
    Ok(())
}

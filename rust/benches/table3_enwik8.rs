//! Table 3 — enwik-8 byte-level LM.
//!
//! Paper: Routing Transformer 0.99 bpb (12L/8H) vs Local 1.10 (24L/8H),
//! TXL 0.99, Sparse Transformer 0.99, Adaptive 0.98 — routing matches
//! the best sparse models with *half the layers*.
//!
//! Here: 3-layer/8-head byte models on the synthetic entity-recurrence
//! text corpus.  Shape claim: routing <= local bits/byte.

use routing_transformer::bench::{
    artifacts_root, bench_eval_batches, bench_steps, header, train_and_eval,
};
use routing_transformer::runtime::Runtime;
use routing_transformer::util::timing::Table;

const ROWS: &[(&str, &str, f64)] = &[
    ("byte_local", "Local Transformer (24L/8H)", 1.10),
    ("byte_routing", "Routing Transformer (12L/8H)", 0.99),
];

fn main() -> anyhow::Result<()> {
    header(
        "Table 3 — enwik-8 (synthetic byte corpus stand-in)",
        "paper: bits/byte at full scale; measured: held-out bits/byte at repro scale",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    let mut table = Table::new(&["variant", "mirrors paper row", "paper bpb", "meas bpb", "steps/s"]);
    let mut measured = Vec::new();
    for (variant, paper_row, paper_bpb) in ROWS {
        let r = train_and_eval(&rt, &root, variant, "bytes", bench_steps(), bench_eval_batches())?;
        table.row(&[
            variant.to_string(),
            paper_row.to_string(),
            format!("{paper_bpb:.2}"),
            format!("{:.3}", r.bits_per_dim()),
            format!("{:.2}", r.steps_per_sec),
        ]);
        println!("  done {variant}: {:.3} bpb", r.bits_per_dim());
        measured.push((variant.to_string(), r.bits_per_dim()));
    }
    println!();
    table.print();
    let get = |n: &str| measured.iter().find(|(v, _)| v == n).map(|&(_, b)| b).unwrap();
    println!(
        "\nshape check: routing <= local bpb: {} ({:.3} vs {:.3})",
        get("byte_routing") <= get("byte_local") + 0.02,
        get("byte_routing"),
        get("byte_local")
    );
    Ok(())
}

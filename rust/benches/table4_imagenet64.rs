//! Table 4 — ImageNet-64 image generation.
//!
//! Paper: Routing 3.43 bits/dim (24L/16H) vs Sparse Transformer 3.44
//! (48L/16H, strided) vs ImageTransformer/local 3.48 vs Reformer 3.65.
//!
//! Here: routing vs local vs strided on synthetic 16x16 rasters whose
//! mirrored halves reward content-based long-range attention.  Shape
//! claims: routing <= strided <= local-ish ordering on bits/dim; strided
//! (dense-masked baseline) is the slowest per-step here since it is the
//! deliberately-O(T²) comparator.

use routing_transformer::bench::{
    artifacts_root, bench_eval_batches, bench_steps, header, train_and_eval,
};
use routing_transformer::runtime::Runtime;
use routing_transformer::util::timing::Table;

const ROWS: &[(&str, &str, f64)] = &[
    ("image_local_w64", "ImageTransformer / Local (3.48)", 3.48),
    ("image_strided", "Sparse Transformer, strided (3.44)", 3.44),
    ("image_r4l2w64", "Routing Transformer (3.43)", 3.43),
];

fn main() -> anyhow::Result<()> {
    header(
        "Table 4 — ImageNet-64 (synthetic mirrored rasters stand-in)",
        "paper: bits/dim at full scale; measured: held-out bits/dim at repro scale",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    let mut table =
        Table::new(&["variant", "mirrors paper row", "paper b/d", "meas b/d", "steps/s"]);
    let mut measured = Vec::new();
    for (variant, paper_row, paper_bits) in ROWS {
        let r = train_and_eval(&rt, &root, variant, "images", bench_steps(), bench_eval_batches())?;
        table.row(&[
            variant.to_string(),
            paper_row.to_string(),
            format!("{paper_bits:.2}"),
            format!("{:.3}", r.bits_per_dim()),
            format!("{:.2}", r.steps_per_sec),
        ]);
        println!("  done {variant}: {:.3} bits/dim", r.bits_per_dim());
        measured.push((variant.to_string(), r.bits_per_dim()));
    }
    println!();
    table.print();
    let get = |n: &str| measured.iter().find(|(v, _)| v == n).map(|&(_, b)| b).unwrap();
    println!(
        "\nshape check: routing <= local bits/dim: {} ({:.3} vs {:.3})",
        get("image_r4l2w64") <= get("image_local_w64") + 0.02,
        get("image_r4l2w64"),
        get("image_local_w64")
    );
    Ok(())
}

//! Table 5 — PG-19 long-document LM.
//!
//! Paper: 22-layer Routing Transformer (2 routing heads, LAST 2 LAYERS
//! only, T=8192) reaches 33.2 test ppl vs Compressive Transformer 33.6
//! (36L) and Local Transformer 39.3 (24L).
//!
//! Here: T=1024 models with the paper's exact head plan (2 routing heads
//! in the last 2 layers) vs all-local, on the long-document byte corpus
//! (entity recurrence is the PG-19-like long-range signal).  Shape
//! claim: routing <= local ppl.

use routing_transformer::bench::{
    artifacts_root, bench_eval_batches, bench_steps, header, train_and_eval,
};
use routing_transformer::runtime::Runtime;
use routing_transformer::util::timing::Table;

const ROWS: &[(&str, &str, f64)] = &[
    ("pg19_local", "Local Transformer (24L/8H)", 39.3),
    ("pg19_routing", "Routing Transformer (22L/8H, 2rh last 2 layers)", 33.2),
];

fn main() -> anyhow::Result<()> {
    header(
        "Table 5 — PG-19 (long-document synthetic corpus, T=1024)",
        "paper: ppl at T=8192 full scale; measured: held-out ppl at repro scale. \
         PG-19 models are the largest here — this bench uses fewer steps.",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    // PG-19 variants are ~8x the flops of the others: quarter the steps.
    let steps = (bench_steps() / 4).max(8);

    let mut table = Table::new(&["variant", "mirrors paper row", "paper ppl", "meas ppl", "steps/s"]);
    let mut measured = Vec::new();
    for (variant, paper_row, paper_ppl) in ROWS {
        let r = train_and_eval(&rt, &root, variant, "bytes", steps, bench_eval_batches().min(2))?;
        table.row(&[
            variant.to_string(),
            paper_row.to_string(),
            format!("{paper_ppl:.1}"),
            format!("{:.2}", r.ppl()),
            format!("{:.3}", r.steps_per_sec),
        ]);
        println!("  done {variant}: ppl {:.2}", r.ppl());
        measured.push((variant.to_string(), r.ppl()));
    }
    println!();
    table.print();
    let get = |n: &str| measured.iter().find(|(v, _)| v == n).map(|&(_, p)| p).unwrap();
    println!(
        "\nshape check: routing <= local ppl: {} ({:.2} vs {:.2})",
        get("pg19_routing") <= get("pg19_local") * 1.02,
        get("pg19_routing"),
        get("pg19_local")
    );
    Ok(())
}

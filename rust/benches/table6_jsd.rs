//! Table 6 — Jensen–Shannon divergence between attention distributions.
//!
//! Paper (Wikitext-103, T=4096, 10 runs): JSD(local‖local) is small per
//! layer (0.004–0.31), JSD(local‖routing) is close to the ln2 ≈ 0.6931
//! upper bound (0.47–0.67), JSD(routing‖routing) falls in between
//! (0.16–0.58) — routing heads attend to very different positions than
//! local heads.
//!
//! Here: the same measurement over the `analysis` variant (trained
//! briefly on the needle corpus) at T=256, 10 runs, random head pairs.

use routing_transformer::analysis;
use routing_transformer::attention::AttentionSpec;
use routing_transformer::bench::{artifacts_root, bench_steps, header};
use routing_transformer::coordinator::{train_batcher, LrSchedule, TrainOptions, Trainer};
use routing_transformer::data;
use routing_transformer::runtime::{execute_tuple, i32_literal, to_f32_vec, Artifacts, Runtime};
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::Table;

/// Paper Table 6 values (layers 0-2 of 10; mean only) for side-by-side.
const PAPER: &[(f64, f64, f64)] =
    &[(0.0038, 0.4706, 0.1579), (0.3071, 0.6674, 0.5820), (0.2164, 0.5896, 0.4015)];

fn main() -> anyhow::Result<()> {
    header(
        "Table 6 — JSD between attention heads (needle corpus, trained model)",
        "paper: Wikitext-103 T=4096; measured: T=256; natural log, bound 0.6931",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let art = Artifacts::load(&root, "analysis")?;
    let manifest = art.manifest.clone();
    let cfg = &manifest.config;

    // brief training so centroids/projections are meaningful
    let steps = bench_steps();
    let mut trainer = Trainer::new(&rt, &art)?;
    let mut batcher = train_batcher(&manifest, "needle", 0)?;
    trainer.train(
        &mut batcher,
        &manifest,
        &TrainOptions {
            steps,
            schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: steps.max(8) as u32 / 8 },
            log_every: 0,
            ..Default::default()
        },
    )?;
    let state = trainer.state;

    let exe = art.executable(&rt, "attn_probs")?;
    let runs = 10;
    let t = cfg.seq_len;
    let mut rng = Rng::new(0);
    let mut ll = vec![Vec::new(); cfg.n_layers];
    let mut lr = vec![Vec::new(); cfg.n_layers];
    let mut rr = vec![Vec::new(); cfg.n_layers];
    for run in 0..runs {
        let mut src =
            data::source_by_name("needle", cfg.vocab_size, t, cfg.window, 2000 + run as u64)?;
        let tokens = data::take(src.as_mut(), t);
        let lit = i32_literal(&tokens, &[1, t])?;
        let mut inputs: Vec<&xla::Literal> = state.params.iter().collect();
        inputs.push(&lit);
        let probs = to_f32_vec(&execute_tuple(&exe, &inputs)?[0])?;
        for layer in 0..cfg.n_layers {
            let plan = &cfg.plan[layer];
            let local = plan.heads_of("local");
            let routing = plan.heads_of("routing");
            for (bucket, (a, b)) in [
                (&mut ll[layer], (&local, &local)),
                (&mut lr[layer], (&local, &routing)),
                (&mut rr[layer], (&routing, &routing)),
            ] {
                if let Some(d) =
                    analysis::sample_pair_jsd(&probs, cfg.n_heads, t, layer, a, b, &mut rng)
                {
                    bucket.push(d);
                }
            }
        }
    }

    let mut table = Table::new(&[
        "layer", "paper l‖l", "meas l‖l", "paper l‖r", "meas l‖r", "paper r‖r", "meas r‖r",
    ]);
    let cell = |xs: &[f64]| {
        let (m, s) = analysis::mean_std(xs);
        format!("{m:.4}±{s:.3}")
    };
    for layer in 0..cfg.n_layers {
        let p = PAPER.get(layer).copied().unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        table.row(&[
            format!("{layer}"),
            format!("{:.4}", p.0),
            cell(&ll[layer]),
            format!("{:.4}", p.1),
            cell(&lr[layer]),
            format!("{:.4}", p.2),
            cell(&rr[layer]),
        ]);
    }
    table.print();

    let (m_ll, _) = analysis::mean_std(&ll.concat());
    let (m_lr, _) = analysis::mean_std(&lr.concat());
    let (m_rr, _) = analysis::mean_std(&rr.concat());
    println!("\nshape checks (paper's qualitative finding):");
    println!("  JSD(l‖l) smallest:        {} ({m_ll:.3})", m_ll < m_lr && m_ll < m_rr);
    println!("  JSD(l‖r) near bound:      {} ({m_lr:.3} vs 0.6931)", m_lr > 0.35);
    println!("  JSD(r‖r) in between:      {} ({m_rr:.3})", m_rr > m_ll && m_rr < m_lr);

    // analytic counterpart straight from the compiled sparsity patterns:
    // uniform attention over each attend-set, no model forward pass
    let k = cfg.n_clusters.max(1);
    let w = (t / k).max(1);
    let local = AttentionSpec::local(cfg.window.max(1))?.compile(t);
    let routing_a = AttentionSpec::routing_balanced(t, k)?.compile(t);
    let shifted: Vec<Vec<usize>> =
        (0..k).map(|c| (0..w).map(|m| (c * w + m + w / 2) % (k * w)).collect()).collect();
    let routing_b = AttentionSpec::routing(shifted).compile(t);
    println!("\nanalytic uniform-pattern JSD (spec-level, bound {:.4}):", analysis::JSD_MAX);
    println!("  local‖routing   {:.4}", analysis::mean_pattern_jsd(&local, &routing_a));
    println!(
        "  routing‖routing {:.4} (phase-shifted clusters)",
        analysis::mean_pattern_jsd(&routing_a, &routing_b)
    );
    Ok(())
}

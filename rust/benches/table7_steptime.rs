//! Table 7 — step-time comparison at long sequence length.
//!
//! Paper (PG-19, T=8192, TPUv3): Local Transformer 1.231 steps/s vs
//! Routing Transformer 0.7236 steps/s — local is ~1.7x faster because
//! TPUs lack sparse-op support; routing's win is memory/quality, not
//! wall-clock (Section 6.3).
//!
//! Here: raw train-block step time of the T=1024 PG-19 variants on CPU
//! PJRT (no training-to-convergence, pure throughput).  Shape claim:
//! local is faster per step; the ratio is reported next to the paper's.

use routing_transformer::bench::{artifacts_root, header, measure_steps_per_sec};
use routing_transformer::runtime::Runtime;
use routing_transformer::util::timing::Table;

fn main() -> anyhow::Result<()> {
    header(
        "Table 7 — step time, Local vs Routing at long sequence length",
        "paper: PG-19 T=8192 on TPUv3; measured: T=1024 on CPU PJRT",
    );
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let blocks = std::env::var("RTX_BENCH_BLOCKS").ok().and_then(|s| s.parse().ok()).unwrap_or(4);

    let local = measure_steps_per_sec(&rt, &root, "pg19_local", "bytes", blocks)?;
    let routing = measure_steps_per_sec(&rt, &root, "pg19_routing", "bytes", blocks)?;
    // second pair: half the heads route (the Table 1/3 allocation), where
    // the routing overhead is actually visible at reproduction scale
    let blocal = measure_steps_per_sec(&rt, &root, "byte_local", "bytes", blocks)?;
    let brouting = measure_steps_per_sec(&rt, &root, "byte_routing", "bytes", blocks)?;

    let mut table = Table::new(&["model", "plan", "paper steps/s", "meas steps/s"]);
    table.row(&["Local (pg19)".into(), "all-local, T=1024".into(), "1.231".into(),
                format!("{local:.3}")]);
    table.row(&["Routing (pg19)".into(), "2rh last 2 layers, T=1024".into(), "0.7236".into(),
                format!("{routing:.3}")]);
    table.row(&["Local (byte)".into(), "all-local, T=512".into(), "-".into(),
                format!("{blocal:.3}")]);
    table.row(&["Routing (byte)".into(), "4rh top 2 layers, T=512".into(), "-".into(),
                format!("{brouting:.3}")]);
    table.print();

    let paper_ratio = 1.231 / 0.7236;
    println!(
        "\nlocal/routing ratio: paper {:.2}x (T=8192), measured pg19 {:.2}x, byte {:.2}x",
        paper_ratio, local / routing, blocal / brouting
    );
    println!(
        "shape check: local >= routing steps/s: pg19 {}, byte {}",
        local >= routing * 0.95, blocal >= brouting * 0.95
    );
    Ok(())
}

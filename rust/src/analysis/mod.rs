//! Attention-distribution analysis: the Table 6 Jensen–Shannon divergence
//! study.
//!
//! The paper measures JSD between the attention distributions of random
//! pairs of heads per layer — local‖local, local‖routing and
//! routing‖routing — over the full sequence, reporting mean ± std over 10
//! runs (natural log, so JSD <= ln 2 ≈ 0.6931).  The `attn_probs` AOT
//! artifact returns dense per-head distributions `[L, H, T, T]`; this
//! module owns the divergence math and the sampling of head pairs.
//!
//! Next to the measured study, [`mean_pattern_jsd`] gives the *analytic*
//! divergence between two sparsity schemes directly from their compiled
//! CSR index sets (uniform attention over each attend-set), in
//! O(|S_i^a| + |S_i^b|) per row instead of the O(n²) dense rows.

use crate::attention::CompiledPattern;
use crate::util::rng::Rng;

/// ln 2 — the JSD upper bound under the natural log.
pub const JSD_MAX: f64 = std::f64::consts::LN_2;

/// KL(p ‖ m) with the convention 0·ln(0/x) = 0.
fn kl(p: &[f64], m: &[f64]) -> f64 {
    let mut s = 0.0;
    for (&pi, &mi) in p.iter().zip(m) {
        if pi > 0.0 && mi > 0.0 {
            s += pi * (pi / mi).ln();
        }
    }
    s
}

/// Jensen–Shannon divergence (natural log) between two distributions.
pub fn jsd(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let m: Vec<f64> = p.iter().zip(q).map(|(&a, &b)| 0.5 * (a + b)).collect();
    0.5 * kl(p, &m) + 0.5 * kl(q, &m)
}

/// Mean JSD between two heads' attention matrices ([T, T] row-major,
/// rows = queries).  Rows where either head has no mass (routing heads
/// leave unselected queries with empty distributions) are skipped, as are
/// the first rows where distributions are trivially degenerate.
pub fn mean_head_jsd(a: &[f32], b: &[f32], t: usize) -> f64 {
    debug_assert_eq!(a.len(), t * t);
    debug_assert_eq!(b.len(), t * t);
    let mut total = 0.0;
    let mut n = 0usize;
    for q in 1..t {
        let ra: Vec<f64> = a[q * t..(q + 1) * t].iter().map(|&x| x as f64).collect();
        let rb: Vec<f64> = b[q * t..(q + 1) * t].iter().map(|&x| x as f64).collect();
        let sa: f64 = ra.iter().sum();
        let sb: f64 = rb.iter().sum();
        if sa < 0.5 || sb < 0.5 {
            continue; // unattended query under a routing head
        }
        total += jsd(&ra, &rb);
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// Mean JSD between the uniform attention distributions induced by two
/// compiled sparsity patterns: row i of each pattern is read as the
/// uniform distribution over its attend-set S_i.  Rows where either
/// pattern leaves the query unattended are skipped (routing drops
/// tokens), matching [`mean_head_jsd`]'s convention.  Sparse closed form
/// over the sorted CSR rows — no dense [T, T] materialization.
pub fn mean_pattern_jsd(a: &CompiledPattern, b: &CompiledPattern) -> f64 {
    debug_assert_eq!(a.n(), b.n());
    let ln2 = std::f64::consts::LN_2;
    let mut total = 0.0;
    let mut rows = 0usize;
    for i in 0..a.n().min(b.n()) {
        let ra = a.row(i);
        let rb = b.row(i);
        if ra.is_empty() || rb.is_empty() {
            continue;
        }
        let mut common = 0usize;
        let (mut x, mut y) = (0usize, 0usize);
        while x < ra.len() && y < rb.len() {
            match ra[x].cmp(&rb[y]) {
                std::cmp::Ordering::Less => x += 1,
                std::cmp::Ordering::Greater => y += 1,
                std::cmp::Ordering::Equal => {
                    common += 1;
                    x += 1;
                    y += 1;
                }
            }
        }
        let pa = 1.0 / ra.len() as f64;
        let pb = 1.0 / rb.len() as f64;
        let m = 0.5 * (pa + pb);
        // keys in exactly one set: m = p/2, so each contributes p·ln2 to
        // its side's KL; keys in both use the mixture m directly
        let mut d = 0.5 * (ra.len() - common) as f64 * pa * ln2;
        d += 0.5 * (rb.len() - common) as f64 * pb * ln2;
        d += 0.5 * common as f64 * (pa * (pa / m).ln() + pb * (pb / m).ln());
        total += d;
        rows += 1;
    }
    if rows == 0 {
        0.0
    } else {
        total / rows as f64
    }
}

/// Mean ± std helper.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// One JSD measurement row: a random pair of heads of the given kinds.
///
/// `probs` is the `[L, H, T, T]` tensor from the `attn_probs` artifact
/// (flattened row-major); `heads_a` / `heads_b` are the head indices of
/// the two kinds within layer `layer`.
pub fn sample_pair_jsd(
    probs: &[f32],
    n_heads: usize,
    t: usize,
    layer: usize,
    heads_a: &[usize],
    heads_b: &[usize],
    rng: &mut Rng,
) -> Option<f64> {
    if heads_a.is_empty() || heads_b.is_empty() {
        return None;
    }
    let (ha, hb) = {
        let a = heads_a[rng.below(heads_a.len())];
        // resample b != a when drawing from the same kind
        let mut b = heads_b[rng.below(heads_b.len())];
        if std::ptr::eq(heads_a.as_ptr(), heads_b.as_ptr()) && heads_b.len() > 1 {
            while b == a {
                b = heads_b[rng.below(heads_b.len())];
            }
        }
        (a, b)
    };
    if ha == hb {
        return None;
    }
    let head_sz = t * t;
    let layer_sz = n_heads * head_sz;
    let off_a = layer * layer_sz + ha * head_sz;
    let off_b = layer * layer_sz + hb * head_sz;
    Some(mean_head_jsd(&probs[off_a..off_a + head_sz], &probs[off_b..off_b + head_sz], t))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsd_identical_is_zero() {
        let p = vec![0.25; 4];
        assert!(jsd(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn jsd_disjoint_is_ln2() {
        let p = vec![1.0, 0.0];
        let q = vec![0.0, 1.0];
        assert!((jsd(&p, &q) - JSD_MAX).abs() < 1e-12);
    }

    #[test]
    fn jsd_symmetric_and_bounded() {
        let p = vec![0.7, 0.2, 0.1];
        let q = vec![0.1, 0.3, 0.6];
        let d1 = jsd(&p, &q);
        let d2 = jsd(&q, &p);
        assert!((d1 - d2).abs() < 1e-12);
        assert!(d1 > 0.0 && d1 < JSD_MAX);
    }

    #[test]
    fn head_jsd_skips_empty_rows() {
        let t = 4;
        // head a: uniform causal rows; head b: empty rows except row 1
        let mut a = vec![0f32; t * t];
        let mut b = vec![0f32; t * t];
        for q in 0..t {
            for k in 0..=q {
                a[q * t + k] = 1.0 / (q + 1) as f32;
            }
        }
        b[1 * t + 0] = 1.0;
        let d = mean_head_jsd(&a, &b, t);
        assert!(d >= 0.0 && d <= JSD_MAX);
    }

    #[test]
    fn identical_heads_zero_divergence() {
        let t = 8;
        let mut a = vec![0f32; t * t];
        for q in 0..t {
            for k in 0..=q {
                a[q * t + k] = 1.0 / (q + 1) as f32;
            }
        }
        assert!(mean_head_jsd(&a, &a, t) < 1e-9);
    }

    #[test]
    fn pattern_jsd_matches_dense_reference() {
        use crate::attention::{AttentionSpec, CompiledPattern};
        fn dense_row(p: &CompiledPattern, i: usize, n: usize) -> Vec<f64> {
            let row = p.row(i);
            let mut v = vec![0.0; n];
            if !row.is_empty() {
                let w = 1.0 / row.len() as f64;
                for &j in row {
                    v[j] = w;
                }
            }
            v
        }
        let n = 24;
        let a = AttentionSpec::local(4).unwrap().compile(n);
        let b = AttentionSpec::routing_balanced(n, 4).unwrap().compile(n);
        let mut total = 0.0;
        let mut rows = 0usize;
        for i in 0..n {
            if a.row(i).is_empty() || b.row(i).is_empty() {
                continue;
            }
            total += jsd(&dense_row(&a, i, n), &dense_row(&b, i, n));
            rows += 1;
        }
        let reference = total / rows as f64;
        let fast = mean_pattern_jsd(&a, &b);
        assert!((fast - reference).abs() < 1e-12, "fast {fast} vs dense {reference}");
        assert!(fast > 0.0 && fast <= JSD_MAX + 1e-12);
        // identical patterns diverge by exactly zero
        assert!(mean_pattern_jsd(&a, &a).abs() < 1e-15);
        // n = 0 patterns are a no-op, not a divide-by-zero
        let e = AttentionSpec::Full.compile(0);
        assert_eq!(mean_pattern_jsd(&e, &e), 0.0);
    }

    #[test]
    fn mean_std_math() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}

//! Pluggable attention execution backends — "run these CSR rows against
//! `[n, d]` Q/K/V" behind one registerable [`Backend`] trait.
//!
//! The engine's sharding/batching layers decide *which* rows run *where*
//! (see [`super::engine`] and [`super::pool`]); a backend decides *how*
//! one contiguous row range is evaluated.  Every backend must be
//! **bit-identical** to [`Reference`] — same f64 accumulation order per
//! output element — so callers can swap backends without revalidating
//! numerics (pinned by the backend-dimension property in
//! `tests/stateful.rs` and the unit tests below).  Three implementations
//! ship:
//!
//! * [`Reference`] — the scalar host kernel
//!   ([`super::engine::sparse_attention_rows`]), kept as the bit-exactness
//!   oracle every other backend is compared against.
//! * [`Blocked`] — a cache-blocked host backend: the query row is
//!   pre-widened to f64 once into a reusable per-worker scratch buffer,
//!   and key columns are processed in tiles of four with one independent
//!   f64 accumulator chain each.  Per-column dot products keep the exact
//!   reference summation order (so results stay bitwise equal), but the
//!   four chains give the CPU instruction-level parallelism the strict
//!   single-chain f64 fold denies it — `bench_complexity` pins ≥ 1.5×
//!   over [`Reference`] at n = 2048, d = 64.  No `unsafe`, no new
//!   dependencies.
//! * `XlaBackend` (behind the `xla` cargo feature, so not linkable from
//!   host-only docs) — the landing slot for the PJRT/accelerator
//!   lowering: its `stage` method exports a pattern's CSR arrays in the
//!   i64 layout the device gather consumes; until the device kernel
//!   lands, execution falls back to the host reference path (still
//!   bit-identical, so the slot is safe to select).
//!
//! Backends register by name in a process-wide registry ([`register`] /
//! [`lookup`] / [`names`]); `rtx serve-bench --backend` selects from it.
//! The sharded and batched execution paths take a backend per call via
//! [`super::ShardedPattern::attention_backend`] and
//! [`super::BatchedAttention::attention_backend`] — backend choice and
//! [`Execution`](super::pool::Execution) strategy compose freely.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use super::compiled::CompiledPattern;
use super::engine::sparse_attention_rows;
pub use super::engine::check_rows_args;

/// An attention execution backend: evaluates the CSR rows of one
/// [`CompiledPattern`] against full `[n, d]` row-major Q/K/V buffers.
///
/// Implementations must be bit-identical to [`Reference`]: identical f64
/// accumulation order per output element, fully-masked rows written as
/// zeros, and the same shape validation errors.  `Send + Sync` because
/// one backend instance is shared across pool workers.
pub trait Backend: Send + Sync + std::fmt::Debug {
    /// Registry / display name (e.g. `"reference"`, `"blocked"`).
    fn name(&self) -> &'static str;

    /// Evaluate the query rows in `rows`, writing row `i`'s output at
    /// `out[(i - rows.start) * d ..]`; `out` holds exactly
    /// `rows.len() * d` values and `q`/`k`/`v` stay the full `[n, d]`
    /// buffers (keys outside the range are still attended).  Same
    /// contract as [`super::engine::sparse_attention_rows`];
    /// implementations should validate via [`check_rows_args`] so every
    /// backend rejects bad shapes identically.
    #[allow(clippy::too_many_arguments)]
    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()>;

    /// Whole-pattern convenience: evaluate every row of `pattern` into a
    /// fresh `[n, d]` output (single-threaded; use the sharded/batched
    /// paths for multi-worker execution).
    fn attention(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
    ) -> Result<Vec<f32>> {
        let n = pattern.n();
        let mut out = vec![0f32; n * d];
        self.attention_rows(q, k, v, d, pattern, 0..n, &mut out)?;
        Ok(out)
    }
}

// ------------------------------------------------------------ reference

/// The scalar host kernel — the bit-exactness oracle.  Delegates to
/// [`super::engine::sparse_attention_rows`] unchanged; every other
/// backend is validated (and benchmarked) against this one.
#[derive(Debug, Default, Clone, Copy)]
pub struct Reference;

impl Backend for Reference {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        sparse_attention_rows(q, k, v, d, pattern, rows, out)
    }
}

// -------------------------------------------------------------- blocked

/// Width of one key-column tile: four independent f64 accumulator chains
/// is enough to hide the ~4-cycle dependent-add latency that serializes
/// the reference kernel's single-chain score fold.
const COL_TILE: usize = 4;

/// Cache-blocked host backend, bit-identical to [`Reference`].
///
/// Per worker call it keeps three reusable scratch buffers (the query row
/// widened to f64, the score vector, and the f64 output accumulator) and
/// walks each row's attend-set in `COL_TILE` (= 4)-wide key tiles: every
/// column's dot product still folds over the head dimension in exactly
/// the reference order (bit-identical per column), but the tile's four
/// accumulator chains are independent, so the CPU overlaps them instead
/// of stalling on one serial f64 add chain.  The softmax and the value
/// accumulation phases reuse the reference loop order unchanged (the
/// value loop is already vectorizable: each output element owns an
/// independent chain).
#[derive(Debug, Default, Clone, Copy)]
pub struct Blocked;

impl Backend for Blocked {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        check_rows_args(q, k, v, d, pattern, &rows, out)?;
        let scale = 1.0 / (d as f64).sqrt();
        // per-worker scratch, reused across every row of the shard
        let mut qf: Vec<f64> = vec![0.0; d];
        let mut scores: Vec<f64> = Vec::new();
        let mut acc: Vec<f64> = vec![0.0; d];
        let start = rows.start;
        for (i, cols, _clusters) in pattern.rows(rows) {
            let oi = &mut out[(i - start) * d..(i - start + 1) * d];
            oi.fill(0.0);
            if cols.is_empty() {
                // fully-masked row: zeros, never NaN (reference contract)
                continue;
            }
            // widen q_i once instead of once per key column
            for (dst, &src) in qf.iter_mut().zip(&q[i * d..(i + 1) * d]) {
                *dst = src as f64;
            }
            scores.clear();
            let mut max = f64::NEG_INFINITY;
            let mut tiles = cols.chunks_exact(COL_TILE);
            for tile in tiles.by_ref() {
                let k0 = &k[tile[0] * d..tile[0] * d + d];
                let k1 = &k[tile[1] * d..tile[1] * d + d];
                let k2 = &k[tile[2] * d..tile[2] * d + d];
                let k3 = &k[tile[3] * d..tile[3] * d + d];
                let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
                for (t, &qt) in qf.iter().enumerate() {
                    s0 += qt * k0[t] as f64;
                    s1 += qt * k1[t] as f64;
                    s2 += qt * k2[t] as f64;
                    s3 += qt * k3[t] as f64;
                }
                for s in [s0 * scale, s1 * scale, s2 * scale, s3 * scale] {
                    max = max.max(s);
                    scores.push(s);
                }
            }
            for &j in tiles.remainder() {
                let kj = &k[j * d..(j + 1) * d];
                let mut s = 0f64;
                for (t, &qt) in qf.iter().enumerate() {
                    s += qt * kj[t] as f64;
                }
                let s = s * scale;
                max = max.max(s);
                scores.push(s);
            }
            // softmax + value gather: reference loop order, verbatim
            let mut z = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                z += *s;
            }
            acc.fill(0.0);
            for (&e, &j) in scores.iter().zip(cols) {
                let w = e / z;
                let vj = &v[j * d..(j + 1) * d];
                for (a, &x) in acc.iter_mut().zip(vj) {
                    *a += w * x as f64;
                }
            }
            for (o, &a) in oi.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------------- xla stub

/// Feature-gated landing slot for the accelerator (PJRT) lowering of a
/// [`CompiledPattern`].
///
/// The ROADMAP's multi-backend step ends with the CSR arrays handed to a
/// device gather kernel; [`XlaBackend::stage`] already exports them in
/// the i64 layout that lowering consumes, so the device kernel can land
/// behind this type without touching any call site.  Until it does,
/// execution falls back to the host [`Reference`] path — bit-identical,
/// so selecting `--backend xla` today is safe (just not yet faster).
#[cfg(feature = "xla")]
#[derive(Debug, Default, Clone, Copy)]
pub struct XlaBackend;

#[cfg(feature = "xla")]
impl XlaBackend {
    /// Stage a pattern for device transfer: `(row_offsets, cols)` as i64
    /// buffers (`n + 1` offsets, `nnz` key indices) — the two literals
    /// the PJRT sparse-gather lowering takes alongside Q/K/V.
    pub fn stage(pattern: &CompiledPattern) -> (Vec<i64>, Vec<i64>) {
        let offsets = pattern.offsets().iter().map(|&o| o as i64).collect();
        let cols = (0..pattern.n())
            .flat_map(|i| pattern.row(i).iter().map(|&j| j as i64))
            .collect();
        (offsets, cols)
    }
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        // host fallback until the PJRT kernel lands; see the type docs
        sparse_attention_rows(q, k, v, d, pattern, rows, out)
    }
}

// ------------------------------------------------------------- registry

type BackendMap = BTreeMap<String, Arc<dyn Backend>>;

fn registry() -> &'static Mutex<BackendMap> {
    static REGISTRY: OnceLock<Mutex<BackendMap>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BackendMap = BTreeMap::new();
        map.insert("reference".to_string(), Arc::new(Reference));
        map.insert("blocked".to_string(), Arc::new(Blocked));
        #[cfg(feature = "xla")]
        map.insert("xla".to_string(), Arc::new(XlaBackend));
        Mutex::new(map)
    })
}

/// Register a backend under [`Backend::name`]; errors if the name is
/// already taken (the built-ins `reference`/`blocked` — plus `xla` with
/// the feature — are pre-registered).
pub fn register(backend: Arc<dyn Backend>) -> Result<()> {
    let name = backend.name().to_string();
    let mut map = registry().lock().unwrap_or_else(|e| e.into_inner());
    if map.contains_key(&name) {
        bail!("attention backend '{name}' is already registered");
    }
    map.insert(name, backend);
    Ok(())
}

/// Look a backend up by registry name (`None` if unknown; see [`names`]).
pub fn lookup(name: &str) -> Option<Arc<dyn Backend>> {
    registry().lock().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
}

/// Registered backend names, sorted — for `--backend` error messages.
pub fn names() -> Vec<String> {
    registry().lock().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionSpec;
    use crate::util::rng::Rng;

    fn random_qkv(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut mk = |rng: &mut Rng| (0..n * d).map(|_| rng.normal() as f32).collect();
        (mk(rng), mk(rng), mk(rng))
    }

    fn specs(n: usize) -> Vec<AttentionSpec> {
        vec![
            AttentionSpec::Full,
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::strided(2).unwrap(),
            AttentionSpec::routing(vec![(0..n).step_by(2).collect(), vec![1, 3]]),
            // fully-masked: no cluster admits anything
            AttentionSpec::routing(vec![]),
            AttentionSpec::union(vec![
                AttentionSpec::local(2).unwrap(),
                AttentionSpec::routing(vec![vec![0, 5, 6]]),
            ])
            .unwrap(),
        ]
    }

    #[test]
    fn blocked_is_bit_identical_to_reference() {
        let mut rng = Rng::new(77);
        for n in [0usize, 1, 2, 5, 17, 33] {
            // d sweeps across the tile boundary cases (d=1, d%4 != 0, big)
            for d in [1usize, 3, 4, 7, 16] {
                let (q, k, v) = random_qkv(&mut rng, n, d);
                for spec in specs(n) {
                    let p = spec.compile(n);
                    let a = Reference.attention(&q, &k, &v, d, &p).unwrap();
                    let b = Blocked.attention(&q, &k, &v, d, &p).unwrap();
                    assert_eq!(a, b, "n={n} d={d} spec={spec:?}");
                }
            }
        }
    }

    #[test]
    fn blocked_handles_masked_rows_and_tile_remainders() {
        // rows with 0, 1, 2, 3, 4, 5 columns exercise every tile shape
        let spec = AttentionSpec::routing(vec![vec![0, 1, 2, 3, 4, 5]]);
        let p = spec.compile(8);
        assert!(p.row(6).is_empty() && p.row(7).is_empty());
        let mut rng = Rng::new(5);
        let (q, k, v) = random_qkv(&mut rng, 8, 4);
        let out = Blocked.attention(&q, &k, &v, 4, &p).unwrap();
        assert_eq!(out, Reference.attention(&q, &k, &v, 4, &p).unwrap());
        assert!(out[6 * 4..].iter().all(|&x| x == 0.0), "masked rows stay zero");
    }

    #[test]
    fn blocked_validates_shapes_like_reference() {
        let p = AttentionSpec::Full.compile(2);
        assert!(Blocked.attention(&[0.0; 3], &[0.0; 4], &[0.0; 4], 2, &p).is_err());
        assert!(Blocked.attention(&[], &[], &[], 0, &p).is_err());
        let mut out = [0f32; 2];
        assert!(Blocked
            .attention_rows(&[0.0; 4], &[0.0; 4], &[0.0; 4], 2, &p, 1..3, &mut out)
            .is_err());
    }

    #[test]
    fn registry_serves_builtins_and_rejects_duplicates() {
        let r = lookup("reference").expect("built-in");
        assert_eq!(r.name(), "reference");
        let b = lookup("blocked").expect("built-in");
        assert_eq!(b.name(), "blocked");
        assert!(lookup("warp-drive").is_none());
        let names = names();
        assert!(names.contains(&"reference".to_string()));
        assert!(names.contains(&"blocked".to_string()));
        assert!(register(Arc::new(Reference)).is_err(), "duplicate name must be rejected");
    }

    #[test]
    fn custom_backends_can_register() {
        /// A deliberately silly wrapper proving third-party registration.
        #[derive(Debug)]
        struct Custom;
        impl Backend for Custom {
            fn name(&self) -> &'static str {
                "custom-test-backend"
            }
            fn attention_rows(
                &self,
                q: &[f32],
                k: &[f32],
                v: &[f32],
                d: usize,
                pattern: &CompiledPattern,
                rows: std::ops::Range<usize>,
                out: &mut [f32],
            ) -> Result<()> {
                sparse_attention_rows(q, k, v, d, pattern, rows, out)
            }
        }
        register(Arc::new(Custom)).unwrap();
        let found = lookup("custom-test-backend").expect("registered");
        let p = AttentionSpec::local(2).unwrap().compile(4);
        let mut rng = Rng::new(9);
        let (q, k, v) = random_qkv(&mut rng, 4, 2);
        assert_eq!(
            found.attention(&q, &k, &v, 2, &p).unwrap(),
            Reference.attention(&q, &k, &v, 2, &p).unwrap()
        );
    }
}

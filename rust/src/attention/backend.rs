//! Pluggable attention execution backends — "run these CSR rows against
//! `[n, d]` Q/K/V" behind one registerable [`Backend`] trait.
//!
//! The engine's sharding/batching layers decide *which* rows run *where*
//! (see [`super::engine`] and [`super::pool`]); a backend decides *how*
//! one contiguous row range is evaluated.  Every backend declares its
//! numerical contract relative to [`Reference`] via
//! [`Backend::exactness`]:
//!
//! * [`Exactness::Bitwise`] — same f64 accumulation order per output
//!   element, so outputs are bit-for-bit equal to the reference kernel.
//! * [`Exactness::Ulps`]`(k)` — outputs may differ by at most `k` units
//!   in the last place per element (fast-math backends that reorder or
//!   narrow the arithmetic for speed).
//!
//! Verification sites (the stateful backend property, proptests,
//! `bench_complexity` pins, serve-bench per-step checks) consume the
//! declaration through one shared comparator, [`assert_outputs_match`],
//! instead of hard-coding `==` — so a bitwise backend is still held to
//! bit-exactness while a `Ulps(k)` backend is held to exactly its
//! declared budget, never a silently widened one.  Four implementations
//! ship:
//!
//! * [`Reference`] — the scalar host kernel
//!   ([`super::engine::sparse_attention_rows`]), kept as the exactness
//!   oracle every other backend is compared against.  `Bitwise` by
//!   definition.
//! * [`Blocked`] — a cache-blocked host backend: the query row is
//!   pre-widened to f64 once into a reusable per-worker scratch buffer,
//!   and key columns are processed in tiles of four with one independent
//!   f64 accumulator chain each.  Per-column dot products keep the exact
//!   reference summation order (so results stay bitwise equal), but the
//!   four chains give the CPU instruction-level parallelism the strict
//!   single-chain f64 fold denies it — `bench_complexity` pins ≥ 1.5×
//!   over [`Reference`] at n = 2048, d = 64.  No `unsafe`, no new
//!   dependencies.  Declares `Bitwise`.
//! * [`Simd`] — the fast-math tier: a portable lane-widened f32 kernel
//!   (eight explicit accumulator lanes the autovectorizer maps onto
//!   AVX2/NEON registers, row-blocked max, f32 softmax, in-place f32
//!   value accumulation).  Trades the reference's f64 ordering for raw
//!   throughput and therefore declares [`Exactness::Ulps`] with a
//!   justified budget ([`Simd::ULPS`]); `bench_complexity` pins ≥ 3×
//!   over [`Reference`] at n = 2048, d = 64.  No `unsafe`, no new
//!   dependencies.
//! * `XlaBackend` (behind the `xla` cargo feature, so not linkable from
//!   host-only docs) — the landing slot for the PJRT/accelerator
//!   lowering: its `stage` method exports a pattern's CSR arrays in the
//!   i64 layout the device gather consumes; until the device kernel
//!   lands, execution falls back to the host reference path (declares
//!   `Bitwise`, so the slot is safe to select).
//!
//! Backends register by name in a process-wide registry ([`register`] /
//! [`lookup`] / [`names`]); `rtx serve-bench --backend` and
//! `rtx serve --backend` select from it.  The sharded and batched
//! execution paths take a backend per call via
//! [`super::ShardedPattern::attention_backend`] and
//! [`super::BatchedAttention::attention_backend`] — backend choice and
//! [`Execution`](super::pool::Execution) strategy compose freely.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use super::compiled::CompiledPattern;
use super::engine::sparse_attention_rows;
pub use super::engine::check_rows_args;

// ------------------------------------------------------------ exactness

/// The numerical contract a [`Backend`] promises relative to
/// [`Reference`], consumed by [`assert_outputs_match`] at every
/// verification site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exactness {
    /// Outputs are bit-for-bit identical to the reference kernel
    /// (`f32::to_bits` equality per element; note this distinguishes
    /// `+0.0` from `-0.0` and is reflexive on NaN bit patterns, making
    /// it strictly stronger than `==`).
    Bitwise,
    /// Each output element is within `k` units in the last place of the
    /// reference value, with an absolute floor of `k · 2⁻²³` (one ulp of
    /// the `[1, 2)` binade per budgeted ulp) so near-zero outputs
    /// produced by catastrophic cancellation — where backend error is
    /// absolute in the accumulation scale, not relative to the tiny
    /// result — don't fail on astronomically large relative distances.
    /// `Ulps(0)` is equivalent to [`Exactness::Bitwise`] on nonzero
    /// finite values (at `±0.0` the ulps distance is 0 but the bits
    /// differ).
    Ulps(u32),
}

impl Exactness {
    /// Combine two budgets for a comparison *between* two non-reference
    /// backends: bitwise is the identity, and two ulps budgets add
    /// (triangle inequality through the shared reference value).
    pub fn join(self, other: Exactness) -> Exactness {
        match (self, other) {
            (Exactness::Bitwise, x) | (x, Exactness::Bitwise) => x,
            (Exactness::Ulps(a), Exactness::Ulps(b)) => Exactness::Ulps(a.saturating_add(b)),
        }
    }
}

impl std::fmt::Display for Exactness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Exactness::Bitwise => write!(f, "bitwise"),
            Exactness::Ulps(k) => write!(f, "ulps({k})"),
        }
    }
}

/// Map an f32 onto the integer line such that adjacent representable
/// floats are adjacent integers and ordering matches numeric ordering
/// (`-0.0` and `+0.0` both map to 0).  The difference of two mapped
/// values is the signed ulps distance.
fn monotone(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        0x8000_0000u32 as i64 - b as i64
    } else {
        b as i64
    }
}

/// Units-in-the-last-place distance between two f32 values: how many
/// representable floats lie between them (0 for equal values and for
/// `+0.0` vs `-0.0`; counts across the zero boundary without a gap).
/// Only meaningful for non-NaN inputs — [`values_match`] handles NaN
/// before consulting this.
pub fn ulps_distance(a: f32, b: f32) -> u64 {
    (monotone(a) - monotone(b)).unsigned_abs()
}

/// Do two scalar outputs match under an [`Exactness`] contract?
///
/// `Bitwise` compares `to_bits` exactly.  `Ulps(k)` treats identical
/// bits as a match, requires NaN to pair only with NaN, requires
/// infinities to match by `==` (no finite value is "close" to
/// infinity), and otherwise accepts a ulps distance of at most `k` *or*
/// an absolute difference of at most `k · 2⁻²³` (see
/// [`Exactness::Ulps`] for why the absolute floor exists).
pub fn values_match(a: f32, b: f32, exactness: Exactness) -> bool {
    match exactness {
        Exactness::Bitwise => a.to_bits() == b.to_bits(),
        Exactness::Ulps(k) => {
            if a.to_bits() == b.to_bits() {
                return true;
            }
            if a.is_nan() || b.is_nan() {
                return a.is_nan() && b.is_nan();
            }
            if a.is_infinite() || b.is_infinite() {
                return a == b;
            }
            ulps_distance(a, b) <= u64::from(k)
                || (f64::from(a) - f64::from(b)).abs() <= f64::from(k) * f64::from(f32::EPSILON)
        }
    }
}

/// The shared verification comparator: assert that `actual` matches
/// `expected` element-wise under `exactness`, or return an error naming
/// the first offending index, both values, and the observed ulps
/// distance.  Every site that used to assert `==` on attention outputs
/// (engine shard/batch equivalence, serve-bench per-step checks,
/// `bench_complexity` pins, the stateful backend property, the
/// proptest oracles) goes through here, so a backend declaring
/// [`Exactness::Bitwise`] is still held to bit-exactness.
pub fn assert_outputs_match(
    expected: &[f32],
    actual: &[f32],
    exactness: Exactness,
    context: &str,
) -> Result<()> {
    if expected.len() != actual.len() {
        bail!(
            "{context}: output length mismatch ({} expected vs {} actual)",
            expected.len(),
            actual.len()
        );
    }
    for (i, (&e, &a)) in expected.iter().zip(actual).enumerate() {
        if !values_match(e, a, exactness) {
            match exactness {
                Exactness::Bitwise => bail!(
                    "{context}: outputs differ at index {i} under {exactness}: \
                     {e:?} (bits {:#010x}) vs {a:?} (bits {:#010x})",
                    e.to_bits(),
                    a.to_bits()
                ),
                Exactness::Ulps(_) => bail!(
                    "{context}: outputs differ at index {i} beyond {exactness}: \
                     {e:?} vs {a:?} ({} ulps apart)",
                    ulps_distance(e, a)
                ),
            }
        }
    }
    Ok(())
}

/// An attention execution backend: evaluates the CSR rows of one
/// [`CompiledPattern`] against full `[n, d]` row-major Q/K/V buffers.
///
/// Implementations declare their numerical contract relative to
/// [`Reference`] via [`Backend::exactness`] (default
/// [`Exactness::Bitwise`], so a backend that doesn't opt into fast math
/// is held to bit-exactness).  All backends must write fully-masked
/// rows as zeros — never NaN — and produce the same shape validation
/// errors (validate via [`check_rows_args`]).  `Send + Sync` because
/// one backend instance is shared across pool workers.
pub trait Backend: Send + Sync + std::fmt::Debug {
    /// Registry / display name (e.g. `"reference"`, `"blocked"`).
    fn name(&self) -> &'static str;

    /// The numerical contract this backend's outputs satisfy relative
    /// to [`Reference`].  Defaults to [`Exactness::Bitwise`] — a
    /// backend must explicitly opt into a `Ulps(k)` budget, so nothing
    /// weakens silently.
    fn exactness(&self) -> Exactness {
        Exactness::Bitwise
    }

    /// Evaluate the query rows in `rows`, writing row `i`'s output at
    /// `out[(i - rows.start) * d ..]`; `out` holds exactly
    /// `rows.len() * d` values and `q`/`k`/`v` stay the full `[n, d]`
    /// buffers (keys outside the range are still attended).  Same
    /// contract as [`super::engine::sparse_attention_rows`];
    /// implementations should validate via [`check_rows_args`] so every
    /// backend rejects bad shapes identically.
    #[allow(clippy::too_many_arguments)]
    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()>;

    /// Whole-pattern convenience: evaluate every row of `pattern` into a
    /// fresh `[n, d]` output (single-threaded; use the sharded/batched
    /// paths for multi-worker execution).
    fn attention(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
    ) -> Result<Vec<f32>> {
        let n = pattern.n();
        let mut out = vec![0f32; n * d];
        self.attention_rows(q, k, v, d, pattern, 0..n, &mut out)?;
        Ok(out)
    }
}

// ------------------------------------------------------------ reference

/// The scalar host kernel — the exactness oracle.  Delegates to
/// [`super::engine::sparse_attention_rows`] unchanged; every other
/// backend is validated (and benchmarked) against this one.  Declares
/// [`Exactness::Bitwise`] by definition.
#[derive(Debug, Default, Clone, Copy)]
pub struct Reference;

impl Backend for Reference {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        sparse_attention_rows(q, k, v, d, pattern, rows, out)
    }
}

// -------------------------------------------------------------- blocked

/// Width of one key-column tile: four independent f64 accumulator chains
/// is enough to hide the ~4-cycle dependent-add latency that serializes
/// the reference kernel's single-chain score fold.
const COL_TILE: usize = 4;

/// Cache-blocked host backend, bit-identical to [`Reference`].
///
/// Per worker call it keeps three reusable scratch buffers (the query row
/// widened to f64, the score vector, and the f64 output accumulator) and
/// walks each row's attend-set in `COL_TILE` (= 4)-wide key tiles: every
/// column's dot product still folds over the head dimension in exactly
/// the reference order (bit-identical per column), but the tile's four
/// accumulator chains are independent, so the CPU overlaps them instead
/// of stalling on one serial f64 add chain.  The softmax and the value
/// accumulation phases reuse the reference loop order unchanged (the
/// value loop is already vectorizable: each output element owns an
/// independent chain).  Declares [`Exactness::Bitwise`].
#[derive(Debug, Default, Clone, Copy)]
pub struct Blocked;

impl Backend for Blocked {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        check_rows_args(q, k, v, d, pattern, &rows, out)?;
        let scale = 1.0 / (d as f64).sqrt();
        // per-worker scratch, reused across every row of the shard
        let mut qf: Vec<f64> = vec![0.0; d];
        let mut scores: Vec<f64> = Vec::new();
        let mut acc: Vec<f64> = vec![0.0; d];
        let start = rows.start;
        for (i, cols, _clusters) in pattern.rows(rows) {
            let oi = &mut out[(i - start) * d..(i - start + 1) * d];
            oi.fill(0.0);
            if cols.is_empty() {
                // fully-masked row: zeros, never NaN (reference contract)
                continue;
            }
            // widen q_i once instead of once per key column
            for (dst, &src) in qf.iter_mut().zip(&q[i * d..(i + 1) * d]) {
                *dst = src as f64;
            }
            scores.clear();
            let mut max = f64::NEG_INFINITY;
            let mut tiles = cols.chunks_exact(COL_TILE);
            for tile in tiles.by_ref() {
                let k0 = &k[tile[0] * d..tile[0] * d + d];
                let k1 = &k[tile[1] * d..tile[1] * d + d];
                let k2 = &k[tile[2] * d..tile[2] * d + d];
                let k3 = &k[tile[3] * d..tile[3] * d + d];
                let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
                for (t, &qt) in qf.iter().enumerate() {
                    s0 += qt * k0[t] as f64;
                    s1 += qt * k1[t] as f64;
                    s2 += qt * k2[t] as f64;
                    s3 += qt * k3[t] as f64;
                }
                for s in [s0 * scale, s1 * scale, s2 * scale, s3 * scale] {
                    max = max.max(s);
                    scores.push(s);
                }
            }
            for &j in tiles.remainder() {
                let kj = &k[j * d..(j + 1) * d];
                let mut s = 0f64;
                for (t, &qt) in qf.iter().enumerate() {
                    s += qt * kj[t] as f64;
                }
                let s = s * scale;
                max = max.max(s);
                scores.push(s);
            }
            // softmax + value gather: reference loop order, verbatim
            let mut z = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                z += *s;
            }
            acc.fill(0.0);
            for (&e, &j) in scores.iter().zip(cols) {
                let w = e / z;
                let vj = &v[j * d..(j + 1) * d];
                for (a, &x) in acc.iter_mut().zip(vj) {
                    *a += w * x as f64;
                }
            }
            for (o, &a) in oi.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------------------- simd

/// Accumulator lane count for the [`Simd`] kernel: eight f32 lanes fill
/// one AVX2 (or two NEON) registers, and the explicit lane array is what
/// lets the autovectorizer emit packed multiply-adds on stable Rust with
/// no `std::simd` and no new dependencies.
const LANES: usize = 8;

/// The fast-math host backend: a portable lane-widened f32 kernel.
///
/// Where [`Reference`]/[`Blocked`] fold every score through one (or
/// four) strictly-ordered f64 chains, this kernel keeps the entire row
/// in f32 and reassociates freely for throughput:
///
/// * **scores** — each key-column dot product runs over
///   `LANES` (= 8) independent f32 accumulator lanes
///   (`chunks_exact(LANES)` over the head dimension plus a scalar
///   tail), reduced pairwise at the end — the shape the autovectorizer
///   turns into packed f32 FMAs;
/// * **row-blocked max** — the softmax max is found lane-parallel over
///   the score vector in `LANES`-wide blocks, then reduced;
/// * **softmax + values** — `exp`/normalization stay in f32 (one
///   `1/z` multiply instead of per-weight divides) and the weighted
///   value rows accumulate directly into the f32 output slice, which
///   vectorizes across the head dimension.
///
/// The score vector is per-worker scratch reused across every row of
/// the shard.  Fully-masked rows are written as zeros (never NaN) and
/// shapes are validated via [`check_rows_args`], exactly like every
/// other backend.  Declares [`Exactness::Ulps`]`(`[`Simd::ULPS`]`)` —
/// see that constant for the error budget; `bench_complexity` pins the
/// payoff at ≥ 3× [`Reference`] for n = 2048, d = 64.
#[derive(Debug, Default, Clone, Copy)]
pub struct Simd;

impl Simd {
    /// Declared ulps budget versus [`Reference`].
    ///
    /// Error budget: an f32 dot over d = 64 terms carries ~`d·ε` ≈ 4e-6
    /// relative score error versus the f64 reference; `exp` converts
    /// score error to relative weight error of the same order, and an
    /// m-term f32 value accumulation (m up to a few hundred attended
    /// keys) adds ~`m·ε/2` ≈ 2e-5.  Together the observed output error
    /// stays near 1e-4 relative ≈ 1700 ulps.  4096 ulps ≈ 5e-4 relative
    /// (with the matching absolute floor near zero) gives ~4× headroom
    /// over that bound so the pin stays deterministic across
    /// architectures with and without fused multiply-add.
    pub const ULPS: u32 = 4096;
}

impl Backend for Simd {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn exactness(&self) -> Exactness {
        Exactness::Ulps(Self::ULPS)
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        check_rows_args(q, k, v, d, pattern, &rows, out)?;
        let scale = (1.0 / (d as f64).sqrt()) as f32;
        // per-worker scratch, reused across every row of the shard
        let mut scores: Vec<f32> = Vec::new();
        let start = rows.start;
        for (i, cols, _clusters) in pattern.rows(rows) {
            let oi = &mut out[(i - start) * d..(i - start + 1) * d];
            oi.fill(0.0);
            if cols.is_empty() {
                // fully-masked row: zeros, never NaN (reference contract)
                continue;
            }
            let qi = &q[i * d..(i + 1) * d];
            // lane-widened f32 dot product per key column
            scores.clear();
            for &j in cols {
                let kj = &k[j * d..(j + 1) * d];
                let mut lanes = [0f32; LANES];
                let mut qc = qi.chunks_exact(LANES);
                let mut kc = kj.chunks_exact(LANES);
                for (qs, ks) in qc.by_ref().zip(kc.by_ref()) {
                    for ((l, &qt), &kt) in lanes.iter_mut().zip(qs).zip(ks) {
                        *l += qt * kt;
                    }
                }
                let mut tail = 0f32;
                for (&qt, &kt) in qc.remainder().iter().zip(kc.remainder()) {
                    tail += qt * kt;
                }
                // pairwise lane reduction keeps the sum shallow
                let mut width = LANES;
                while width > 1 {
                    width /= 2;
                    let (lo, hi) = lanes.split_at_mut(width);
                    for (a, &b) in lo.iter_mut().zip(hi.iter()) {
                        *a += b;
                    }
                }
                scores.push((lanes[0] + tail) * scale);
            }
            // row-blocked max: lane-parallel over LANES-wide blocks
            let mut max = f32::NEG_INFINITY;
            let mut maxes = [f32::NEG_INFINITY; LANES];
            let mut blocks = scores.chunks_exact(LANES);
            for block in blocks.by_ref() {
                for (m, &s) in maxes.iter_mut().zip(block) {
                    *m = m.max(s);
                }
            }
            for &s in blocks.remainder() {
                max = max.max(s);
            }
            for &m in &maxes {
                max = max.max(m);
            }
            // f32 softmax; z >= 1 because the max element contributes 1
            let mut z = 0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                z += *s;
            }
            let inv_z = 1.0 / z;
            // weighted value rows accumulate straight into the output
            for (&e, &j) in scores.iter().zip(cols) {
                let w = e * inv_z;
                let vj = &v[j * d..(j + 1) * d];
                for (o, &x) in oi.iter_mut().zip(vj) {
                    *o += w * x;
                }
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------------- xla stub

/// Feature-gated landing slot for the accelerator (PJRT) lowering of a
/// [`CompiledPattern`].
///
/// The ROADMAP's multi-backend step ends with the CSR arrays handed to a
/// device gather kernel; [`XlaBackend::stage`] already exports them in
/// the i64 layout that lowering consumes, so the device kernel can land
/// behind this type without touching any call site.  Until it does,
/// execution falls back to the host [`Reference`] path — bit-identical
/// (declares [`Exactness::Bitwise`]), so selecting `--backend xla`
/// today is safe (just not yet faster).
#[cfg(feature = "xla")]
#[derive(Debug, Default, Clone, Copy)]
pub struct XlaBackend;

#[cfg(feature = "xla")]
impl XlaBackend {
    /// Stage a pattern for device transfer: `(row_offsets, cols)` as i64
    /// buffers (`n + 1` offsets, `nnz` key indices) — the two literals
    /// the PJRT sparse-gather lowering takes alongside Q/K/V.
    pub fn stage(pattern: &CompiledPattern) -> (Vec<i64>, Vec<i64>) {
        let offsets = pattern.offsets().iter().map(|&o| o as i64).collect();
        let cols = (0..pattern.n())
            .flat_map(|i| pattern.row(i).iter().map(|&j| j as i64))
            .collect();
        (offsets, cols)
    }
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn attention_rows(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        pattern: &CompiledPattern,
        rows: Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        // host fallback until the PJRT kernel lands; see the type docs
        sparse_attention_rows(q, k, v, d, pattern, rows, out)
    }
}

// ------------------------------------------------------------- registry

type BackendMap = BTreeMap<String, Arc<dyn Backend>>;

fn registry() -> &'static Mutex<BackendMap> {
    static REGISTRY: OnceLock<Mutex<BackendMap>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BackendMap = BTreeMap::new();
        map.insert("reference".to_string(), Arc::new(Reference));
        map.insert("blocked".to_string(), Arc::new(Blocked));
        map.insert("simd".to_string(), Arc::new(Simd));
        #[cfg(feature = "xla")]
        map.insert("xla".to_string(), Arc::new(XlaBackend));
        Mutex::new(map)
    })
}

/// Register a backend under [`Backend::name`]; errors if the name is
/// already taken (the built-ins `reference`/`blocked`/`simd` — plus
/// `xla` with the feature — are pre-registered).
pub fn register(backend: Arc<dyn Backend>) -> Result<()> {
    let name = backend.name().to_string();
    let mut map = registry().lock().unwrap_or_else(|e| e.into_inner());
    if map.contains_key(&name) {
        bail!("attention backend '{name}' is already registered");
    }
    map.insert(name, backend);
    Ok(())
}

/// Look a backend up by registry name (`None` if unknown; see [`names`]).
pub fn lookup(name: &str) -> Option<Arc<dyn Backend>> {
    registry().lock().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
}

/// Registered backend names, sorted — for `--backend` error messages.
pub fn names() -> Vec<String> {
    registry().lock().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionSpec;
    use crate::util::rng::Rng;

    fn random_qkv(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut mk = |rng: &mut Rng| (0..n * d).map(|_| rng.normal() as f32).collect();
        (mk(rng), mk(rng), mk(rng))
    }

    fn specs(n: usize) -> Vec<AttentionSpec> {
        vec![
            AttentionSpec::Full,
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::strided(2).unwrap(),
            AttentionSpec::routing(vec![(0..n).step_by(2).collect(), vec![1, 3]]),
            // fully-masked: no cluster admits anything
            AttentionSpec::routing(vec![]),
            AttentionSpec::union(vec![
                AttentionSpec::local(2).unwrap(),
                AttentionSpec::routing(vec![vec![0, 5, 6]]),
            ])
            .unwrap(),
        ]
    }

    #[test]
    fn builtins_declare_expected_exactness() {
        assert_eq!(Reference.exactness(), Exactness::Bitwise);
        assert_eq!(Blocked.exactness(), Exactness::Bitwise);
        assert_eq!(Simd.exactness(), Exactness::Ulps(Simd::ULPS));
        #[cfg(feature = "xla")]
        assert_eq!(XlaBackend.exactness(), Exactness::Bitwise);
    }

    #[test]
    fn exactness_join_and_display() {
        use Exactness::*;
        assert_eq!(Bitwise.join(Bitwise), Bitwise);
        assert_eq!(Bitwise.join(Ulps(7)), Ulps(7));
        assert_eq!(Ulps(7).join(Bitwise), Ulps(7));
        assert_eq!(Ulps(3).join(Ulps(4)), Ulps(7));
        assert_eq!(Ulps(u32::MAX).join(Ulps(1)), Ulps(u32::MAX), "saturating");
        assert_eq!(Bitwise.to_string(), "bitwise");
        assert_eq!(Ulps(4096).to_string(), "ulps(4096)");
    }

    #[test]
    fn ulps_comparator_handles_special_values() {
        use Exactness::*;
        // NaN: matches only NaN under Ulps, bit-equal NaN under Bitwise
        assert!(values_match(f32::NAN, f32::NAN, Ulps(0)));
        assert!(values_match(f32::NAN, f32::NAN, Bitwise), "same NaN bits");
        assert!(!values_match(f32::NAN, 1.0, Ulps(u32::MAX)));
        assert!(!values_match(1.0, f32::NAN, Ulps(u32::MAX)));
        // signed zero: 0 ulps apart but bitwise-distinct
        assert!(values_match(0.0, -0.0, Ulps(0)));
        assert!(!values_match(0.0, -0.0, Bitwise));
        assert_eq!(ulps_distance(0.0, -0.0), 0);
        // infinities match only themselves
        assert!(values_match(f32::INFINITY, f32::INFINITY, Ulps(0)));
        assert!(values_match(f32::NEG_INFINITY, f32::NEG_INFINITY, Ulps(0)));
        assert!(!values_match(f32::INFINITY, f32::NEG_INFINITY, Ulps(u32::MAX)));
        assert!(!values_match(f32::INFINITY, f32::MAX, Ulps(u32::MAX)));
        // the distance counts across zero without a gap
        let tiny = f32::from_bits(1); // smallest positive subnormal
        assert_eq!(ulps_distance(tiny, -tiny), 2);
    }

    #[test]
    fn ulps_boundary_is_exact() {
        // magnitude 256 so the absolute floor (k · 2⁻²³) is far below one
        // ulp (2⁻¹⁵ here) and cannot mask the boundary
        let k = 8u32;
        let a = 256.0f32;
        let pass = f32::from_bits(a.to_bits() + k);
        let fail = f32::from_bits(a.to_bits() + k + 1);
        assert_eq!(ulps_distance(a, pass), u64::from(k));
        assert!(values_match(a, pass, Exactness::Ulps(k)), "exactly k ulps passes");
        assert!(!values_match(a, fail, Exactness::Ulps(k)), "k + 1 ulps fails");
        // and the absolute floor admits near-zero differences the
        // relative view would reject
        let cancel = k as f32 * f32::EPSILON;
        assert!(ulps_distance(0.0, cancel) > u64::from(k));
        assert!(values_match(0.0, cancel, Exactness::Ulps(k)));
    }

    #[test]
    fn assert_outputs_match_names_first_offender() {
        let e = [1.0f32, 2.0, 3.0];
        let mut a = e;
        assert_outputs_match(&e, &a, Exactness::Bitwise, "ctx").unwrap();
        a[1] = f32::from_bits(a[1].to_bits() + 1);
        let err = assert_outputs_match(&e, &a, Exactness::Bitwise, "ctx").unwrap_err();
        assert!(err.to_string().contains("index 1"), "{err}");
        assert_outputs_match(&e, &a, Exactness::Ulps(1), "ctx").unwrap();
        let err = assert_outputs_match(&e, &a[..2], Exactness::Bitwise, "ctx").unwrap_err();
        assert!(err.to_string().contains("length mismatch"), "{err}");
    }

    #[test]
    fn blocked_is_bit_identical_to_reference() {
        let mut rng = Rng::new(77);
        for n in [0usize, 1, 2, 5, 17, 33] {
            // d sweeps across the tile boundary cases (d=1, d%4 != 0, big)
            for d in [1usize, 3, 4, 7, 16] {
                let (q, k, v) = random_qkv(&mut rng, n, d);
                for spec in specs(n) {
                    let p = spec.compile(n);
                    let a = Reference.attention(&q, &k, &v, d, &p).unwrap();
                    let b = Blocked.attention(&q, &k, &v, d, &p).unwrap();
                    assert_outputs_match(&a, &b, Blocked.exactness(), "blocked vs reference")
                        .unwrap_or_else(|e| panic!("n={n} d={d} spec={spec:?}: {e}"));
                }
            }
        }
    }

    #[test]
    fn simd_matches_reference_within_declared_ulps() {
        let mut rng = Rng::new(78);
        for n in [0usize, 1, 2, 5, 17, 33] {
            // d sweeps across the lane boundary cases (d=1, tail-only,
            // d=8 exact, d%8 != 0, multi-chunk)
            for d in [1usize, 3, 8, 11, 16, 24] {
                let (q, k, v) = random_qkv(&mut rng, n, d);
                for spec in specs(n) {
                    let p = spec.compile(n);
                    let a = Reference.attention(&q, &k, &v, d, &p).unwrap();
                    let b = Simd.attention(&q, &k, &v, d, &p).unwrap();
                    assert_outputs_match(&a, &b, Simd.exactness(), "simd vs reference")
                        .unwrap_or_else(|e| panic!("n={n} d={d} spec={spec:?}: {e}"));
                }
            }
        }
    }

    #[test]
    fn simd_masked_rows_zero_and_shapes_validate() {
        // rows with 0..=5 columns exercise the max-block remainder too
        let spec = AttentionSpec::routing(vec![vec![0, 1, 2, 3, 4, 5]]);
        let p = spec.compile(8);
        assert!(p.row(6).is_empty() && p.row(7).is_empty());
        let mut rng = Rng::new(6);
        let (q, k, v) = random_qkv(&mut rng, 8, 4);
        let out = Simd.attention(&q, &k, &v, 4, &p).unwrap();
        assert!(out[6 * 4..].iter().all(|&x| x == 0.0), "masked rows stay zero");
        assert!(out.iter().all(|x| x.is_finite()), "no NaN/inf leaks");
        // identical shape validation to every other backend
        let p2 = AttentionSpec::Full.compile(2);
        assert!(Simd.attention(&[0.0; 3], &[0.0; 4], &[0.0; 4], 2, &p2).is_err());
        assert!(Simd.attention(&[], &[], &[], 0, &p2).is_err());
        let mut out = [0f32; 2];
        assert!(Simd
            .attention_rows(&[0.0; 4], &[0.0; 4], &[0.0; 4], 2, &p2, 1..3, &mut out)
            .is_err());
    }

    #[test]
    fn simd_is_deterministic_across_calls() {
        // fast math relaxes the match to Reference, not run-to-run
        // reproducibility: the same inputs must give the same bits
        let mut rng = Rng::new(41);
        let (q, k, v) = random_qkv(&mut rng, 33, 11);
        let p = AttentionSpec::local(5).unwrap().compile(33);
        let a = Simd.attention(&q, &k, &v, 11, &p).unwrap();
        let b = Simd.attention(&q, &k, &v, 11, &p).unwrap();
        assert_outputs_match(&a, &b, Exactness::Bitwise, "simd reruns").unwrap();
    }

    #[test]
    fn blocked_handles_masked_rows_and_tile_remainders() {
        // rows with 0, 1, 2, 3, 4, 5 columns exercise every tile shape
        let spec = AttentionSpec::routing(vec![vec![0, 1, 2, 3, 4, 5]]);
        let p = spec.compile(8);
        assert!(p.row(6).is_empty() && p.row(7).is_empty());
        let mut rng = Rng::new(5);
        let (q, k, v) = random_qkv(&mut rng, 8, 4);
        let out = Blocked.attention(&q, &k, &v, 4, &p).unwrap();
        assert_eq!(out, Reference.attention(&q, &k, &v, 4, &p).unwrap());
        assert!(out[6 * 4..].iter().all(|&x| x == 0.0), "masked rows stay zero");
    }

    #[test]
    fn blocked_validates_shapes_like_reference() {
        let p = AttentionSpec::Full.compile(2);
        assert!(Blocked.attention(&[0.0; 3], &[0.0; 4], &[0.0; 4], 2, &p).is_err());
        assert!(Blocked.attention(&[], &[], &[], 0, &p).is_err());
        let mut out = [0f32; 2];
        assert!(Blocked
            .attention_rows(&[0.0; 4], &[0.0; 4], &[0.0; 4], 2, &p, 1..3, &mut out)
            .is_err());
    }

    #[test]
    fn registry_serves_builtins_and_rejects_duplicates() {
        let r = lookup("reference").expect("built-in");
        assert_eq!(r.name(), "reference");
        let b = lookup("blocked").expect("built-in");
        assert_eq!(b.name(), "blocked");
        let s = lookup("simd").expect("built-in");
        assert_eq!(s.name(), "simd");
        assert_eq!(s.exactness(), Exactness::Ulps(Simd::ULPS));
        assert!(lookup("warp-drive").is_none());
        let names = names();
        assert!(names.contains(&"reference".to_string()));
        assert!(names.contains(&"blocked".to_string()));
        assert!(names.contains(&"simd".to_string()));
        assert!(register(Arc::new(Reference)).is_err(), "duplicate name must be rejected");
    }

    #[test]
    fn custom_backends_can_register() {
        /// A deliberately silly wrapper proving third-party registration.
        #[derive(Debug)]
        struct Custom;
        impl Backend for Custom {
            fn name(&self) -> &'static str {
                "custom-test-backend"
            }
            fn attention_rows(
                &self,
                q: &[f32],
                k: &[f32],
                v: &[f32],
                d: usize,
                pattern: &CompiledPattern,
                rows: std::ops::Range<usize>,
                out: &mut [f32],
            ) -> Result<()> {
                sparse_attention_rows(q, k, v, d, pattern, rows, out)
            }
        }
        register(Arc::new(Custom)).unwrap();
        let found = lookup("custom-test-backend").expect("registered");
        assert_eq!(found.exactness(), Exactness::Bitwise, "default contract is bitwise");
        let p = AttentionSpec::local(2).unwrap().compile(4);
        let mut rng = Rng::new(9);
        let (q, k, v) = random_qkv(&mut rng, 4, 2);
        assert_eq!(
            found.attention(&q, &k, &v, 2, &p).unwrap(),
            Reference.attention(&q, &k, &v, 2, &p).unwrap()
        );
    }
}

//! Compiled sparsity patterns — phase two of the spec→compile pipeline.
//!
//! A [`CompiledPattern`] is an [`AttentionSpec`](super::AttentionSpec)
//! materialized for one sequence length as a CSR index set: row offsets
//! plus sorted per-query key indices, with an optional cluster id per
//! entry for routed keys.  Compiling once buys every consumer the same
//! semantics at the right complexity: `allowed` is a binary search
//! (O(log w) instead of the old linear `Vec::contains` scans), `nnz` and
//! `density` read the CSR tail pointer (O(1)), and `row(i)` hands out the
//! attend-set as a zero-allocation slice.  The Figure-1 ASCII/CSV
//! renderers and the exact-FLOP `cost` model live here so there is exactly
//! one source of truth for "which keys may query i attend to".
//!
//! Long-context additions (the banded-compilation refactor): a
//! [`PatternBand`] is the same CSR content for one contiguous row range
//! only — bit-identical to slicing a monolithic compile — so 100k–1M
//! token patterns can be materialized band by band instead of all at
//! once ([`super::AttentionSpec::compile_band`] /
//! [`ChunkedPattern`](super::spec::ChunkedPattern)); a [`MemoryBudget`]
//! is the shared byte meter the pattern caches charge resident
//! [`CompiledPattern::heap_bytes`] against and spill over.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Sentinel cluster id for entries admitted by a non-routing scheme
/// (public so engine consumers iterating raw cluster slices via
/// [`CompiledPattern::rows`] can tell routed from unrouted entries).
pub const NO_CLUSTER: u32 = u32::MAX;

/// A compiled sparsity pattern over a sequence of length `n`, stored as
/// CSR: `cols[row_offsets[i]..row_offsets[i+1]]` is S_i, sorted ascending.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompiledPattern {
    n: usize,
    /// `n + 1` offsets into `cols`/`cluster_ids`.
    row_offsets: Vec<usize>,
    /// Key indices, sorted ascending within each row.
    cols: Vec<usize>,
    /// Per-entry cluster id (`NO_CLUSTER` for non-routed entries).
    cluster_ids: Vec<u32>,
}

/// Per-row attend-set size summary (for `rtx figure1 --stats`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowStats {
    /// Smallest attend-set size across rows.
    pub min: usize,
    /// Mean attend-set size (`nnz / n`).
    pub mean: f64,
    /// Largest attend-set size across rows.
    pub max: usize,
}

impl CompiledPattern {
    /// Pack sorted, deduped per-row `(key, cluster)` entries into CSR.
    pub(crate) fn from_rows(n: usize, rows: Vec<Vec<(usize, u32)>>) -> CompiledPattern {
        debug_assert_eq!(rows.len(), n);
        let nnz: usize = rows.iter().map(Vec::len).sum();
        let mut row_offsets = Vec::with_capacity(n + 1);
        let mut cols = Vec::with_capacity(nnz);
        let mut cluster_ids = Vec::with_capacity(nnz);
        row_offsets.push(0);
        for row in &rows {
            for &(j, c) in row {
                cols.push(j);
                cluster_ids.push(c);
            }
            row_offsets.push(cols.len());
        }
        CompiledPattern { n, row_offsets, cols, cluster_ids }
    }

    /// Assemble from raw CSR arrays (band concatenation / band padding).
    /// Callers guarantee the shape invariants; debug builds assert them.
    pub(crate) fn from_parts(
        n: usize,
        row_offsets: Vec<usize>,
        cols: Vec<usize>,
        cluster_ids: Vec<u32>,
    ) -> CompiledPattern {
        debug_assert_eq!(row_offsets.len(), n + 1);
        debug_assert_eq!(cols.len(), cluster_ids.len());
        debug_assert_eq!(*row_offsets.last().expect("n + 1 >= 1 offsets"), cols.len());
        debug_assert!(row_offsets.windows(2).all(|w| w[0] <= w[1]));
        CompiledPattern { n, row_offsets, cols, cluster_ids }
    }

    /// Heap bytes owned by the CSR arrays — what one resident pattern
    /// costs a [`MemoryBudget`].  Exact for the values stored (offsets +
    /// cols at `usize` width, cluster ids at `u32`); allocator slack is
    /// deliberately not modeled so the number is deterministic.
    pub fn heap_bytes(&self) -> usize {
        self.row_offsets.len() * std::mem::size_of::<usize>()
            + self.cols.len() * std::mem::size_of::<usize>()
            + self.cluster_ids.len() * std::mem::size_of::<u32>()
    }

    /// Sequence length the pattern was compiled for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// CSR row offsets (`n + 1` entries; `offsets()[i]` is the nnz before
    /// row i).  Crate-internal: the engine's sharding uses it as a prefix
    /// sum for O(1) per-range nnz and O(log n) balanced split points.
    pub(crate) fn offsets(&self) -> &[usize] {
        &self.row_offsets
    }

    /// Total non-zero entries of the attention matrix — O(1) from CSR.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// The attend-set S_i as a sorted slice; empty for out-of-range `i`
    /// (so `n = 0` is a total no-op rather than an underflow).
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let p = AttentionSpec::local(3).unwrap().compile(8);
    /// assert_eq!(p.row(5), &[3, 4, 5]);
    /// assert_eq!(p.row(0), &[0]);
    /// assert!(p.row(99).is_empty(), "out-of-range rows are empty, not a panic");
    /// ```
    pub fn row(&self, i: usize) -> &[usize] {
        if i >= self.n {
            return &[];
        }
        &self.cols[self.row_offsets[i]..self.row_offsets[i + 1]]
    }

    /// May query `i` attend to key `j`?  O(log |S_i|) binary search.
    pub fn allowed(&self, i: usize, j: usize) -> bool {
        self.row(i).binary_search(&j).is_ok()
    }

    /// Per-entry cluster ids aligned with `row(i)` ([`NO_CLUSTER`] for
    /// unrouted entries); empty for out-of-range `i`.
    pub fn row_clusters(&self, i: usize) -> &[u32] {
        if i >= self.n {
            return &[];
        }
        &self.cluster_ids[self.row_offsets[i]..self.row_offsets[i + 1]]
    }

    /// Batched zero-allocation row gather: iterate `(i, keys, clusters)`
    /// for every query row in `range` (clamped to `0..n`), handing out
    /// slices straight from the CSR arrays.  This is the engine's
    /// per-shard evaluation primitive — see
    /// [`crate::attention::engine`].
    pub fn rows(&self, range: std::ops::Range<usize>) -> RowIter<'_> {
        let end = range.end.min(self.n);
        let start = range.start.min(end);
        RowIter { pattern: self, range: start..end }
    }

    /// Cluster id that routed key `j` into S_i, if any.
    pub fn cluster_of(&self, i: usize, j: usize) -> Option<u32> {
        if i >= self.n {
            return None;
        }
        let lo = self.row_offsets[i];
        match self.cols[lo..self.row_offsets[i + 1]].binary_search(&j) {
            Ok(off) => match self.cluster_ids[lo + off] {
                NO_CLUSTER => None,
                c => Some(c),
            },
            Err(_) => None,
        }
    }

    /// Sparsity fraction (nnz / full causal nnz); 0.0 for `n = 0`.
    ///
    /// The full-causal denominator `n·(n+1)/2` is computed in `u128`: in
    /// `usize` it overflows on 32-bit targets from n = 92682 and on
    /// 64-bit targets for n near 2⁶⁴ — exactly the long-context regime
    /// the banded pipeline targets.
    pub fn density(&self) -> f64 {
        let full = self.n as u128 * (self.n as u128 + 1) / 2;
        if full == 0 {
            0.0
        } else {
            self.nnz() as f64 / full as f64
        }
    }

    /// Exact multiply-accumulate count for one attention pass over this
    /// pattern with head dimension `d`: QK^T and PV each touch every
    /// materialized (query, key) pair once (`2 · nnz · d`), saturating at
    /// `u64::MAX` instead of wrapping when nnz·d overflows 64 bits.
    pub fn cost(&self, d: usize) -> u64 {
        let macs = 2u128 * self.nnz() as u128 * d as u128;
        u64::try_from(macs).unwrap_or(u64::MAX)
    }

    /// Attention-matrix entries instantiated (memory model).
    pub fn memory(&self) -> u64 {
        self.nnz() as u64
    }

    /// Largest per-cluster entry count: the nnz of the busiest cluster's
    /// attention block, ignoring unrouted ([`NO_CLUSTER`]) entries; 0 for
    /// a pattern with no routed entries.  This is the load-balance
    /// observable behind the expert-choice family — bounded by
    /// `capacity·(capacity+1)/2` there, unbounded for token-choice
    /// routing — surfaced as `max_cluster_nnz` in the serve `--json`
    /// schema.
    pub fn max_cluster_nnz(&self) -> usize {
        let mut counts: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
        for &c in &self.cluster_ids {
            if c != NO_CLUSTER {
                *counts.entry(c).or_insert(0) += 1;
            }
        }
        counts.into_values().max().unwrap_or(0)
    }

    /// Every admitted key is causal (j <= i).  True by construction; kept
    /// as a checkable invariant for tests.
    pub fn is_causal(&self) -> bool {
        (0..self.n).all(|i| self.row(i).iter().all(|&j| j <= i))
    }

    /// Rows are strictly ascending (sorted, duplicate-free).
    pub fn rows_sorted(&self) -> bool {
        (0..self.n).all(|i| self.row(i).windows(2).all(|w| w[0] < w[1]))
    }

    /// Min / mean / max attend-set size across rows.
    pub fn row_stats(&self) -> RowStats {
        if self.n == 0 {
            return RowStats { min: 0, mean: 0.0, max: 0 };
        }
        let mut min = usize::MAX;
        let mut max = 0usize;
        for i in 0..self.n {
            let len = self.row(i).len();
            min = min.min(len);
            max = max.max(len);
        }
        RowStats { min, mean: self.nnz() as f64 / self.n as f64, max }
    }

    /// ASCII rendering of the attention scheme, Figure-1 style: rows are
    /// outputs, columns inputs; routed entries are drawn with one letter
    /// per cluster, unrouted admitted entries with '#'.
    ///
    /// Clipped to [`RENDER_CLIP`] rows: the unclipped render is O(n²)
    /// bytes (~10 GB at n = 100k), so big patterns get a truncation
    /// marker instead of an OOM.  Use
    /// [`render_ascii_clipped`](Self::render_ascii_clipped) to pick the
    /// window explicitly.
    pub fn render_ascii(&self) -> String {
        self.render_ascii_clipped(RENDER_CLIP)
    }

    /// ASCII rendering of the first `max_rows` query rows (and, by
    /// causality, the first `max_rows` key columns — no admitted entry of
    /// a rendered row lies outside the clipped square).  When rows are
    /// clipped a final marker line `… (showing R of N rows)` is appended.
    pub fn render_ascii_clipped(&self, max_rows: usize) -> String {
        let rows = self.n.min(max_rows);
        let mut out = String::with_capacity(rows * (rows + 1) + 48);
        for i in 0..rows {
            let (lo, hi) = (self.row_offsets[i], self.row_offsets[i + 1]);
            let mut next = lo;
            for j in 0..rows {
                let ch = if next < hi && self.cols[next] == j {
                    let c = self.cluster_ids[next];
                    next += 1;
                    if c == NO_CLUSTER {
                        '#'
                    } else {
                        (b'A' + (c % 26) as u8) as char
                    }
                } else if j <= i {
                    '·'
                } else {
                    ' '
                };
                out.push(ch);
            }
            out.push('\n');
        }
        if rows < self.n {
            out.push_str(&format!("… (showing {rows} of {} rows)\n", self.n));
        }
        out
    }

    /// CSV rendering: `query,key,cluster` rows for every non-zero entry
    /// (cluster field empty for unrouted entries).
    ///
    /// Clipped to [`RENDER_CLIP`] rows for the same O(n²)-output reason
    /// as [`render_ascii`](Self::render_ascii); use
    /// [`render_csv_clipped`](Self::render_csv_clipped) to pick the
    /// window explicitly.
    pub fn render_csv(&self) -> String {
        self.render_csv_clipped(RENDER_CLIP)
    }

    /// CSV rendering of the first `max_rows` query rows.  When rows are
    /// clipped a trailing comment line
    /// `# truncated: rows R..N omitted` is appended.
    pub fn render_csv_clipped(&self, max_rows: usize) -> String {
        let rows = self.n.min(max_rows);
        let mut out = String::from("query,key,cluster\n");
        for i in 0..rows {
            for e in self.row_offsets[i]..self.row_offsets[i + 1] {
                let j = self.cols[e];
                match self.cluster_ids[e] {
                    NO_CLUSTER => out.push_str(&format!("{i},{j},\n")),
                    c => out.push_str(&format!("{i},{j},{c}\n")),
                }
            }
        }
        if rows < self.n {
            out.push_str(&format!("# truncated: rows {rows}..{} omitted\n", self.n));
        }
        out
    }
}

/// Default row clip for [`CompiledPattern::render_ascii`] /
/// [`CompiledPattern::render_csv`]: enough for every Figure-1-sized
/// render to be unclipped while bounding the worst case at ~0.3 MB.
pub const RENDER_CLIP: usize = 512;

/// Iterator over `(i, keys, clusters)` row slices; see
/// [`CompiledPattern::rows`].
#[derive(Debug, Clone)]
pub struct RowIter<'a> {
    pattern: &'a CompiledPattern,
    range: std::ops::Range<usize>,
}

impl<'a> Iterator for RowIter<'a> {
    type Item = (usize, &'a [usize], &'a [u32]);

    fn next(&mut self) -> Option<Self::Item> {
        let i = self.range.next()?;
        Some((i, self.pattern.row(i), self.pattern.row_clusters(i)))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.range.size_hint()
    }
}

impl<'a> ExactSizeIterator for RowIter<'a> {}

/// One contiguous row band of a compiled pattern: the same CSR content a
/// monolithic [`AttentionSpec::compile`](super::AttentionSpec::compile)
/// would produce for rows `start..end`, with offsets rebased to the band
/// start so only O(band) memory is resident.  Built by
/// [`AttentionSpec::compile_band`](super::AttentionSpec::compile_band);
/// bit-identity with monolithic slices is property-tested.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PatternBand {
    /// Sequence length of the *whole* pattern this band belongs to.
    n: usize,
    /// First absolute query row covered by the band.
    start: usize,
    /// `len + 1` offsets, rebased so `row_offsets[0] == 0`.
    row_offsets: Vec<usize>,
    cols: Vec<usize>,
    cluster_ids: Vec<u32>,
}

impl PatternBand {
    /// Pack sorted per-row entries for absolute rows `start..start+rows.len()`.
    pub(crate) fn from_rows(
        n: usize,
        start: usize,
        rows: Vec<Vec<(usize, u32)>>,
    ) -> PatternBand {
        debug_assert!(start + rows.len() <= n);
        let nnz: usize = rows.iter().map(Vec::len).sum();
        let mut row_offsets = Vec::with_capacity(rows.len() + 1);
        let mut cols = Vec::with_capacity(nnz);
        let mut cluster_ids = Vec::with_capacity(nnz);
        row_offsets.push(0);
        for row in &rows {
            for &(j, c) in row {
                cols.push(j);
                cluster_ids.push(c);
            }
            row_offsets.push(cols.len());
        }
        PatternBand { n, start, row_offsets, cols, cluster_ids }
    }

    /// Sequence length of the whole pattern (not the band length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// First absolute query row covered.
    pub fn start(&self) -> usize {
        self.start
    }

    /// One past the last absolute query row covered.
    pub fn end(&self) -> usize {
        self.start + self.len()
    }

    /// Number of query rows in the band.
    pub fn len(&self) -> usize {
        self.row_offsets.len() - 1
    }

    /// True when the band covers zero rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-zero entries in the band — O(1) from the CSR tail.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Attend-set for *absolute* row `i`; empty outside the band (same
    /// out-of-range contract as [`CompiledPattern::row`]).
    pub fn row(&self, i: usize) -> &[usize] {
        match i.checked_sub(self.start) {
            Some(r) if r < self.len() => &self.cols[self.row_offsets[r]..self.row_offsets[r + 1]],
            _ => &[],
        }
    }

    /// Cluster ids aligned with [`row`](Self::row); empty outside the band.
    pub fn row_clusters(&self, i: usize) -> &[u32] {
        match i.checked_sub(self.start) {
            Some(r) if r < self.len() => {
                &self.cluster_ids[self.row_offsets[r]..self.row_offsets[r + 1]]
            }
            _ => &[],
        }
    }

    /// Heap bytes owned by the band's CSR arrays — the
    /// [`MemoryBudget`] charge for keeping it resident.
    pub fn heap_bytes(&self) -> usize {
        self.row_offsets.len() * std::mem::size_of::<usize>()
            + self.cols.len() * std::mem::size_of::<usize>()
            + self.cluster_ids.len() * std::mem::size_of::<u32>()
    }

    /// Exact MAC count for evaluating just this band (`2 · nnz · d`),
    /// saturating like [`CompiledPattern::cost`].
    pub fn cost(&self, d: usize) -> u64 {
        u64::try_from(2u128 * self.nnz() as u128 * d as u128).unwrap_or(u64::MAX)
    }

    /// Materialize an n-row [`CompiledPattern`] whose rows outside the
    /// band are empty and whose band rows are bit-identical to a
    /// monolithic compile.  This is how banded evaluation reuses every
    /// existing [`Backend`](super::Backend) unchanged: evaluating the
    /// padded pattern over `start..end` touches exactly the band's CSR
    /// entries, so backends see the same slices a monolithic pattern
    /// would hand them.
    pub fn to_pattern(&self) -> CompiledPattern {
        let nnz = self.nnz();
        let mut row_offsets = Vec::with_capacity(self.n + 1);
        row_offsets.resize(self.start + 1, 0);
        row_offsets.extend_from_slice(&self.row_offsets[1..]);
        row_offsets.resize(self.n + 1, nnz);
        CompiledPattern::from_parts(
            self.n,
            row_offsets,
            self.cols.clone(),
            self.cluster_ids.clone(),
        )
    }
}

/// Shared byte meter for resident compiled patterns, bands, and member
/// lists.  Cloning shares the meter (it is an `Arc` internally), so one
/// budget can govern `PatternCache`, `EpochCache`, `MemberCache`, and
/// `ChunkedPattern` instances at once; caches [`charge`](Self::charge)
/// on insert, [`release`](Self::release) on evict/drop, and consult
/// [`over_budget`](Self::over_budget) to decide when to LRU-spill.
///
/// The budget is a *soft* cap enforced by the caches, not the meter:
/// pinned entries and the entry being returned from an in-flight lookup
/// are never spilled, so `resident` may transiently exceed `max_bytes`
/// by at most those protected entries.
#[derive(Debug, Clone)]
pub struct MemoryBudget {
    inner: Arc<BudgetInner>,
}

#[derive(Debug)]
struct BudgetInner {
    /// `None` = unbounded (metering only, never over budget).
    max_bytes: Option<usize>,
    resident: AtomicUsize,
    peak: AtomicUsize,
    evicted: AtomicU64,
}

impl MemoryBudget {
    /// A metering-only budget that is never over budget.
    pub fn unbounded() -> MemoryBudget {
        MemoryBudget {
            inner: Arc::new(BudgetInner {
                max_bytes: None,
                resident: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                evicted: AtomicU64::new(0),
            }),
        }
    }

    /// A budget capped at `max_bytes` resident pattern bytes.
    pub fn bytes(max_bytes: usize) -> MemoryBudget {
        MemoryBudget {
            inner: Arc::new(BudgetInner {
                max_bytes: Some(max_bytes),
                resident: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                evicted: AtomicU64::new(0),
            }),
        }
    }

    /// The cap, if any.
    pub fn max_bytes(&self) -> Option<usize> {
        self.inner.max_bytes
    }

    /// Meter `bytes` as newly resident (updates the peak watermark).
    pub fn charge(&self, bytes: usize) {
        let now = self.inner.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Meter `bytes` as freed (eviction or drop), counting them toward
    /// [`evicted`](Self::evicted).
    pub fn release(&self, bytes: usize) {
        self.inner.resident.fetch_sub(bytes, Ordering::Relaxed);
        self.inner.evicted.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Bytes currently metered as resident.
    pub fn resident(&self) -> usize {
        self.inner.resident.load(Ordering::Relaxed)
    }

    /// High-water mark of [`resident`](Self::resident) over the budget's
    /// lifetime.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Total bytes ever [`release`](Self::release)d.
    pub fn evicted(&self) -> u64 {
        self.inner.evicted.load(Ordering::Relaxed)
    }

    /// True when a cap is set and resident bytes exceed it — the signal
    /// for caches to LRU-spill.
    pub fn over_budget(&self) -> bool {
        match self.inner.max_bytes {
            Some(max) => self.resident() > max,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionSpec;

    #[test]
    fn full_attends_everything_causal() {
        let p = AttentionSpec::Full.compile(8);
        assert_eq!(p.row(5), &[0, 1, 2, 3, 4, 5]);
        assert!(p.is_causal());
        assert_eq!(p.nnz(), 36);
    }

    #[test]
    fn local_window_bound() {
        let p = AttentionSpec::local(4).unwrap().compile(16);
        assert_eq!(p.row(10), &[7, 8, 9, 10]);
        assert_eq!(p.row(1), &[0, 1]);
        assert!(p.is_causal());
    }

    #[test]
    fn block_local_two_blocks() {
        let p = AttentionSpec::block_local(4).unwrap().compile(16);
        // query 9 (block 2) sees blocks 1 and 2, causally
        assert_eq!(p.row(9), &[4, 5, 6, 7, 8, 9]);
        // block 0 sees only itself
        assert_eq!(p.row(2), &[0, 1, 2]);
    }

    #[test]
    fn strided_pattern() {
        let p = AttentionSpec::strided(4).unwrap().compile(16);
        assert_eq!(p.row(9), &[1, 5, 9]);
        assert!(p.is_causal());
    }

    #[test]
    fn routing_same_cluster_only() {
        let spec = AttentionSpec::routing(vec![vec![0, 2, 5], vec![1, 3, 4, 6, 7]]);
        let p = spec.compile(8);
        assert!(p.allowed(5, 2));
        assert!(p.allowed(5, 0));
        assert!(!p.allowed(5, 3)); // different cluster
        assert!(!p.allowed(2, 5)); // causality
        assert_eq!(p.cluster_of(5, 2), Some(0));
        assert_eq!(p.cluster_of(7, 3), Some(1));
        assert_eq!(p.cluster_of(5, 3), None);
        assert!(p.is_causal());
    }

    #[test]
    fn max_cluster_nnz_counts_the_busiest_cluster() {
        // cluster 0 has 3 members (6 causal pairs), cluster 1 has 2 (3 pairs)
        let p = AttentionSpec::routing(vec![vec![0, 2, 5], vec![1, 3]]).compile(8);
        assert_eq!(p.max_cluster_nnz(), 6);
        // unrouted patterns report 0 (every entry is NO_CLUSTER)
        assert_eq!(AttentionSpec::Full.compile(8).max_cluster_nnz(), 0);
        assert_eq!(AttentionSpec::Full.compile(0).max_cluster_nnz(), 0);
        // expert-choice blocks are bounded by capacity*(capacity+1)/2
        let p = AttentionSpec::expert_choice(vec![vec![0, 1, 4], vec![2, 3]], 3)
            .unwrap()
            .compile(8);
        assert_eq!(p.max_cluster_nnz(), 6);
        assert!(p.max_cluster_nnz() <= 3 * 4 / 2);
    }

    #[test]
    fn density_ordering_matches_paper() {
        // local(w) and routing(k=sqrt n) are sparse; full is dense
        let n = 64;
        let full = AttentionSpec::Full.compile(n);
        let local = AttentionSpec::local(8).unwrap().compile(n);
        let routing = AttentionSpec::routing_balanced(n, 8).unwrap().compile(n);
        assert!(local.density() < full.density());
        assert!(routing.density() < full.density());
        assert!((full.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_render_shapes() {
        let p = AttentionSpec::block_local(2).unwrap().compile(8);
        let art = p.render_ascii();
        assert_eq!(art.lines().count(), 8);
        assert!(art.lines().all(|l| l.chars().count() == 8));
        // first char of first row is '#': token 0 attends to itself
        assert_eq!(art.lines().next().unwrap().chars().next().unwrap(), '#');
    }

    #[test]
    fn csv_render_contains_entries() {
        let p = AttentionSpec::routing(vec![vec![0, 1, 2, 3]]).compile(4);
        let csv = p.render_csv();
        assert!(csv.contains("3,0,0"));
        assert_eq!(csv.lines().count(), 1 + p.nnz());
    }

    #[test]
    fn empty_and_singleton_sequences() {
        // n = 0 used to underflow (attend_set evaluated n - 1) and divide
        // by zero (density over n*(n+1)/2 = 0); now a total no-op
        for spec in [
            AttentionSpec::Full,
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::block_local(2).unwrap(),
            AttentionSpec::strided(2).unwrap(),
            AttentionSpec::routing(vec![vec![0, 1]]),
            AttentionSpec::union(vec![AttentionSpec::Full, AttentionSpec::local(1).unwrap()])
                .unwrap(),
        ] {
            let p0 = spec.compile(0);
            assert_eq!(p0.nnz(), 0);
            assert_eq!(p0.density(), 0.0);
            assert_eq!(p0.row(0), &[] as &[usize]);
            assert!(!p0.allowed(0, 0));
            assert_eq!(p0.render_ascii(), "");
            assert_eq!(p0.render_csv(), "query,key,cluster\n");
            assert_eq!(p0.row_stats(), RowStats { min: 0, mean: 0.0, max: 0 });

            let p1 = spec.compile(1);
            assert!(p1.is_causal());
            assert!(p1.nnz() <= 1);
            assert!(p1.density() <= 1.0);
        }
        // every positional kind admits the diagonal at n = 1
        assert_eq!(AttentionSpec::local(5).unwrap().compile(1).nnz(), 1);
    }

    #[test]
    fn union_nnz_pinned_against_parts() {
        let n = 16;
        let local = AttentionSpec::local(4).unwrap();
        let routing = AttentionSpec::routing(vec![vec![0, 5, 9, 13], vec![2, 3, 11]]);
        let pl = local.compile(n);
        let pr = routing.compile(n);
        let pu = AttentionSpec::union(vec![local, routing]).unwrap().compile(n);
        let mut expect = 0usize;
        for i in 0..n {
            for j in 0..=i {
                if pl.allowed(i, j) || pr.allowed(i, j) {
                    expect += 1;
                }
            }
        }
        assert_eq!(pu.nnz(), expect, "union nnz must equal the set union of the parts");
        assert!(pu.nnz() >= pl.nnz().max(pr.nnz()));
        assert!(pu.nnz() <= pl.nnz() + pr.nnz());
        assert!(pu.is_causal() && pu.rows_sorted());
        // routed entries keep their cluster letter through the union
        assert_eq!(pu.cluster_of(5, 0), Some(0));
        let art = pu.render_ascii();
        assert!(art.contains('A') && art.contains('#'));
    }

    #[test]
    fn intersect_full_is_identity() {
        let n = 12;
        let local = AttentionSpec::local(3).unwrap();
        let pi = AttentionSpec::intersect(vec![AttentionSpec::Full, local.clone()])
            .unwrap()
            .compile(n);
        assert_eq!(pi, local.compile(n));
    }

    #[test]
    fn row_stats_summary() {
        let p = AttentionSpec::local(4).unwrap().compile(16);
        let s = p.row_stats();
        assert_eq!(s.min, 1); // row 0
        assert_eq!(s.max, 4);
        assert!((s.mean - p.nnz() as f64 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn cost_is_exact_from_nnz() {
        let p = AttentionSpec::local(8).unwrap().compile(64);
        assert_eq!(p.cost(64), 2 * p.nnz() as u64 * 64);
        assert_eq!(p.memory(), p.nnz() as u64);
    }

    #[test]
    fn density_and_cost_survive_width_boundaries() {
        // n = 200_000: n·(n+1)/2 ≈ 2·10¹⁰ overflows 32-bit usize (the
        // old code's width), so pin the u128 path against exact f64 math
        // on a synthetic one-entry pattern (offsets built directly —
        // compiling 200k rows of Full would be gigabytes).
        let n = 200_000usize;
        let mut row_offsets = vec![0usize; n + 1];
        for o in row_offsets.iter_mut().skip(1) {
            *o = 1;
        }
        let p = CompiledPattern::from_parts(n, row_offsets, vec![0], vec![NO_CLUSTER]);
        let expect = 1.0 / (n as f64 * (n as f64 + 1.0) / 2.0);
        assert!((p.density() - expect).abs() < expect * 1e-12);
        // cost saturates instead of wrapping: 2·1·usize::MAX > u64::MAX.
        assert_eq!(p.cost(usize::MAX), u64::MAX);
        assert_eq!(p.cost(32), 64, "small d stays exact");
    }

    #[test]
    fn renders_clip_with_truncation_markers() {
        let p = AttentionSpec::local(3).unwrap().compile(16);
        let art = p.render_ascii_clipped(4);
        let lines: Vec<&str> = art.lines().collect();
        assert_eq!(lines.len(), 5, "4 rendered rows + marker");
        assert!(lines[..4].iter().all(|l| l.chars().count() == 4));
        assert_eq!(lines[4], "… (showing 4 of 16 rows)");
        // clipped rows are byte-identical to the unclipped render's prefix
        let full = p.render_ascii_clipped(usize::MAX);
        for (clipped, full_row) in lines[..4].iter().zip(full.lines()) {
            assert_eq!(*clipped, &full_row[..clipped.len()]);
        }
        assert!(!full.contains("showing"), "unclipped render has no marker");

        let csv = p.render_csv_clipped(2);
        assert_eq!(csv.lines().count(), 1 + 3 + 1, "header + nnz(rows 0..2) + marker");
        assert!(csv.ends_with("# truncated: rows 2..16 omitted\n"));
        assert!(!p.render_csv_clipped(16).contains("truncated"));

        // defaults clip at RENDER_CLIP: small patterns unchanged, huge
        // ones bounded (and causality means no rendered content is lost)
        assert_eq!(p.render_ascii(), p.render_ascii_clipped(usize::MAX));
        let big = AttentionSpec::local(2).unwrap().compile(RENDER_CLIP + 8);
        assert_eq!(big.render_ascii().lines().count(), RENDER_CLIP + 1);
        assert!(big.render_csv().ends_with(&format!(
            "# truncated: rows {RENDER_CLIP}..{} omitted\n",
            RENDER_CLIP + 8
        )));
    }

    #[test]
    fn heap_bytes_counts_csr_arrays() {
        let p = AttentionSpec::local(4).unwrap().compile(16);
        let usz = std::mem::size_of::<usize>();
        assert_eq!(p.heap_bytes(), 17 * usz + p.nnz() * usz + p.nnz() * 4);
        assert_eq!(AttentionSpec::Full.compile(0).heap_bytes(), usz);
    }

    #[test]
    fn band_to_pattern_pads_outside_rows_empty() {
        let spec = AttentionSpec::local(4).unwrap();
        let band = spec.compile_band(16, 5..9);
        assert_eq!((band.start(), band.end(), band.len()), (5, 9, 4));
        let mono = spec.compile(16);
        for i in 5..9 {
            assert_eq!(band.row(i), mono.row(i));
            assert_eq!(band.row_clusters(i), mono.row_clusters(i));
        }
        assert!(band.row(4).is_empty() && band.row(9).is_empty());
        let padded = band.to_pattern();
        assert_eq!(padded.n(), 16);
        for i in 0..16 {
            if (5..9).contains(&i) {
                assert_eq!(padded.row(i), mono.row(i));
                assert_eq!(padded.row_clusters(i), mono.row_clusters(i));
            } else {
                assert!(padded.row(i).is_empty());
            }
        }
        assert_eq!(padded.nnz(), band.nnz());
        assert_eq!(band.cost(8), 2 * band.nnz() as u64 * 8);
        assert!(band.heap_bytes() < mono.heap_bytes());
    }

    #[test]
    fn memory_budget_meters_and_caps() {
        let b = MemoryBudget::bytes(100);
        assert_eq!(b.max_bytes(), Some(100));
        b.charge(60);
        assert!(!b.over_budget());
        let shared = b.clone(); // clones share the meter
        shared.charge(60);
        assert_eq!(b.resident(), 120);
        assert!(b.over_budget());
        b.release(60);
        assert_eq!((b.resident(), b.peak(), b.evicted()), (60, 120, 60));
        assert!(!b.over_budget());

        let unbounded = MemoryBudget::unbounded();
        unbounded.charge(usize::MAX / 2);
        assert!(!unbounded.over_budget());
        assert_eq!(unbounded.max_bytes(), None);
    }
}

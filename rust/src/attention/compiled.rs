//! Compiled sparsity patterns — phase two of the spec→compile pipeline.
//!
//! A [`CompiledPattern`] is an [`AttentionSpec`](super::AttentionSpec)
//! materialized for one sequence length as a CSR index set: row offsets
//! plus sorted per-query key indices, with an optional cluster id per
//! entry for routed keys.  Compiling once buys every consumer the same
//! semantics at the right complexity: `allowed` is a binary search
//! (O(log w) instead of the old linear `Vec::contains` scans), `nnz` and
//! `density` read the CSR tail pointer (O(1)), and `row(i)` hands out the
//! attend-set as a zero-allocation slice.  The Figure-1 ASCII/CSV
//! renderers and the exact-FLOP `cost` model live here so there is exactly
//! one source of truth for "which keys may query i attend to".

/// Sentinel cluster id for entries admitted by a non-routing scheme
/// (public so engine consumers iterating raw cluster slices via
/// [`CompiledPattern::rows`] can tell routed from unrouted entries).
pub const NO_CLUSTER: u32 = u32::MAX;

/// A compiled sparsity pattern over a sequence of length `n`, stored as
/// CSR: `cols[row_offsets[i]..row_offsets[i+1]]` is S_i, sorted ascending.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompiledPattern {
    n: usize,
    /// `n + 1` offsets into `cols`/`cluster_ids`.
    row_offsets: Vec<usize>,
    /// Key indices, sorted ascending within each row.
    cols: Vec<usize>,
    /// Per-entry cluster id (`NO_CLUSTER` for non-routed entries).
    cluster_ids: Vec<u32>,
}

/// Per-row attend-set size summary (for `rtx figure1 --stats`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowStats {
    /// Smallest attend-set size across rows.
    pub min: usize,
    /// Mean attend-set size (`nnz / n`).
    pub mean: f64,
    /// Largest attend-set size across rows.
    pub max: usize,
}

impl CompiledPattern {
    /// Pack sorted, deduped per-row `(key, cluster)` entries into CSR.
    pub(crate) fn from_rows(n: usize, rows: Vec<Vec<(usize, u32)>>) -> CompiledPattern {
        debug_assert_eq!(rows.len(), n);
        let nnz: usize = rows.iter().map(Vec::len).sum();
        let mut row_offsets = Vec::with_capacity(n + 1);
        let mut cols = Vec::with_capacity(nnz);
        let mut cluster_ids = Vec::with_capacity(nnz);
        row_offsets.push(0);
        for row in &rows {
            for &(j, c) in row {
                cols.push(j);
                cluster_ids.push(c);
            }
            row_offsets.push(cols.len());
        }
        CompiledPattern { n, row_offsets, cols, cluster_ids }
    }

    /// Sequence length the pattern was compiled for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// CSR row offsets (`n + 1` entries; `offsets()[i]` is the nnz before
    /// row i).  Crate-internal: the engine's sharding uses it as a prefix
    /// sum for O(1) per-range nnz and O(log n) balanced split points.
    pub(crate) fn offsets(&self) -> &[usize] {
        &self.row_offsets
    }

    /// Total non-zero entries of the attention matrix — O(1) from CSR.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// The attend-set S_i as a sorted slice; empty for out-of-range `i`
    /// (so `n = 0` is a total no-op rather than an underflow).
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let p = AttentionSpec::local(3).unwrap().compile(8);
    /// assert_eq!(p.row(5), &[3, 4, 5]);
    /// assert_eq!(p.row(0), &[0]);
    /// assert!(p.row(99).is_empty(), "out-of-range rows are empty, not a panic");
    /// ```
    pub fn row(&self, i: usize) -> &[usize] {
        if i >= self.n {
            return &[];
        }
        &self.cols[self.row_offsets[i]..self.row_offsets[i + 1]]
    }

    /// May query `i` attend to key `j`?  O(log |S_i|) binary search.
    pub fn allowed(&self, i: usize, j: usize) -> bool {
        self.row(i).binary_search(&j).is_ok()
    }

    /// Per-entry cluster ids aligned with `row(i)` ([`NO_CLUSTER`] for
    /// unrouted entries); empty for out-of-range `i`.
    pub fn row_clusters(&self, i: usize) -> &[u32] {
        if i >= self.n {
            return &[];
        }
        &self.cluster_ids[self.row_offsets[i]..self.row_offsets[i + 1]]
    }

    /// Batched zero-allocation row gather: iterate `(i, keys, clusters)`
    /// for every query row in `range` (clamped to `0..n`), handing out
    /// slices straight from the CSR arrays.  This is the engine's
    /// per-shard evaluation primitive — see
    /// [`crate::attention::engine`].
    pub fn rows(&self, range: std::ops::Range<usize>) -> RowIter<'_> {
        let end = range.end.min(self.n);
        let start = range.start.min(end);
        RowIter { pattern: self, range: start..end }
    }

    /// Cluster id that routed key `j` into S_i, if any.
    pub fn cluster_of(&self, i: usize, j: usize) -> Option<u32> {
        if i >= self.n {
            return None;
        }
        let lo = self.row_offsets[i];
        match self.cols[lo..self.row_offsets[i + 1]].binary_search(&j) {
            Ok(off) => match self.cluster_ids[lo + off] {
                NO_CLUSTER => None,
                c => Some(c),
            },
            Err(_) => None,
        }
    }

    /// Sparsity fraction (nnz / full causal nnz); 0.0 for `n = 0`.
    pub fn density(&self) -> f64 {
        let full = self.n * (self.n + 1) / 2;
        if full == 0 {
            0.0
        } else {
            self.nnz() as f64 / full as f64
        }
    }

    /// Exact multiply-accumulate count for one attention pass over this
    /// pattern with head dimension `d`: QK^T and PV each touch every
    /// materialized (query, key) pair once (`2 · nnz · d`).
    pub fn cost(&self, d: usize) -> u64 {
        2 * self.nnz() as u64 * d as u64
    }

    /// Attention-matrix entries instantiated (memory model).
    pub fn memory(&self) -> u64 {
        self.nnz() as u64
    }

    /// Every admitted key is causal (j <= i).  True by construction; kept
    /// as a checkable invariant for tests.
    pub fn is_causal(&self) -> bool {
        (0..self.n).all(|i| self.row(i).iter().all(|&j| j <= i))
    }

    /// Rows are strictly ascending (sorted, duplicate-free).
    pub fn rows_sorted(&self) -> bool {
        (0..self.n).all(|i| self.row(i).windows(2).all(|w| w[0] < w[1]))
    }

    /// Min / mean / max attend-set size across rows.
    pub fn row_stats(&self) -> RowStats {
        if self.n == 0 {
            return RowStats { min: 0, mean: 0.0, max: 0 };
        }
        let mut min = usize::MAX;
        let mut max = 0usize;
        for i in 0..self.n {
            let len = self.row(i).len();
            min = min.min(len);
            max = max.max(len);
        }
        RowStats { min, mean: self.nnz() as f64 / self.n as f64, max }
    }

    /// ASCII rendering of the attention scheme, Figure-1 style: rows are
    /// outputs, columns inputs; routed entries are drawn with one letter
    /// per cluster, unrouted admitted entries with '#'.
    pub fn render_ascii(&self) -> String {
        let mut out = String::with_capacity(self.n * (self.n + 1));
        for i in 0..self.n {
            let (lo, hi) = (self.row_offsets[i], self.row_offsets[i + 1]);
            let mut next = lo;
            for j in 0..self.n {
                let ch = if next < hi && self.cols[next] == j {
                    let c = self.cluster_ids[next];
                    next += 1;
                    if c == NO_CLUSTER {
                        '#'
                    } else {
                        (b'A' + (c % 26) as u8) as char
                    }
                } else if j <= i {
                    '·'
                } else {
                    ' '
                };
                out.push(ch);
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering: `query,key,cluster` rows for every non-zero entry
    /// (cluster field empty for unrouted entries).
    pub fn render_csv(&self) -> String {
        let mut out = String::from("query,key,cluster\n");
        for i in 0..self.n {
            for e in self.row_offsets[i]..self.row_offsets[i + 1] {
                let j = self.cols[e];
                match self.cluster_ids[e] {
                    NO_CLUSTER => out.push_str(&format!("{i},{j},\n")),
                    c => out.push_str(&format!("{i},{j},{c}\n")),
                }
            }
        }
        out
    }
}

/// Iterator over `(i, keys, clusters)` row slices; see
/// [`CompiledPattern::rows`].
#[derive(Debug, Clone)]
pub struct RowIter<'a> {
    pattern: &'a CompiledPattern,
    range: std::ops::Range<usize>,
}

impl<'a> Iterator for RowIter<'a> {
    type Item = (usize, &'a [usize], &'a [u32]);

    fn next(&mut self) -> Option<Self::Item> {
        let i = self.range.next()?;
        Some((i, self.pattern.row(i), self.pattern.row_clusters(i)))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.range.size_hint()
    }
}

impl<'a> ExactSizeIterator for RowIter<'a> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionSpec;

    #[test]
    fn full_attends_everything_causal() {
        let p = AttentionSpec::Full.compile(8);
        assert_eq!(p.row(5), &[0, 1, 2, 3, 4, 5]);
        assert!(p.is_causal());
        assert_eq!(p.nnz(), 36);
    }

    #[test]
    fn local_window_bound() {
        let p = AttentionSpec::local(4).unwrap().compile(16);
        assert_eq!(p.row(10), &[7, 8, 9, 10]);
        assert_eq!(p.row(1), &[0, 1]);
        assert!(p.is_causal());
    }

    #[test]
    fn block_local_two_blocks() {
        let p = AttentionSpec::block_local(4).unwrap().compile(16);
        // query 9 (block 2) sees blocks 1 and 2, causally
        assert_eq!(p.row(9), &[4, 5, 6, 7, 8, 9]);
        // block 0 sees only itself
        assert_eq!(p.row(2), &[0, 1, 2]);
    }

    #[test]
    fn strided_pattern() {
        let p = AttentionSpec::strided(4).unwrap().compile(16);
        assert_eq!(p.row(9), &[1, 5, 9]);
        assert!(p.is_causal());
    }

    #[test]
    fn routing_same_cluster_only() {
        let spec = AttentionSpec::routing(vec![vec![0, 2, 5], vec![1, 3, 4, 6, 7]]);
        let p = spec.compile(8);
        assert!(p.allowed(5, 2));
        assert!(p.allowed(5, 0));
        assert!(!p.allowed(5, 3)); // different cluster
        assert!(!p.allowed(2, 5)); // causality
        assert_eq!(p.cluster_of(5, 2), Some(0));
        assert_eq!(p.cluster_of(7, 3), Some(1));
        assert_eq!(p.cluster_of(5, 3), None);
        assert!(p.is_causal());
    }

    #[test]
    fn density_ordering_matches_paper() {
        // local(w) and routing(k=sqrt n) are sparse; full is dense
        let n = 64;
        let full = AttentionSpec::Full.compile(n);
        let local = AttentionSpec::local(8).unwrap().compile(n);
        let routing = AttentionSpec::routing_balanced(n, 8).unwrap().compile(n);
        assert!(local.density() < full.density());
        assert!(routing.density() < full.density());
        assert!((full.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_render_shapes() {
        let p = AttentionSpec::block_local(2).unwrap().compile(8);
        let art = p.render_ascii();
        assert_eq!(art.lines().count(), 8);
        assert!(art.lines().all(|l| l.chars().count() == 8));
        // first char of first row is '#': token 0 attends to itself
        assert_eq!(art.lines().next().unwrap().chars().next().unwrap(), '#');
    }

    #[test]
    fn csv_render_contains_entries() {
        let p = AttentionSpec::routing(vec![vec![0, 1, 2, 3]]).compile(4);
        let csv = p.render_csv();
        assert!(csv.contains("3,0,0"));
        assert_eq!(csv.lines().count(), 1 + p.nnz());
    }

    #[test]
    fn empty_and_singleton_sequences() {
        // n = 0 used to underflow (attend_set evaluated n - 1) and divide
        // by zero (density over n*(n+1)/2 = 0); now a total no-op
        for spec in [
            AttentionSpec::Full,
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::block_local(2).unwrap(),
            AttentionSpec::strided(2).unwrap(),
            AttentionSpec::routing(vec![vec![0, 1]]),
            AttentionSpec::union(vec![AttentionSpec::Full, AttentionSpec::local(1).unwrap()])
                .unwrap(),
        ] {
            let p0 = spec.compile(0);
            assert_eq!(p0.nnz(), 0);
            assert_eq!(p0.density(), 0.0);
            assert_eq!(p0.row(0), &[] as &[usize]);
            assert!(!p0.allowed(0, 0));
            assert_eq!(p0.render_ascii(), "");
            assert_eq!(p0.render_csv(), "query,key,cluster\n");
            assert_eq!(p0.row_stats(), RowStats { min: 0, mean: 0.0, max: 0 });

            let p1 = spec.compile(1);
            assert!(p1.is_causal());
            assert!(p1.nnz() <= 1);
            assert!(p1.density() <= 1.0);
        }
        // every positional kind admits the diagonal at n = 1
        assert_eq!(AttentionSpec::local(5).unwrap().compile(1).nnz(), 1);
    }

    #[test]
    fn union_nnz_pinned_against_parts() {
        let n = 16;
        let local = AttentionSpec::local(4).unwrap();
        let routing = AttentionSpec::routing(vec![vec![0, 5, 9, 13], vec![2, 3, 11]]);
        let pl = local.compile(n);
        let pr = routing.compile(n);
        let pu = AttentionSpec::union(vec![local, routing]).unwrap().compile(n);
        let mut expect = 0usize;
        for i in 0..n {
            for j in 0..=i {
                if pl.allowed(i, j) || pr.allowed(i, j) {
                    expect += 1;
                }
            }
        }
        assert_eq!(pu.nnz(), expect, "union nnz must equal the set union of the parts");
        assert!(pu.nnz() >= pl.nnz().max(pr.nnz()));
        assert!(pu.nnz() <= pl.nnz() + pr.nnz());
        assert!(pu.is_causal() && pu.rows_sorted());
        // routed entries keep their cluster letter through the union
        assert_eq!(pu.cluster_of(5, 0), Some(0));
        let art = pu.render_ascii();
        assert!(art.contains('A') && art.contains('#'));
    }

    #[test]
    fn intersect_full_is_identity() {
        let n = 12;
        let local = AttentionSpec::local(3).unwrap();
        let pi = AttentionSpec::intersect(vec![AttentionSpec::Full, local.clone()])
            .unwrap()
            .compile(n);
        assert_eq!(pi, local.compile(n));
    }

    #[test]
    fn row_stats_summary() {
        let p = AttentionSpec::local(4).unwrap().compile(16);
        let s = p.row_stats();
        assert_eq!(s.min, 1); // row 0
        assert_eq!(s.max, 4);
        assert!((s.mean - p.nnz() as f64 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn cost_is_exact_from_nnz() {
        let p = AttentionSpec::local(8).unwrap().compile(64);
        assert_eq!(p.cost(64), 2 * p.nnz() as u64 * 64);
        assert_eq!(p.memory(), p.nnz() as u64);
    }
}

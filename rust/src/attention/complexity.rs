//! Closed-form complexity estimates of Section 4.1, as methods on
//! [`AttentionSpec`].
//!
//! Routing attention costs `O(nkd + n²d/k)`: the first term compares all n
//! routing vectors with k centroids, the second performs within-cluster
//! attention assuming balanced clusters of size n/k.  The optimum is
//! k = √n, giving `O(n^1.5 d)` — versus `O(n² d)` for full attention and
//! `O(n w d)` for local attention.  These are the *asymptotic estimates*;
//! the exact per-pattern count lives on
//! [`CompiledPattern::cost`](super::CompiledPattern::cost), computed from
//! the materialized CSR index set.  The `bench_complexity` harness sweeps
//! this model against measured wall-clock to reproduce the paper's
//! asymptotic claim (Section 6.3 discusses the constant factors).

use super::spec::AttentionSpec;

impl AttentionSpec {
    /// Leading-order multiply-accumulate estimate for one attention module
    /// over a sequence of length `n` with head dimension `d`.  For routing
    /// specs this includes the n·k·d cluster-assignment term; `Union` sums
    /// its parts (each head plan member runs), `Intersect` is bounded by
    /// its cheapest part.
    pub fn flops_estimate(&self, n: usize, d: usize) -> u64 {
        let nn = n as u64;
        let dd = d as u64;
        match self {
            // QK^T + PV over the causal half: 2 * (n^2/2) * d each
            AttentionSpec::Full => 2 * nn * nn * dd,
            // each query: window keys
            AttentionSpec::Local { window } => 2 * nn * (*window).max(1) as u64 * dd,
            // each query: at most two blocks of window keys
            AttentionSpec::BlockLocal { window } => {
                2 * nn * 2 * (*window).max(1) as u64 * dd
            }
            // each query: ~n/stride keys (causal average n/(2s), keep n/s bound)
            AttentionSpec::Strided { stride } => {
                2 * nn * (nn / (*stride).max(1) as u64).max(1) * dd
            }
            // nkd routing + within-cluster attention 2·|c|²·d per cluster
            AttentionSpec::Routing { clusters } => {
                let k = clusters.len() as u64;
                let attend: u64 =
                    clusters.iter().map(|m| 2 * (m.len() as u64).pow(2) * dd).sum();
                nn * k * dd + attend
            }
            // same routing-shaped model, but |c| <= capacity by
            // construction, so the attend term is bounded by 2·k·cap²·d
            AttentionSpec::ExpertChoice { clusters, .. } => {
                let k = clusters.len() as u64;
                let attend: u64 =
                    clusters.iter().map(|m| 2 * (m.len() as u64).pow(2) * dd).sum();
                nn * k * dd + attend
            }
            // n·d proxy scoring + the exact stored attend-set sizes
            AttentionSpec::Threshold { rows } => {
                let attend: u64 = rows.iter().map(|r| 2 * r.len() as u64 * dd).sum();
                nn * dd + attend
            }
            AttentionSpec::Union(parts) => {
                parts.iter().map(|p| p.flops_estimate(n, d)).sum()
            }
            AttentionSpec::Intersect(parts) => {
                parts.iter().map(|p| p.flops_estimate(n, d)).min().unwrap_or(0)
            }
        }
    }

    /// Memory-footprint estimate (attention-matrix entries instantiated).
    pub fn memory_estimate(&self, n: usize) -> u64 {
        let nn = n as u64;
        match self {
            AttentionSpec::Full => nn * nn / 2,
            AttentionSpec::Local { window } => nn * (*window).max(1) as u64,
            AttentionSpec::BlockLocal { window } => nn * 2 * (*window).max(1) as u64,
            AttentionSpec::Strided { stride } => {
                nn * (nn / (*stride).max(1) as u64).max(1)
            }
            AttentionSpec::Routing { clusters }
            | AttentionSpec::ExpertChoice { clusters, .. } => {
                clusters.iter().map(|m| (m.len() as u64).pow(2)).sum()
            }
            AttentionSpec::Threshold { rows } => rows.iter().map(|r| r.len() as u64).sum(),
            AttentionSpec::Union(parts) => {
                parts.iter().map(|p| p.memory_estimate(n)).sum()
            }
            AttentionSpec::Intersect(parts) => {
                parts.iter().map(|p| p.memory_estimate(n)).min().unwrap_or(0)
            }
        }
    }
}

/// The k minimizing the routing cost model: k* = √(2n) ≈ √n (the paper
/// states k ~ √n; the constant depends on how the two terms are counted).
pub fn optimal_clusters(n: usize) -> usize {
    ((2.0 * n as f64).sqrt().round() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routing(n: usize, k: usize) -> AttentionSpec {
        AttentionSpec::routing_balanced(n, k).unwrap()
    }

    #[test]
    fn routing_beats_full_at_scale() {
        for &n in &[1024usize, 4096, 8192] {
            let k = optimal_clusters(n);
            let r = routing(n, k).flops_estimate(n, 64);
            let full = AttentionSpec::Full.flops_estimate(n, 64);
            assert!(r < full / 4, "n={n}: routing {r} vs full {full}");
        }
    }

    #[test]
    fn routing_scales_as_n_to_1_5() {
        // quadrupling n with k=sqrt(n) should scale cost by ~4^1.5 = 8x
        let d = 64;
        let c1 = routing(4096, optimal_clusters(4096)).flops_estimate(4096, d);
        let c2 = routing(16384, optimal_clusters(16384)).flops_estimate(16384, d);
        let ratio = c2 as f64 / c1 as f64;
        assert!((ratio - 8.0).abs() < 1.5, "ratio {ratio}");
    }

    #[test]
    fn optimal_k_minimizes_model() {
        let n = 4096;
        let d = 64;
        let kopt = optimal_clusters(n);
        let copt = routing(n, kopt).flops_estimate(n, d);
        for &k in &[kopt / 4, kopt / 2, kopt * 2, kopt * 4] {
            if k == 0 || k == kopt {
                continue;
            }
            let c = routing(n, k).flops_estimate(n, d);
            assert!(copt <= c, "k={k} cost {c} < k*={kopt} cost {copt}");
        }
    }

    #[test]
    fn local_linear_in_n() {
        let local = AttentionSpec::local(256).unwrap();
        let a = local.flops_estimate(4096, 64);
        let b = local.flops_estimate(8192, 64);
        assert_eq!(b, a * 2);
    }

    #[test]
    fn memory_model_ordering() {
        let n = 8192;
        let full = AttentionSpec::Full.memory_estimate(n);
        let local = AttentionSpec::local(256).unwrap().memory_estimate(n);
        let r = routing(n, optimal_clusters(n)).memory_estimate(n);
        assert!(local < full);
        assert!(r < full);
    }

    #[test]
    fn union_estimate_sums_parts() {
        let n = 1024;
        let d = 64;
        let local = AttentionSpec::local(64).unwrap();
        let r = routing(n, 32);
        let mixed =
            AttentionSpec::union(vec![local.clone(), r.clone()]).unwrap();
        assert_eq!(
            mixed.flops_estimate(n, d),
            local.flops_estimate(n, d) + r.flops_estimate(n, d)
        );
    }

    #[test]
    fn expert_choice_attend_term_bounded_by_capacity() {
        let (n, d, k, cap) = (1024usize, 64usize, 32usize, 8usize);
        let clusters: Vec<Vec<usize>> =
            (0..k).map(|c| (c * cap..(c + 1) * cap).collect()).collect();
        let spec = AttentionSpec::expert_choice(clusters, cap).unwrap();
        let bound = (n * k * d + 2 * k * cap * cap * d) as u64;
        assert!(spec.flops_estimate(n, d) <= bound);
        assert!(spec.memory_estimate(n) <= (k * cap * cap) as u64);
        // threshold model is exact in the stored sets
        let t = AttentionSpec::threshold(vec![vec![0], vec![0, 1], vec![2]]).unwrap();
        assert_eq!(t.flops_estimate(3, d), (3 * d + 2 * 4 * d) as u64);
        assert_eq!(t.memory_estimate(3), 4);
    }

    #[test]
    fn estimate_tracks_exact_cost_for_local() {
        // the closed-form bound upper-bounds the exact CSR count (edge
        // rows attend fewer than `window` keys) and is tight within 2x
        let n = 512;
        let spec = AttentionSpec::local(32).unwrap();
        let exact = spec.compile(n).cost(64);
        let bound = spec.flops_estimate(n, 64);
        assert!(exact <= bound && bound < exact * 2, "exact {exact} bound {bound}");
    }
}

//! Analytic complexity model of Section 4.1.
//!
//! Routing attention costs `O(nkd + n²d/k)`: the first term compares all n
//! routing vectors with k centroids, the second performs within-cluster
//! attention assuming balanced clusters of size n/k.  The optimum is
//! k = √n, giving `O(n^1.5 d)` — versus `O(n² d)` for full attention and
//! `O(n w d)` for local attention.  The `bench_complexity` harness sweeps
//! this model against measured wall-clock to reproduce the paper's
//! asymptotic claim (Section 6.3 discusses the constant factors).

/// Attention kinds the model covers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttentionKind {
    Full,
    Local { window: usize },
    Strided { stride: usize },
    Routing { clusters: usize },
}

/// Leading-order multiply-accumulate count for one attention module over a
/// sequence of length `n` with head dimension `d`.
pub fn attention_flops(kind: AttentionKind, n: usize, d: usize) -> u64 {
    let n = n as u64;
    let d = d as u64;
    match kind {
        // QK^T + PV over the causal half: 2 * (n^2/2) * d each
        AttentionKind::Full => 2 * n * n * d,
        // each query: window keys
        AttentionKind::Local { window } => 2 * n * (window as u64) * d,
        // each query: ~n/stride keys (causal average n/(2s), keep n/s bound)
        AttentionKind::Strided { stride } => 2 * n * (n / stride as u64).max(1) * d,
        // nkd routing + k * w^2 * d * 2 attention with w = n/k
        AttentionKind::Routing { clusters } => {
            let k = clusters as u64;
            let w = (n / k).max(1);
            n * k * d + 2 * k * w * w * d
        }
    }
}

/// The k minimizing the routing cost model: k* = √(2n) ≈ √n (the paper
/// states k ~ √n; the constant depends on how the two terms are counted).
pub fn optimal_clusters(n: usize) -> usize {
    ((2.0 * n as f64).sqrt().round() as usize).max(1)
}

/// Memory footprint (attention-matrix entries instantiated).
pub fn attention_memory(kind: AttentionKind, n: usize) -> u64 {
    let n = n as u64;
    match kind {
        AttentionKind::Full => n * n / 2,
        AttentionKind::Local { window } => n * window as u64,
        AttentionKind::Strided { stride } => n * (n / stride as u64).max(1),
        AttentionKind::Routing { clusters } => {
            let k = clusters as u64;
            let w = (n / k).max(1);
            k * w * w
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_beats_full_at_scale() {
        for &n in &[1024usize, 4096, 8192] {
            let k = optimal_clusters(n);
            let routing = attention_flops(AttentionKind::Routing { clusters: k }, n, 64);
            let full = attention_flops(AttentionKind::Full, n, 64);
            assert!(routing < full / 4, "n={n}: routing {routing} vs full {full}");
        }
    }

    #[test]
    fn routing_scales_as_n_to_1_5() {
        // doubling n with k=sqrt(n) should scale cost by ~2^1.5 ≈ 2.83
        let d = 64;
        let c1 = attention_flops(AttentionKind::Routing { clusters: optimal_clusters(4096) }, 4096, d);
        let c2 = attention_flops(AttentionKind::Routing { clusters: optimal_clusters(16384) }, 16384, d);
        let ratio = c2 as f64 / c1 as f64;
        // quadrupling n -> 4^1.5 = 8x
        assert!((ratio - 8.0).abs() < 1.5, "ratio {ratio}");
    }

    #[test]
    fn optimal_k_minimizes_model() {
        let n = 4096;
        let d = 64;
        let kopt = optimal_clusters(n);
        let copt = attention_flops(AttentionKind::Routing { clusters: kopt }, n, d);
        for &k in &[kopt / 4, kopt / 2, kopt * 2, kopt * 4] {
            if k == 0 || k == kopt {
                continue;
            }
            let c = attention_flops(AttentionKind::Routing { clusters: k }, n, d);
            assert!(copt <= c, "k={k} cost {c} < k*={kopt} cost {copt}");
        }
    }

    #[test]
    fn local_linear_in_n() {
        let a = attention_flops(AttentionKind::Local { window: 256 }, 4096, 64);
        let b = attention_flops(AttentionKind::Local { window: 256 }, 8192, 64);
        assert_eq!(b, a * 2);
    }

    #[test]
    fn memory_model_ordering() {
        let n = 8192;
        let full = attention_memory(AttentionKind::Full, n);
        let local = attention_memory(AttentionKind::Local { window: 256 }, n);
        let routing = attention_memory(
            AttentionKind::Routing { clusters: optimal_clusters(n) }, n);
        assert!(local < full);
        assert!(routing < full);
    }
}

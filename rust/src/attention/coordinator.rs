//! Multi-process shard coordination — one head-plan split across OS
//! processes.
//!
//! A single process caps out at `available_parallelism`; this module is
//! the horizontal-scale step the ROADMAP names.  The [`Coordinator`]
//! owns **all** routing state — the [`RoutingSession`], the
//! [`EpochCache`], and the per-slot [`MemberCache`]s — exactly as the
//! in-process serve loop does, and ships workers only what they need to
//! execute: epoch-stamped [`AttentionSpec`] installs, epoch-bump
//! [`RouteUpdate`] deltas (the [`AssignmentDelta`] dirty-cluster
//! machinery reused verbatim as the wire payload), and self-contained
//! row-range grants cut with [`ShardedPattern::balanced`] so every
//! worker gets (nearly) equal nnz, not equal rows.
//!
//! # Wire protocol
//!
//! Frames are length-prefixed JSON: a 4-byte big-endian `u32` byte
//! length followed by that many bytes of UTF-8 JSON ([`write_frame`] /
//! [`read_frame`]).  `f32` payloads survive the text round-trip
//! bit-exactly: every finite `f32` widens to `f64` losslessly, the
//! serializer prints the shortest round-trip decimal, and the parser
//! reads it back to the identical `f64`.  Messages are type-tagged
//! objects:
//!
//! | type       | direction | payload |
//! |------------|-----------|---------|
//! | `join`     | worker→coord | `worker`, `protocol` |
//! | `hello`    | coord→worker | `worker`, `protocol`, `backend`, `n`, `d` |
//! | `spec`     | coord→worker | `stream`, `epoch`, `assignment_epoch`, optional `layer`/`head`, declarative `spec` (compiled worker-side) |
//! | `delta`    | coord→worker | `layer`, `head`, `update` ([`RouteUpdate`]) |
//! | `evict`    | coord→worker | `stream` (retirement GC reaches workers too) |
//! | `grant`    | coord→worker | `task`, `stream`, `epoch`, `rows [lo,hi)`, full `q`/`k`/`v` |
//! | `result`   | worker→coord | `task`, `worker`, `stream`, `epoch`, `rows`, `out` |
//! | `nack`     | worker→coord | `task`, `worker`, `stream`, `epoch` (unknown stream / stale install) |
//! | `error`    | worker→coord | `task`, `worker`, `stream`, `epoch`, `msg` (kernel failure — worker is retired) |
//! | `shutdown` | coord→worker | — |
//!
//! # State machine
//!
//! ```text
//!  spawn ──▶ Joining ──join──▶ Ready ──grant──▶ Busy
//!                               ▲  ▲              │result/nack/timeout
//!                               │  └──────────────┘
//!                            rejoin
//!                               │
//!            Crashed ◀──EOF/kill/crash-fault── (any state)
//! ```
//!
//! Exactly-once completion is enforced coordinator-side: every grant
//! carries a fresh task id, and a result is accepted only while its task
//! id is outstanding.  A crashed worker's row-range is re-granted to
//! survivors (or computed inline when none remain); the superseded
//! grant's late result — and any duplicated or delayed copy — fails the
//! task-id match and is counted in
//! [`CoordStats::rejected_stale_epoch`] / [`CoordStats::rejected_duplicate`]
//! instead of being applied.  At rest the grant ledger conserves:
//! `grants == accepted + superseded + voided` ([`CoordStats::conserved`]).
//!
//! The [`Transport`] trait keeps the state machine pluggable: the same
//! coordinator runs over [`ProcessTransport`] (real `rtx worker` child
//! processes over stdin/stdout) and [`SimTransport`] (in-memory workers
//! with deterministic drop / duplicate / delay / crash-on-Nth-message
//! fault injection — the substrate `tests/coordinator.rs` drives its
//! model-based suite on).

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::ops::Range;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{self, Backend};
use super::compiled::{CompiledPattern, MemoryBudget};
use super::decode::{
    routed_family_spec, EpochCache, EpochCacheStats, MemberCache, RegenStats, RouteSlot,
    RouteUpdate, RoutingSession, SpecFamily,
};
use super::engine::{CacheStats, ShardedPattern};
use super::spec::AttentionSpec;
use crate::util::json::Json;

/// Wire protocol version stamped into `join`/`hello`; a mismatch is a
/// protocol error on either side.
pub const PROTOCOL_VERSION: u64 = 1;

/// Hard cap on one frame's payload (a corrupted length prefix must not
/// allocate unbounded memory).
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// The reserved stream id of the shared static (local-window) pattern.
pub const STATIC_STREAM: u64 = 0;

/// FNV-1a offset basis — the initial accumulator for [`fold_digest`].
pub const DIGEST_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold a slice of attention outputs into a running FNV-1a digest over
/// the raw `f32` bit patterns (little-endian byte order).  The serve
/// loop threads every sweep's output through this, so two runs that
/// produced bit-identical attention — in-process or coordinated across
/// OS workers — report the same `output_digest`.
pub fn fold_digest(acc: u64, xs: &[f32]) -> u64 {
    let mut h = acc;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

// ------------------------------------------------------------ frame codec

/// Write one length-prefixed JSON frame: 4-byte big-endian byte length,
/// then the UTF-8 serialization.
pub fn write_frame<W: Write>(w: &mut W, msg: &Json) -> io::Result<()> {
    let text = msg.to_string();
    let len = u32::try_from(text.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame exceeds u32 length"))?;
    if len as usize > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds MAX_FRAME_BYTES"));
    }
    w.write_all(&len.to_be_bytes())?;
    w.write_all(text.as_bytes())
}

/// Read one length-prefixed JSON frame.  `Ok(None)` is a clean EOF at a
/// frame boundary; EOF mid-frame, an oversized length prefix, or
/// malformed JSON are errors.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Json>> {
    let mut len_bytes = [0u8; 4];
    // read the first byte separately so EOF at a boundary is clean
    loop {
        match r.read(&mut len_bytes[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    r.read_exact(&mut len_bytes[1..])?;
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds MAX_FRAME_BYTES"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("frame not UTF-8: {e}")))?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("frame not JSON: {e}")))
}

// -------------------------------------------------------- message helpers

fn jobj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn jnum(n: u64) -> Json {
    Json::Num(n as f64)
}

fn floats_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(f64::from(x))).collect())
}

fn floats_from_json(v: &Json) -> Result<Vec<f32>> {
    v.as_arr()
        .context("expected a JSON array of numbers")?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32).context("expected a number"))
        .collect()
}

fn field<'a>(msg: &'a Json, key: &str) -> Result<&'a Json> {
    msg.get(key).with_context(|| format!("message missing field '{key}'"))
}

fn field_u64(msg: &Json, key: &str) -> Result<u64> {
    let v = field(msg, key)?;
    let i = v.as_i64().with_context(|| format!("field '{key}' is not an integer"))?;
    u64::try_from(i).map_err(|_| anyhow!("field '{key}' is negative"))
}

fn field_usize(msg: &Json, key: &str) -> Result<usize> {
    field(msg, key)?.as_usize().with_context(|| format!("field '{key}' is not a usize"))
}

fn field_str<'a>(msg: &'a Json, key: &str) -> Result<&'a str> {
    field(msg, key)?.as_str().with_context(|| format!("field '{key}' is not a string"))
}

fn msg_type(msg: &Json) -> Result<&str> {
    field_str(msg, "type")
}

fn field_rows(msg: &Json) -> Result<Range<usize>> {
    let arr = field(msg, "rows")?.as_arr().context("'rows' is not an array")?;
    if arr.len() != 2 {
        bail!("'rows' must be [lo, hi]");
    }
    let lo = arr[0].as_usize().context("'rows' lo is not a usize")?;
    let hi = arr[1].as_usize().context("'rows' hi is not a usize")?;
    if lo > hi {
        bail!("'rows' range is inverted ({lo} > {hi})");
    }
    Ok(lo..hi)
}

// -------------------------------------------------------------- transport

/// A worker's identity — assigned by the coordinator at spawn and stable
/// across crash/rejoin (the *incarnation* changes, the id does not).
pub type WorkerId = usize;

/// One delivery from the transport to the coordinator.
#[derive(Debug, Clone)]
pub enum TransportEvent {
    /// A frame from a worker.
    Message(WorkerId, Json),
    /// The worker's channel died (process exit, EOF, injected crash).
    Crashed(WorkerId),
}

/// The pluggable channel layer under the [`Coordinator`] state machine.
///
/// Implementations deliver [`TransportEvent`]s in a deterministic order
/// for a fixed input sequence; `send` to a crashed worker is a silent
/// dead-letter (the crash surfaces through `poll`, never through
/// `send`).
pub trait Transport {
    /// Start (or restart) the worker with this id; a `join` message is
    /// expected to arrive via `poll` once it is up.
    fn spawn(&mut self, worker: WorkerId) -> Result<()>;
    /// Forcibly terminate a worker.
    fn kill(&mut self, worker: WorkerId);
    /// Deliver one frame to a worker (dead-letters if it is down).
    fn send(&mut self, worker: WorkerId, msg: &Json) -> Result<()>;
    /// Next event, if any.  `wait` allows blocking (bounded by the
    /// implementation's timeout); `Ok(None)` means "nothing arrived" —
    /// the coordinator treats in-flight grants as lost and re-grants.
    fn poll(&mut self, wait: bool) -> Result<Option<TransportEvent>>;
}

// ------------------------------------------------------------ worker node

struct WorkerStream {
    /// `Some((layer, head))` for routed streams (delta targets); `None`
    /// for the static stream.
    plan: Option<(usize, usize)>,
    epoch: u64,
    pattern: Arc<CompiledPattern>,
}

/// The worker half of the protocol: compiles installed specs, applies
/// epoch-bump deltas, and executes row-range grants with its configured
/// backend.  [`run_worker`] wraps it in the stdin/stdout frame loop for
/// real `rtx worker` processes; [`SimTransport`] drives the same struct
/// in-memory, so both transports execute identical logic.
pub struct WorkerNode {
    id: WorkerId,
    n: usize,
    d: usize,
    backend: Option<Arc<dyn Backend>>,
    streams: HashMap<u64, WorkerStream>,
}

impl WorkerNode {
    /// A fresh (pre-`hello`) worker.
    pub fn new(id: WorkerId) -> WorkerNode {
        WorkerNode { id, n: 0, d: 0, backend: None, streams: HashMap::new() }
    }

    /// The `join` frame this worker announces itself with.
    pub fn join_msg(&self) -> Json {
        jobj(vec![
            ("type", Json::Str("join".to_string())),
            ("worker", jnum(self.id as u64)),
            ("protocol", jnum(PROTOCOL_VERSION)),
        ])
    }

    /// Installed streams (test observability).
    pub fn stream_count(&self) -> usize {
        self.streams.len()
    }

    /// Process one coordinator frame; returns the replies to send back
    /// and whether the worker should shut down.  `Err` means a protocol
    /// violation — a real worker exits (and the coordinator sees the
    /// crash), a simulated one fails the test loudly.
    pub fn handle(&mut self, msg: &Json) -> Result<(Vec<Json>, bool)> {
        match msg_type(msg)? {
            "hello" => {
                let protocol = field_u64(msg, "protocol")?;
                if protocol != PROTOCOL_VERSION {
                    bail!("protocol mismatch: coordinator {protocol}, worker {PROTOCOL_VERSION}");
                }
                self.n = field_usize(msg, "n")?;
                self.d = field_usize(msg, "d")?;
                let name = field_str(msg, "backend")?;
                self.backend = Some(
                    backend::lookup(name)
                        .with_context(|| format!("worker {}: unknown backend '{name}'", self.id))?,
                );
                Ok((vec![], false))
            }
            "spec" => {
                let stream = field_u64(msg, "stream")?;
                let epoch = field_u64(msg, "epoch")?;
                let plan = match (msg.get("layer"), msg.get("head")) {
                    (Some(l), Some(h)) => Some((
                        l.as_usize().context("'layer' is not a usize")?,
                        h.as_usize().context("'head' is not a usize")?,
                    )),
                    _ => None,
                };
                let spec = AttentionSpec::from_json(field(msg, "spec")?)
                    .context("spec install failed to parse")?;
                let pattern = Arc::new(spec.compile(self.n));
                self.streams.insert(stream, WorkerStream { plan, epoch, pattern });
                Ok((vec![], false))
            }
            "delta" => {
                let layer = field_usize(msg, "layer")?;
                let head = field_usize(msg, "head")?;
                let upd = RouteUpdate::from_json(field(msg, "update")?)?;
                if upd.delta.changed() {
                    // assignments moved: installed compiles for this
                    // (layer, head) are stale; the coordinator re-ships
                    // specs before granting at the new assignment epoch
                    self.streams.retain(|_, s| s.plan != Some((layer, head)));
                } else {
                    // centroid drift without movement: O(1) epoch bump,
                    // the compile stays servable (the EpochCache
                    // unchanged-epoch contract, applied worker-side)
                    for s in self.streams.values_mut() {
                        if s.plan == Some((layer, head)) {
                            s.epoch = upd.epoch;
                        }
                    }
                }
                Ok((vec![], false))
            }
            "evict" => {
                let stream = field_u64(msg, "stream")?;
                self.streams.remove(&stream);
                Ok((vec![], false))
            }
            "grant" => {
                let task = field_u64(msg, "task")?;
                let stream = field_u64(msg, "stream")?;
                let epoch = field_u64(msg, "epoch")?;
                let rows = field_rows(msg)?;
                let echo = |kind: &str| {
                    jobj(vec![
                        ("type", Json::Str(kind.to_string())),
                        ("task", jnum(task)),
                        ("worker", jnum(self.id as u64)),
                        ("stream", jnum(stream)),
                        ("epoch", jnum(epoch)),
                    ])
                };
                // a grant before hello (lost handshake frame) is
                // recoverable — nack it rather than dying
                let Some(backend) = self.backend.as_ref() else {
                    return Ok((vec![echo("nack")], false));
                };
                let live = self
                    .streams
                    .get(&stream)
                    .is_some_and(|s| s.epoch == epoch && s.pattern.n() == self.n);
                if !live || rows.end > self.n {
                    // unknown stream or stale install (e.g. a dropped
                    // spec/delta frame): ask the coordinator to re-ship
                    return Ok((vec![echo("nack")], false));
                }
                let q = floats_from_json(field(msg, "q")?)?;
                let k = floats_from_json(field(msg, "k")?)?;
                let v = floats_from_json(field(msg, "v")?)?;
                let pattern = Arc::clone(&self.streams[&stream].pattern);
                let mut out = vec![0f32; rows.len() * self.d];
                match backend.attention_rows(&q, &k, &v, self.d, &pattern, rows.clone(), &mut out) {
                    Ok(()) => {
                        let mut reply = echo("result").to_map();
                        reply.insert(
                            "rows".to_string(),
                            Json::Arr(vec![jnum(rows.start as u64), jnum(rows.end as u64)]),
                        );
                        reply.insert("out".to_string(), floats_to_json(&out));
                        Ok((vec![Json::Obj(reply.into_iter().collect())], false))
                    }
                    Err(e) => {
                        let mut reply = echo("error").to_map();
                        reply.insert("msg".to_string(), Json::Str(format!("{e:#}")));
                        Ok((vec![Json::Obj(reply.into_iter().collect())], false))
                    }
                }
            }
            "shutdown" => Ok((vec![], true)),
            other => bail!("worker {}: unknown message type '{other}'", self.id),
        }
    }
}

/// The `rtx worker` main loop: announce `join`, then serve frames from
/// stdin until `shutdown` or EOF.  Never meant to be invoked by hand —
/// the coordinator spawns these with pipes on both ends.
pub fn run_worker(id: WorkerId) -> Result<()> {
    let mut node = WorkerNode::new(id);
    let stdin = io::stdin();
    let mut input = io::BufReader::new(stdin.lock());
    let stdout = io::stdout();
    let mut output = io::BufWriter::new(stdout.lock());
    write_frame(&mut output, &node.join_msg())?;
    output.flush()?;
    while let Some(msg) = read_frame(&mut input)? {
        let (replies, quit) = node.handle(&msg)?;
        for reply in &replies {
            write_frame(&mut output, reply)?;
        }
        output.flush()?;
        if quit {
            break;
        }
    }
    Ok(())
}

// ---------------------------------------------------------- sim transport

/// Counters for the faults a [`SimTransport`] actually injected.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Coordinator→worker frames silently dropped.
    pub dropped: u64,
    /// Worker→coordinator replies delivered twice.
    pub duplicated: u64,
    /// Worker→coordinator replies re-ordered behind the queue.
    pub delayed: u64,
    /// Workers killed by a `crash_on_nth_message` trigger.
    pub forced_crashes: u64,
}

struct SimNode {
    node: WorkerNode,
    alive: bool,
}

/// In-memory [`Transport`]: every worker is a [`WorkerNode`] executed
/// synchronously in-process, with deterministic fault injection.  All
/// faults are *explicitly scheduled* (by the seeded test harness), so a
/// failing op sequence replays bit-for-bit from its seed:
///
/// - [`SimTransport::inject_drop_next`] — drop the next frame *to* a worker
/// - [`SimTransport::inject_duplicate_next`] — deliver a worker's next reply twice
/// - [`SimTransport::inject_delay_next`] — hold a worker's next reply until the
///   event queue drains (re-ordering it behind later traffic)
/// - [`SimTransport::crash_on_nth_message`] — kill a worker the moment its
///   N-th subsequent frame arrives (before processing it)
#[derive(Default)]
pub struct SimTransport {
    nodes: BTreeMap<WorkerId, SimNode>,
    events: VecDeque<TransportEvent>,
    delayed: VecDeque<TransportEvent>,
    drop_next: BTreeSet<WorkerId>,
    duplicate_next: BTreeSet<WorkerId>,
    delay_next: BTreeSet<WorkerId>,
    crash_after: BTreeMap<WorkerId, u64>,
    faults: FaultCounters,
}

impl SimTransport {
    /// An empty transport with no workers and no scheduled faults.
    pub fn new() -> SimTransport {
        SimTransport::default()
    }

    /// Drop the next coordinator→worker frame addressed to `worker`.
    pub fn inject_drop_next(&mut self, worker: WorkerId) {
        self.drop_next.insert(worker);
    }

    /// Deliver `worker`'s next reply twice.
    pub fn inject_duplicate_next(&mut self, worker: WorkerId) {
        self.duplicate_next.insert(worker);
    }

    /// Re-order `worker`'s next reply behind everything already queued
    /// (released only when the live queue runs dry).
    pub fn inject_delay_next(&mut self, worker: WorkerId) {
        self.delay_next.insert(worker);
    }

    /// Kill `worker` the moment its `n`-th subsequent inbound frame
    /// arrives (`n >= 1`), before the frame is processed.
    pub fn crash_on_nth_message(&mut self, worker: WorkerId, n: u64) {
        self.crash_after.insert(worker, n.max(1));
    }

    /// What faults fired so far.
    pub fn faults(&self) -> FaultCounters {
        self.faults
    }

    /// Is this worker's simulated process up?
    pub fn is_alive(&self, worker: WorkerId) -> bool {
        self.nodes.get(&worker).is_some_and(|s| s.alive)
    }
}

impl Transport for SimTransport {
    fn spawn(&mut self, worker: WorkerId) -> Result<()> {
        let node = WorkerNode::new(worker);
        self.events.push_back(TransportEvent::Message(worker, node.join_msg()));
        self.nodes.insert(worker, SimNode { node, alive: true });
        Ok(())
    }

    fn kill(&mut self, worker: WorkerId) {
        if let Some(s) = self.nodes.get_mut(&worker) {
            if s.alive {
                s.alive = false;
                self.events.push_back(TransportEvent::Crashed(worker));
            }
        }
    }

    fn send(&mut self, worker: WorkerId, msg: &Json) -> Result<()> {
        let Some(slot) = self.nodes.get_mut(&worker) else { return Ok(()) };
        if !slot.alive {
            return Ok(()); // dead letter
        }
        if self.drop_next.remove(&worker) {
            self.faults.dropped += 1;
            return Ok(());
        }
        if let Some(left) = self.crash_after.get_mut(&worker) {
            *left -= 1;
            if *left == 0 {
                self.crash_after.remove(&worker);
                slot.alive = false;
                self.faults.forced_crashes += 1;
                self.events.push_back(TransportEvent::Crashed(worker));
                return Ok(());
            }
        }
        // mirror real-process semantics: a worker whose handler errors
        // dies (run_worker propagates the error and exits; the reader
        // thread then reports EOF as a crash)
        let replies = match slot.node.handle(msg) {
            Ok((replies, _)) => replies,
            Err(_) => {
                slot.alive = false;
                self.events.push_back(TransportEvent::Crashed(worker));
                return Ok(());
            }
        };
        for reply in replies {
            let ev = TransportEvent::Message(worker, reply);
            if self.delay_next.remove(&worker) {
                self.faults.delayed += 1;
                self.delayed.push_back(ev);
            } else {
                if self.duplicate_next.remove(&worker) {
                    self.faults.duplicated += 1;
                    self.events.push_back(ev.clone());
                }
                self.events.push_back(ev);
            }
        }
        Ok(())
    }

    fn poll(&mut self, _wait: bool) -> Result<Option<TransportEvent>> {
        if self.events.is_empty() && !self.delayed.is_empty() {
            self.events.append(&mut self.delayed);
        }
        Ok(self.events.pop_front())
    }
}

// ------------------------------------------------------ process transport

/// Real child-process [`Transport`]: spawns `<program> worker --id N`
/// with piped stdin/stdout, one reader thread per child feeding a shared
/// event channel.  EOF or a read error on a child's stdout surfaces as
/// [`TransportEvent::Crashed`]; `send` never reports worker death
/// directly (a broken pipe dead-letters, the crash arrives via `poll`).
pub struct ProcessTransport {
    program: PathBuf,
    poll_timeout: Duration,
    children: HashMap<WorkerId, Child>,
    tx: mpsc::Sender<TransportEvent>,
    rx: mpsc::Receiver<TransportEvent>,
}

impl ProcessTransport {
    /// A transport spawning workers from an explicit binary (tests use
    /// `env!("CARGO_BIN_EXE_rtx")`).
    pub fn new(program: impl Into<PathBuf>) -> ProcessTransport {
        let (tx, rx) = mpsc::channel();
        ProcessTransport {
            program: program.into(),
            poll_timeout: Duration::from_secs(10),
            children: HashMap::new(),
            tx,
            rx,
        }
    }

    /// A transport re-spawning the currently running binary — what
    /// `rtx serve --workers N` uses.
    pub fn current_exe() -> Result<ProcessTransport> {
        Ok(ProcessTransport::new(
            std::env::current_exe().context("cannot locate the running executable")?,
        ))
    }

    /// Bound on one blocking [`Transport::poll`] (default 10 s); after
    /// it, the coordinator presumes in-flight grants lost and re-grants.
    pub fn set_poll_timeout(&mut self, timeout: Duration) {
        self.poll_timeout = timeout;
    }
}

impl Transport for ProcessTransport {
    fn spawn(&mut self, worker: WorkerId) -> Result<()> {
        let mut child = Command::new(&self.program)
            .arg("worker")
            .arg("--id")
            .arg(worker.to_string())
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker {worker} from {:?}", self.program))?;
        let stdout = child.stdout.take().context("worker stdout not piped")?;
        let tx = self.tx.clone();
        std::thread::spawn(move || {
            let mut reader = io::BufReader::new(stdout);
            loop {
                match read_frame(&mut reader) {
                    Ok(Some(msg)) => {
                        if tx.send(TransportEvent::Message(worker, msg)).is_err() {
                            break;
                        }
                    }
                    Ok(None) | Err(_) => {
                        let _ = tx.send(TransportEvent::Crashed(worker));
                        break;
                    }
                }
            }
        });
        if let Some(old) = self.children.insert(worker, child) {
            drop(old); // a rejoin replaces the dead incarnation's handle
        }
        Ok(())
    }

    fn kill(&mut self, worker: WorkerId) {
        if let Some(mut child) = self.children.remove(&worker) {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    fn send(&mut self, worker: WorkerId, msg: &Json) -> Result<()> {
        let Some(child) = self.children.get_mut(&worker) else { return Ok(()) };
        let Some(stdin) = child.stdin.as_mut() else { return Ok(()) };
        // a write into a dying child dead-letters; the reader thread
        // reports the crash through poll
        if write_frame(stdin, msg).and_then(|()| stdin.flush()).is_err() {
            return Ok(());
        }
        Ok(())
    }

    fn poll(&mut self, wait: bool) -> Result<Option<TransportEvent>> {
        if wait {
            Ok(self.rx.recv_timeout(self.poll_timeout).ok())
        } else {
            Ok(self.rx.try_recv().ok())
        }
    }
}

impl Drop for ProcessTransport {
    fn drop(&mut self) {
        for (_, mut child) in self.children.drain() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

// ------------------------------------------------------------ coordinator

/// Shape + head-plan parameters for a [`Coordinator`] (the same plan the
/// serve loop runs: even heads static local window, odd heads
/// local ∪ routed).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Sequence length of every grant.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
    /// Transformer layers.
    pub layers: usize,
    /// Heads per layer.
    pub heads: usize,
    /// Local attention window (the static spec and the routed unions).
    pub window: usize,
    /// Routing clusters per (layer, head).
    pub clusters: usize,
    /// Top-w membership per cluster (per-cluster capacity when
    /// `spec_family` is [`SpecFamily::ExpertChoice`]).
    pub top_w: usize,
    /// The content-based family the odd heads' routed component uses —
    /// must match the serve options driving this coordinator so the
    /// in-process and coordinated digests stay bit-identical.
    pub spec_family: SpecFamily,
    /// Concurrent request slots (routed stream ids span
    /// `layers × heads × capacity`).
    pub capacity: usize,
    /// Routing k-means seed.
    pub seed: u64,
    /// Registered backend name — the coordinator's inline fallback and
    /// every worker (via `hello`) run the same kernel, so outputs are
    /// bit-identical regardless of who computed which rows.
    pub backend: String,
    /// How many times one row-range may be re-granted before the
    /// coordinator computes it inline (bounds fault-storm livelock).
    pub max_regrants: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n: 128,
            d: 32,
            layers: 2,
            heads: 4,
            window: 16,
            clusters: 8,
            top_w: 16,
            spec_family: SpecFamily::Routing,
            capacity: 4,
            seed: 0,
            backend: "reference".to_string(),
            max_regrants: 8,
        }
    }
}

/// Coordinator-side lifecycle state of one worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// Spawned; its `join` has not been processed yet.
    Joining,
    /// Installed and idle — grantable.
    Ready,
    /// Holds an outstanding grant.
    Busy,
    /// Channel dead (crash, kill, or kernel error); may rejoin.
    Crashed,
}

/// The coordinator's grant/membership ledger.  At rest (no outstanding
/// grants) the conservation law holds:
/// `grants == accepted + superseded + voided`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoordStats {
    /// Join messages processed (first joins and rejoins alike).
    pub joins: u64,
    /// Crashed workers re-spawned.
    pub rejoins: u64,
    /// Workers observed crashed (events, kills, kernel errors).
    pub crashes: u64,
    /// Row-range grants issued (re-grants included).
    pub grants: u64,
    /// Grants whose result was accepted (exactly one per completed range).
    pub accepted: u64,
    /// Grants abandoned for a re-grant (lost reply, nack) — their late
    /// results are rejected by task id.
    pub superseded: u64,
    /// Grants voided because their worker crashed.
    pub voided: u64,
    /// Re-issues of a row-range (each one supersedes or follows a void).
    pub regrants: u64,
    /// Results/nacks rejected because their stream epoch is stale.
    pub rejected_stale_epoch: u64,
    /// Results/nacks rejected as duplicates at the current epoch.
    pub rejected_duplicate: u64,
    /// Worker nacks received (missing/stale install → re-ship + re-grant).
    pub nacks: u64,
    /// Spec install broadcasts (not per-worker sends).
    pub spec_installs: u64,
    /// [`RouteUpdate`] delta broadcasts.
    pub delta_broadcasts: u64,
    /// Stream eviction broadcasts (retirement GC).
    pub evict_broadcasts: u64,
    /// Output rows computed by workers.
    pub worker_rows: u64,
    /// Output rows computed inline by the coordinator (no workers alive,
    /// or a range exceeded `max_regrants`).
    pub inline_rows: u64,
}

impl CoordStats {
    /// The grant-ledger conservation law; `true` whenever no grant is
    /// outstanding (i.e. between [`Coordinator`] calls).
    pub fn conserved(&self) -> bool {
        self.grants == self.accepted + self.superseded + self.voided
    }
}

struct StreamSpec {
    plan: Option<(usize, usize)>,
    epoch: u64,
    assignment_epoch: u64,
    spec: Json,
}

struct GrantRec {
    worker: WorkerId,
    rows: Range<usize>,
    regrants: u64,
}

/// The multi-process shard coordinator: owns all routing state and
/// splits each attention call's rows across worker processes via a
/// pluggable [`Transport`].  See the module docs for the protocol and
/// state machine; `tests/coordinator.rs` pins its behavior against a
/// single-process reference model under fault injection.
pub struct Coordinator<T: Transport> {
    cfg: CoordinatorConfig,
    transport: T,
    backend: Arc<dyn Backend>,
    session: RoutingSession,
    cache: EpochCache,
    budget: MemoryBudget,
    members: Vec<MemberCache>,
    regen: RegenStats,
    local: AttentionSpec,
    static_pattern: Arc<CompiledPattern>,
    workers: BTreeMap<WorkerId, WorkerState>,
    next_worker: WorkerId,
    next_task: u64,
    specs: BTreeMap<u64, StreamSpec>,
    stats: CoordStats,
}

impl<T: Transport> Coordinator<T> {
    /// Build the coordinator: validates the config, resolves the
    /// backend, pins the static pattern, and registers the static
    /// stream.  Spawn workers separately with
    /// [`Coordinator::spawn_worker`]; with none, every call falls back
    /// to bit-identical inline execution.
    pub fn new(cfg: CoordinatorConfig, transport: T) -> Result<Coordinator<T>> {
        if cfg.n == 0 || cfg.d == 0 {
            bail!("coordinator requires n >= 1 and d >= 1 (got n = {}, d = {})", cfg.n, cfg.d);
        }
        if cfg.layers == 0 || cfg.heads == 0 || cfg.capacity == 0 {
            bail!(
                "coordinator requires layers, heads, capacity >= 1 (got {}, {}, {})",
                cfg.layers,
                cfg.heads,
                cfg.capacity
            );
        }
        if cfg.window == 0 || cfg.clusters == 0 || cfg.top_w == 0 {
            bail!(
                "coordinator requires window, clusters, top_w >= 1 (got {}, {}, {})",
                cfg.window,
                cfg.clusters,
                cfg.top_w
            );
        }
        let backend = backend::lookup(&cfg.backend).with_context(|| {
            format!(
                "unknown attention backend '{}' (registered: {})",
                cfg.backend,
                backend::names().join(", ")
            )
        })?;
        let session =
            RoutingSession::new(cfg.layers, cfg.heads, cfg.clusters, cfg.d, 0.5, cfg.seed)?;
        let budget = MemoryBudget::unbounded();
        let mut cache = EpochCache::with_budget(budget.clone());
        let local = AttentionSpec::local(cfg.window)?;
        let static_pattern = cache.get_static(&local, cfg.n);
        let members = (0..cfg.layers * cfg.heads * cfg.capacity)
            .map(|_| MemberCache::with_budget(budget.clone()))
            .collect();
        let mut specs = BTreeMap::new();
        specs.insert(
            STATIC_STREAM,
            StreamSpec { plan: None, epoch: 0, assignment_epoch: 0, spec: local.to_json() },
        );
        Ok(Coordinator {
            cfg,
            transport,
            backend,
            session,
            cache,
            budget,
            members,
            regen: RegenStats::default(),
            local,
            static_pattern,
            workers: BTreeMap::new(),
            next_worker: 0,
            next_task: 0,
            specs,
            stats: CoordStats::default(),
        })
    }

    // ----------------------------------------------------- worker control

    /// Spawn a fresh worker; returns its id.  The worker becomes
    /// grantable once its `join` is processed (next [`Coordinator::pump`]
    /// or attention call).
    pub fn spawn_worker(&mut self) -> Result<WorkerId> {
        let id = self.next_worker;
        self.next_worker += 1;
        self.transport.spawn(id)?;
        self.workers.insert(id, WorkerState::Joining);
        Ok(id)
    }

    /// Forcibly kill a worker (test op / administrative drain); its
    /// state moves to [`WorkerState::Crashed`] immediately.
    pub fn kill_worker(&mut self, worker: WorkerId) {
        if let Some(state) = self.workers.get_mut(&worker) {
            if *state != WorkerState::Crashed {
                *state = WorkerState::Crashed;
                self.stats.crashes += 1;
            }
        }
        self.transport.kill(worker);
    }

    /// Re-spawn a crashed worker under its old id; it re-joins with a
    /// full install (all live stream specs at their current epochs).
    pub fn rejoin_worker(&mut self, worker: WorkerId) -> Result<()> {
        match self.workers.get(&worker) {
            Some(WorkerState::Crashed) => {}
            Some(state) => bail!("worker {worker} is {state:?}, not Crashed — cannot rejoin"),
            None => bail!("worker {worker} was never spawned"),
        }
        self.transport.spawn(worker)?;
        self.workers.insert(worker, WorkerState::Joining);
        self.stats.rejoins += 1;
        Ok(())
    }

    /// Drain pending transport events (joins, crash notices, late
    /// replies) without blocking.
    pub fn pump(&mut self) -> Result<()> {
        while let Some(ev) = self.transport.poll(false)? {
            match ev {
                TransportEvent::Message(w, msg) => match msg_type(&msg)? {
                    "join" => self.handle_join(w)?,
                    "result" | "nack" | "error" => self.classify_reject(&msg),
                    other => bail!("unexpected idle message type '{other}' from worker {w}"),
                },
                TransportEvent::Crashed(w) => self.note_crash(w),
            }
        }
        Ok(())
    }

    fn note_crash(&mut self, worker: WorkerId) {
        if let Some(state) = self.workers.get_mut(&worker) {
            if *state != WorkerState::Crashed {
                *state = WorkerState::Crashed;
                self.stats.crashes += 1;
            }
        }
    }

    fn handle_join(&mut self, worker: WorkerId) -> Result<()> {
        if !self.workers.contains_key(&worker) {
            return Ok(()); // join from an id we never spawned: ignore
        }
        let hello = jobj(vec![
            ("type", Json::Str("hello".to_string())),
            ("worker", jnum(worker as u64)),
            ("protocol", jnum(PROTOCOL_VERSION)),
            ("backend", Json::Str(self.cfg.backend.clone())),
            ("n", jnum(self.cfg.n as u64)),
            ("d", jnum(self.cfg.d as u64)),
        ]);
        self.transport.send(worker, &hello)?;
        let installs: Vec<Json> =
            self.specs.iter().map(|(&sid, ss)| spec_msg(sid, ss)).collect();
        for msg in &installs {
            self.transport.send(worker, msg)?;
        }
        self.workers.insert(worker, WorkerState::Ready);
        self.stats.joins += 1;
        Ok(())
    }

    fn broadcast(&mut self, msg: &Json) -> Result<usize> {
        let targets: Vec<WorkerId> = self
            .workers
            .iter()
            .filter(|(_, s)| matches!(s, WorkerState::Ready | WorkerState::Busy))
            .map(|(&w, _)| w)
            .collect();
        for &w in &targets {
            self.transport.send(w, msg)?;
        }
        Ok(targets.len())
    }

    /// A late/duplicated reply with no outstanding grant: stale epoch or
    /// duplicate at the current epoch.
    fn classify_reject(&mut self, msg: &Json) {
        let stream = msg.get("stream").and_then(Json::as_usize).map(|s| s as u64);
        let epoch = msg.get("epoch").and_then(Json::as_usize).map(|e| e as u64);
        let current = stream.and_then(|s| self.specs.get(&s)).map(|ss| ss.epoch);
        if epoch.is_some() && epoch == current {
            self.stats.rejected_duplicate += 1;
        } else {
            self.stats.rejected_stale_epoch += 1;
        }
    }

    // ------------------------------------------------------- routing state

    fn member_index(&self, layer: usize, head: usize, slot: usize) -> usize {
        (layer * self.cfg.heads + head) * self.cfg.capacity + slot
    }

    fn stream_id(&self, layer: usize, head: usize, slot: usize) -> u64 {
        1 + ((layer * self.cfg.heads + head) * self.cfg.capacity + slot) as u64
    }

    /// One online k-means update for `(layer, head)` — the identical
    /// call the in-process serve loop makes, plus the wire side:
    /// the [`RouteUpdate`] (carrying the [`AssignmentDelta`]) is
    /// broadcast so workers either bump stream epochs in place
    /// (nothing moved — the O(1)-wire analogue of the epoch-cache
    /// unchanged-epoch hit) or drop their now-stale compiles (tokens
    /// moved — fresh specs ship lazily before the next grant).
    ///
    /// [`AssignmentDelta`]: crate::kmeans::AssignmentDelta
    pub fn update(&mut self, layer: usize, head: usize, xs: &[f32], n: usize) -> Result<RouteUpdate> {
        if layer >= self.cfg.layers || head >= self.cfg.heads {
            bail!("update({layer}, {head}) out of range for {}x{}", self.cfg.layers, self.cfg.heads);
        }
        let upd = self.session.update(layer, head, xs, n);
        if upd.delta.assigned > 0 {
            let msg = jobj(vec![
                ("type", Json::Str("delta".to_string())),
                ("layer", jnum(layer as u64)),
                ("head", jnum(head as u64)),
                ("update", upd.to_json()),
            ]);
            self.broadcast(&msg)?;
            self.stats.delta_broadcasts += 1;
            for slot in 0..self.cfg.capacity {
                let sid = self.stream_id(layer, head, slot);
                if upd.delta.changed() {
                    // stale everywhere; re-shipped on next routed call
                    self.specs.remove(&sid);
                } else if let Some(ss) = self.specs.get_mut(&sid) {
                    ss.epoch = upd.epoch;
                }
            }
        }
        Ok(upd)
    }

    /// Step-protect cache entries the coming lookups touch (identical to
    /// the in-process loop's [`EpochCache::mark_step`]).
    pub fn mark_step(&mut self) {
        self.cache.mark_step();
    }

    /// Retirement GC for one request slot: forget its routed streams on
    /// every worker, and fold + reset its [`MemberCache`]s.  The
    /// [`EpochCache`] half happens where it always has — the serve
    /// scheduler's `finish_step(&mut cache)` (via
    /// [`Coordinator::cache_mut`]) or [`Coordinator::evict_slot`].
    pub fn retire_slot(&mut self, slot: usize) -> Result<()> {
        for layer in 0..self.cfg.layers {
            for head in 0..self.cfg.heads {
                let sid = self.stream_id(layer, head, slot);
                if self.specs.remove(&sid).is_some() {
                    let msg = jobj(vec![
                        ("type", Json::Str("evict".to_string())),
                        ("stream", jnum(sid)),
                    ]);
                    self.broadcast(&msg)?;
                    self.stats.evict_broadcasts += 1;
                }
                let idx = self.member_index(layer, head, slot);
                let budget = self.budget.clone();
                let mc = &mut self.members[idx];
                self.regen.merge(mc.stats());
                *mc = MemberCache::with_budget(budget);
            }
        }
        Ok(())
    }

    /// Evict one routed `(layer, head, slot)` compile from the epoch
    /// cache *and* the wire (workers drop the stream too).  Returns the
    /// heap bytes freed, as [`EpochCache::evict_slot`] does.
    pub fn evict_slot(&mut self, layer: usize, head: usize, slot: usize) -> Result<Option<usize>> {
        let bytes = self.cache.evict_slot(RouteSlot { layer, head, seq: slot });
        let sid = self.stream_id(layer, head, slot);
        if self.specs.remove(&sid).is_some() {
            let msg =
                jobj(vec![("type", Json::Str("evict".to_string())), ("stream", jnum(sid))]);
            self.broadcast(&msg)?;
            self.stats.evict_broadcasts += 1;
        }
        Ok(bytes)
    }

    // ---------------------------------------------------------- attention

    /// Shared static-pattern attention for one sequence, split across
    /// workers; returns the output and the pattern's MAC cost.
    pub fn static_attention(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<(Vec<f32>, u64)> {
        let pattern = Arc::clone(&self.static_pattern);
        let cost = pattern.cost(self.cfg.d);
        let out = self.execute(STATIC_STREAM, &pattern, 0, q, k, v)?;
        Ok((out, cost))
    }

    /// Routed attention for one `(layer, head, slot)`: serves the
    /// compile through the epoch cache exactly as the in-process loop
    /// does (assignment-epoch keyed, dirty-cluster-only membership
    /// regeneration), ships the spec to workers only when its stamp
    /// moved, then splits the rows.  Returns the output and the
    /// pattern's MAC cost.
    #[allow(clippy::too_many_arguments)]
    pub fn routed_attention(
        &mut self,
        layer: usize,
        head: usize,
        slot: usize,
        xs: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<(Vec<f32>, u64)> {
        if layer >= self.cfg.layers || head >= self.cfg.heads || slot >= self.cfg.capacity {
            bail!(
                "routed_attention({layer}, {head}, {slot}) out of range for {}x{}x{}",
                self.cfg.layers,
                self.cfg.heads,
                self.cfg.capacity
            );
        }
        let epoch = self.session.epoch(layer, head);
        let ae = self.session.assignment_epoch(layer, head);
        let sid = self.stream_id(layer, head, slot);
        let idx = self.member_index(layer, head, slot);
        let (n, top_w) = (self.cfg.n, self.cfg.top_w);
        let family = self.cfg.spec_family;
        let mut made: Option<AttentionSpec> = None;
        let pattern = {
            let Coordinator { ref mut cache, ref session, ref mut members, ref local, .. } = *self;
            let mc = &mut members[idx];
            cache.get_routed_at(RouteSlot { layer, head, seq: slot }, epoch, ae, n, || {
                let spec = AttentionSpec::union(vec![
                    local.clone(),
                    routed_family_spec(family, session, layer, head, mc, xs, n, top_w),
                ])
                .expect("non-empty union of valid specs");
                made = Some(spec.clone());
                spec
            })
        };
        let need_ship = match self.specs.get_mut(&sid) {
            Some(ss) if ss.assignment_epoch == ae => {
                ss.epoch = epoch; // workers were bumped by the delta broadcast
                false
            }
            _ => true,
        };
        if need_ship {
            // the stamp can only go stale through an assignment-epoch
            // move or a retirement, and both evict the cached compile
            // too — so the cache miss above regenerated the spec
            let spec = made.expect("a stale spec stamp implies a cache miss");
            let ss = StreamSpec {
                plan: Some((layer, head)),
                epoch,
                assignment_epoch: ae,
                spec: spec.to_json(),
            };
            let msg = spec_msg(sid, &ss);
            self.specs.insert(sid, ss);
            self.broadcast(&msg)?;
            self.stats.spec_installs += 1;
        }
        let cost = pattern.cost(self.cfg.d);
        let out = self.execute(sid, &pattern, epoch, q, k, v)?;
        Ok((out, cost))
    }

    /// The grant/collect engine: split `pattern`'s rows nnz-balanced
    /// over ready workers, grant each shard, and collect results with
    /// exactly-once accounting.  Crashes void grants (re-granted to
    /// survivors), quiet transports supersede them, nacks re-ship the
    /// spec first, and a range that exceeds `max_regrants` — or a call
    /// with no workers at all — is computed inline with the same
    /// backend, so the output is bit-identical no matter who computed
    /// which rows.
    fn execute(
        &mut self,
        stream: u64,
        pattern: &Arc<CompiledPattern>,
        epoch: u64,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<Vec<f32>> {
        let (n, d) = (self.cfg.n, self.cfg.d);
        if q.len() != n * d || k.len() != n * d || v.len() != n * d {
            bail!(
                "execute requires [n, d] = [{n}, {d}] q/k/v (got {}, {}, {})",
                q.len(),
                k.len(),
                v.len()
            );
        }
        let backend = Arc::clone(&self.backend);
        let mut out = vec![0f32; n * d];
        self.pump()?;
        let ready: Vec<WorkerId> = self
            .workers
            .iter()
            .filter(|(_, s)| **s == WorkerState::Ready)
            .map(|(&w, _)| w)
            .collect();
        if ready.is_empty() {
            backend.attention_rows(q, k, v, d, pattern, 0..n, &mut out)?;
            self.stats.inline_rows += n as u64;
            return Ok(out);
        }
        let sharded = ShardedPattern::balanced(Arc::clone(pattern), ready.len())?;
        let mut pending: VecDeque<(Range<usize>, u64)> = sharded
            .shards()
            .iter()
            .filter(|s| s.n_rows() > 0)
            .map(|s| (s.rows.clone(), 0u64))
            .collect();
        let mut outstanding: HashMap<u64, GrantRec> = HashMap::new();
        loop {
            // hand every queued range to a ready worker (or inline it
            // when it has exhausted its re-grant budget)
            while let Some((rows, regrants)) = pending.pop_front() {
                if regrants > self.cfg.max_regrants {
                    backend.attention_rows(
                        q,
                        k,
                        v,
                        d,
                        pattern,
                        rows.clone(),
                        &mut out[rows.start * d..rows.end * d],
                    )?;
                    self.stats.inline_rows += rows.len() as u64;
                    continue;
                }
                let Some(w) = self.first_ready() else {
                    pending.push_front((rows, regrants));
                    break;
                };
                let task = self.next_task;
                self.next_task += 1;
                let msg = grant_msg(task, stream, epoch, &rows, q, k, v);
                self.transport.send(w, &msg)?;
                self.workers.insert(w, WorkerState::Busy);
                outstanding.insert(task, GrantRec { worker: w, rows, regrants });
                self.stats.grants += 1;
                if regrants > 0 {
                    self.stats.regrants += 1;
                }
            }
            if outstanding.is_empty() && pending.is_empty() {
                break;
            }
            if outstanding.is_empty()
                && !self
                    .workers
                    .values()
                    .any(|s| matches!(s, WorkerState::Ready | WorkerState::Joining))
            {
                // nobody left to wake us: fold the queue in inline
                for (rows, _) in pending.drain(..) {
                    backend.attention_rows(
                        q,
                        k,
                        v,
                        d,
                        pattern,
                        rows.clone(),
                        &mut out[rows.start * d..rows.end * d],
                    )?;
                    self.stats.inline_rows += rows.len() as u64;
                }
                break;
            }
            match self.transport.poll(true)? {
                Some(TransportEvent::Message(w, msg)) => match msg_type(&msg)? {
                    "join" => self.handle_join(w)?,
                    "result" => {
                        let task = field_u64(&msg, "task")?;
                        match outstanding.remove(&task) {
                            Some(g) => {
                                let rows = field_rows(&msg)?;
                                if rows != g.rows || field_u64(&msg, "epoch")? != epoch {
                                    bail!("worker {w} echoed a corrupted grant for task {task}");
                                }
                                let vals = floats_from_json(field(&msg, "out")?)?;
                                if vals.len() != g.rows.len() * d {
                                    bail!(
                                        "worker {w} returned {} values for {} rows",
                                        vals.len(),
                                        g.rows.len()
                                    );
                                }
                                out[g.rows.start * d..g.rows.end * d].copy_from_slice(&vals);
                                self.stats.accepted += 1;
                                self.stats.worker_rows += g.rows.len() as u64;
                                self.mark_idle_if_done(g.worker, &outstanding);
                            }
                            None => {
                                self.classify_reject(&msg);
                                self.mark_idle_if_done(w, &outstanding);
                            }
                        }
                    }
                    "nack" => {
                        self.stats.nacks += 1;
                        let task = field_u64(&msg, "task")?;
                        match outstanding.remove(&task) {
                            Some(g) => {
                                // the worker lost its install (dropped
                                // spec/delta): re-ship, then re-queue
                                if let Some(ss) = self.specs.get(&stream) {
                                    let reinstall = spec_msg(stream, ss);
                                    self.transport.send(w, &reinstall)?;
                                }
                                self.stats.superseded += 1;
                                self.mark_idle_if_done(g.worker, &outstanding);
                                pending.push_back((g.rows, g.regrants + 1));
                            }
                            None => self.classify_reject(&msg),
                        }
                    }
                    "error" => {
                        // kernel failure: retire this worker, re-grant
                        // its ranges to survivors
                        self.kill_worker(w);
                        let dead: Vec<u64> = outstanding
                            .iter()
                            .filter(|(_, g)| g.worker == w)
                            .map(|(&t, _)| t)
                            .collect();
                        for t in dead {
                            let g = outstanding.remove(&t).expect("task listed above");
                            self.stats.voided += 1;
                            pending.push_back((g.rows, g.regrants + 1));
                        }
                    }
                    other => bail!("unexpected message type '{other}' from worker {w}"),
                },
                Some(TransportEvent::Crashed(w)) => {
                    self.note_crash(w);
                    let dead: Vec<u64> = outstanding
                        .iter()
                        .filter(|(_, g)| g.worker == w)
                        .map(|(&t, _)| t)
                        .collect();
                    for t in dead {
                        let g = outstanding.remove(&t).expect("task listed above");
                        self.stats.voided += 1;
                        pending.push_back((g.rows, g.regrants + 1));
                    }
                }
                None => {
                    if outstanding.is_empty() {
                        // only Joining workers could wake us and none
                        // did: compute the queue inline
                        for (rows, _) in pending.drain(..) {
                            backend.attention_rows(
                                q,
                                k,
                                v,
                                d,
                                pattern,
                                rows.clone(),
                                &mut out[rows.start * d..rows.end * d],
                            )?;
                            self.stats.inline_rows += rows.len() as u64;
                        }
                    } else {
                        // quiet transport: presume in-flight results
                        // lost and supersede every outstanding grant
                        let tasks: Vec<u64> = outstanding.keys().copied().collect();
                        for t in tasks {
                            let g = outstanding.remove(&t).expect("task listed above");
                            self.stats.superseded += 1;
                            self.mark_idle_if_done(g.worker, &outstanding);
                            pending.push_back((g.rows, g.regrants + 1));
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    fn first_ready(&self) -> Option<WorkerId> {
        self.workers.iter().find(|(_, s)| **s == WorkerState::Ready).map(|(&w, _)| w)
    }

    /// A Busy worker with no remaining outstanding grant is Ready again.
    fn mark_idle_if_done(&mut self, worker: WorkerId, outstanding: &HashMap<u64, GrantRec>) {
        if self.workers.get(&worker) == Some(&WorkerState::Busy)
            && !outstanding.values().any(|g| g.worker == worker)
        {
            self.workers.insert(worker, WorkerState::Ready);
        }
    }

    // -------------------------------------------------------- observation

    /// Grant/membership ledger counters.
    pub fn stats(&self) -> CoordStats {
        self.stats
    }

    /// Compile-cache counters (identical evolution to the in-process
    /// serve loop's [`EpochCache`]).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Assignment-epoch hit/miss counters.
    pub fn epoch_stats(&self) -> EpochCacheStats {
        self.cache.epoch_stats()
    }

    /// Membership-regeneration counters: retirements already folded plus
    /// every live [`MemberCache`].
    pub fn regen_total(&self) -> RegenStats {
        let mut total = self.regen;
        for mc in &self.members {
            total.merge(mc.stats());
        }
        total
    }

    /// Compiled patterns currently resident (pinned static included).
    pub fn live_patterns(&self) -> usize {
        self.cache.len()
    }

    /// The shared byte meter (peak / resident / evicted).
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// The routing session (epochs, assignment epochs, k-means state).
    pub fn session(&self) -> &RoutingSession {
        &self.session
    }

    /// The epoch cache — the serve scheduler's `finish_step` needs
    /// `&mut` access for retirement GC, exactly as in-process.
    pub fn cache_mut(&mut self) -> &mut EpochCache {
        &mut self.cache
    }

    /// One worker's lifecycle state.
    pub fn worker_state(&self, worker: WorkerId) -> Option<WorkerState> {
        self.workers.get(&worker).copied()
    }

    /// Workers ever spawned (crashed ones included).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Workers currently not crashed.
    pub fn alive_count(&self) -> usize {
        self.workers.values().filter(|s| !matches!(s, WorkerState::Crashed)).count()
    }

    /// The configured backend's registry name.
    pub fn backend_name(&self) -> &str {
        &self.cfg.backend
    }

    /// Direct access to the transport — how tests schedule
    /// [`SimTransport`] faults mid-sequence.
    pub fn transport_mut(&mut self) -> &mut T {
        &mut self.transport
    }

    /// Politely stop every live worker (then hard-kill their channels).
    /// Every worker ends [`WorkerState::Crashed`]; an administrative
    /// drain is not a fault, so [`CoordStats::crashes`] is untouched.
    pub fn shutdown(&mut self) {
        let msg = jobj(vec![("type", Json::Str("shutdown".to_string()))]);
        let targets: Vec<WorkerId> = self.workers.keys().copied().collect();
        for w in targets {
            if !matches!(self.workers[&w], WorkerState::Crashed) {
                let _ = self.transport.send(w, &msg);
            }
            self.transport.kill(w);
            self.workers.insert(w, WorkerState::Crashed);
        }
    }
}

fn spec_msg(stream: u64, ss: &StreamSpec) -> Json {
    let mut fields = vec![
        ("type", Json::Str("spec".to_string())),
        ("stream", jnum(stream)),
        ("epoch", jnum(ss.epoch)),
        ("assignment_epoch", jnum(ss.assignment_epoch)),
    ];
    if let Some((layer, head)) = ss.plan {
        fields.push(("layer", jnum(layer as u64)));
        fields.push(("head", jnum(head as u64)));
    }
    fields.push(("spec", ss.spec.clone()));
    jobj(fields)
}

fn grant_msg(task: u64, stream: u64, epoch: u64, rows: &Range<usize>, q: &[f32], k: &[f32], v: &[f32]) -> Json {
    jobj(vec![
        ("type", Json::Str("grant".to_string())),
        ("task", jnum(task)),
        ("stream", jnum(stream)),
        ("epoch", jnum(epoch)),
        ("rows", Json::Arr(vec![jnum(rows.start as u64), jnum(rows.end as u64)])),
        ("q", floats_to_json(q)),
        ("k", floats_to_json(k)),
        ("v", floats_to_json(v)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecs(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn frame_roundtrip_and_eof() {
        let msgs = vec![
            jobj(vec![("type", Json::Str("join".to_string())), ("worker", jnum(3))]),
            Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(true)]),
            Json::Str("π ≠ 3".to_string()),
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, m).unwrap();
        }
        let mut r = io::Cursor::new(buf.clone());
        for m in &msgs {
            let got = read_frame(&mut r).unwrap().expect("frame present");
            assert_eq!(got.to_string(), m.to_string());
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at boundary");
        // EOF mid-frame is an error, not a silent None
        let mut truncated = io::Cursor::new(buf[..buf.len() - 1].to_vec());
        for _ in 0..2 {
            read_frame(&mut truncated).unwrap();
        }
        assert!(read_frame(&mut truncated).is_err(), "mid-frame EOF must error");
    }

    #[test]
    fn floats_survive_wire_bit_exactly() {
        let mut rng = Rng::new(7);
        let xs = vecs(&mut rng, 257);
        let text = floats_to_json(&xs).to_string();
        let back = floats_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 -> json -> f32 must be bit-exact");
        }
    }

    #[test]
    fn sim_static_attention_matches_inline() {
        let mut rng = Rng::new(11);
        let cfg = CoordinatorConfig {
            n: 16,
            d: 4,
            layers: 1,
            heads: 2,
            window: 3,
            clusters: 2,
            top_w: 4,
            capacity: 2,
            ..CoordinatorConfig::default()
        };
        let inline = {
            let spec = AttentionSpec::local(cfg.window).unwrap();
            Arc::new(spec.compile(cfg.n))
        };
        let backend = backend::lookup("reference").unwrap();
        let mut coord = Coordinator::new(cfg.clone(), SimTransport::new()).unwrap();
        coord.spawn_worker().unwrap();
        coord.spawn_worker().unwrap();
        let (q, k, v) =
            (vecs(&mut rng, 16 * 4), vecs(&mut rng, 16 * 4), vecs(&mut rng, 16 * 4));
        let (out, cost) = coord.static_attention(&q, &k, &v).unwrap();
        let expect = backend.attention(&q, &k, &v, 4, &inline).unwrap();
        assert_eq!(out, expect, "coordinated static attention must be bit-identical");
        assert_eq!(cost, inline.cost(4));
        let st = coord.stats();
        assert!(st.conserved(), "ledger must conserve: {st:?}");
        assert_eq!(st.worker_rows, 16);
        assert_eq!(st.inline_rows, 0);
        assert_eq!(st.joins, 2);
    }

    #[test]
    fn crash_regrants_to_survivor_and_rejoin_works() {
        let mut rng = Rng::new(13);
        let cfg = CoordinatorConfig {
            n: 24,
            d: 3,
            layers: 1,
            heads: 2,
            window: 4,
            clusters: 2,
            top_w: 6,
            capacity: 1,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, SimTransport::new()).unwrap();
        let w0 = coord.spawn_worker().unwrap();
        coord.spawn_worker().unwrap();
        // worker 0 dies on its next inbound frame (the grant)
        coord.transport_mut().crash_on_nth_message(w0, 1);
        let (q, k, v) =
            (vecs(&mut rng, 24 * 3), vecs(&mut rng, 24 * 3), vecs(&mut rng, 24 * 3));
        // pump first so both joins are processed and w0's fault hits a grant
        coord.pump().unwrap();
        let (out, _) = coord.static_attention(&q, &k, &v).unwrap();
        let backend = backend::lookup("reference").unwrap();
        let spec = AttentionSpec::local(4).unwrap();
        let expect = backend.attention(&q, &k, &v, 3, &Arc::new(spec.compile(24))).unwrap();
        assert_eq!(out, expect);
        let st = coord.stats();
        assert!(st.conserved(), "ledger must conserve after a crash: {st:?}");
        assert_eq!(st.crashes, 1);
        assert_eq!(st.voided, 1, "the dead worker's grant is voided exactly once");
        assert_eq!(coord.worker_state(w0), Some(WorkerState::Crashed));
        // rejoin and verify the worker serves again
        coord.rejoin_worker(w0).unwrap();
        let (out2, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_eq!(out2, expect);
        assert_eq!(coord.stats().rejoins, 1);
        assert_eq!(coord.worker_state(w0), Some(WorkerState::Ready));
    }

    #[test]
    fn dropped_grant_is_superseded_and_duplicate_rejected() {
        let mut rng = Rng::new(17);
        let cfg = CoordinatorConfig {
            n: 12,
            d: 2,
            layers: 1,
            heads: 1,
            window: 2,
            clusters: 1,
            top_w: 3,
            capacity: 1,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, SimTransport::new()).unwrap();
        let w0 = coord.spawn_worker().unwrap();
        coord.pump().unwrap();
        let (q, k, v) =
            (vecs(&mut rng, 12 * 2), vecs(&mut rng, 12 * 2), vecs(&mut rng, 12 * 2));
        // drop the grant itself: the quiet transport forces a re-grant
        coord.transport_mut().inject_drop_next(w0);
        let (out, _) = coord.static_attention(&q, &k, &v).unwrap();
        let st = coord.stats();
        assert_eq!(st.superseded, 1, "the lost grant is superseded: {st:?}");
        assert!(st.conserved());
        // duplicate the next result: second copy must be rejected
        coord.transport_mut().inject_duplicate_next(w0);
        let (out2, _) = coord.static_attention(&q, &k, &v).unwrap();
        assert_eq!(out, out2);
        // the duplicated copy may drain during this call or the next pump
        coord.pump().unwrap();
        let st = coord.stats();
        assert_eq!(
            st.rejected_duplicate + st.rejected_stale_epoch,
            1,
            "the duplicated result is rejected exactly once: {st:?}"
        );
        assert!(st.conserved());
    }

    #[test]
    fn routed_attention_ships_specs_and_deltas() {
        let mut rng = Rng::new(19);
        let (n, d) = (16, 3);
        let cfg = CoordinatorConfig {
            n,
            d,
            layers: 1,
            heads: 2,
            window: 3,
            clusters: 2,
            top_w: 4,
            capacity: 1,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, SimTransport::new()).unwrap();
        coord.spawn_worker().unwrap();
        coord.pump().unwrap();
        let xs = vecs(&mut rng, n * d);
        let (q, k, v) = (vecs(&mut rng, n * d), vecs(&mut rng, n * d), vecs(&mut rng, n * d));
        let (out1, _) = coord.routed_attention(0, 1, 0, &xs, &q, &k, &v).unwrap();
        assert_eq!(coord.stats().spec_installs, 1, "first routed call ships the spec");
        // an update that moves nothing is a delta broadcast, not a re-ship
        let upd = coord.update(0, 1, &xs, n).unwrap();
        let (out2, _) = coord.routed_attention(0, 1, 0, &xs, &q, &k, &v).unwrap();
        let st = coord.stats();
        assert_eq!(st.delta_broadcasts, 1);
        if !upd.delta.changed() {
            assert_eq!(st.spec_installs, 1, "unchanged assignments must not re-ship the spec");
            assert_eq!(out1, out2, "same assignments, same pattern, same output");
        } else {
            assert_eq!(st.spec_installs, 2, "moved assignments re-ship the spec");
        }
        assert_eq!(st.nacks, 0, "no nacks on the happy path: {st:?}");
        assert!(st.conserved());
        // epoch-cache counters behave exactly like the in-process loop
        assert_eq!(coord.epoch_stats().epoch_hits + coord.epoch_stats().epoch_misses, 2);
    }

    #[test]
    fn no_workers_falls_back_inline() {
        let mut rng = Rng::new(23);
        let cfg = CoordinatorConfig {
            n: 8,
            d: 2,
            layers: 1,
            heads: 1,
            window: 2,
            clusters: 1,
            top_w: 2,
            capacity: 1,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, SimTransport::new()).unwrap();
        let (q, k, v) = (vecs(&mut rng, 16), vecs(&mut rng, 16), vecs(&mut rng, 16));
        let (out, _) = coord.static_attention(&q, &k, &v).unwrap();
        let backend = backend::lookup("reference").unwrap();
        let spec = AttentionSpec::local(2).unwrap();
        let expect = backend.attention(&q, &k, &v, 2, &Arc::new(spec.compile(8))).unwrap();
        assert_eq!(out, expect);
        let st = coord.stats();
        assert_eq!(st.inline_rows, 8);
        assert_eq!(st.grants, 0);
    }
}

//! Decode-loop serving layer: epoch-keyed routing state and cross-request
//! batching on top of the pattern engine.
//!
//! The paper's O(n^1.5 d) win assumes routing assignments are *recomputed
//! as content changes* — online spherical k-means (Algorithm 1) moves
//! centroids every update, so a decode loop cannot treat a compiled
//! routing pattern as immutable the way it treats a local or strided one.
//! This module adds the three serving pieces the engine deliberately left
//! out:
//!
//! * [`RoutingSession`] — per-layer/per-head [`SphericalKMeans`] state
//!   with two counters per slot, both advanced by
//!   [`RoutingSession::update`] and both monotone: the **cluster epoch**
//!   (bumped by every non-empty update — centroids moved) and the
//!   **assignment epoch** (advanced only when the update's
//!   [`AssignmentDelta`] actually moved a token between clusters).  The
//!   assignment epoch is the cache-coherence token: MoSA-style
//!   expert-choice routing observes most assignments are stable step to
//!   step, so a compiled routing pattern is kept across centroid drift
//!   until an argmax assignment really changes.  This reuse is a
//!   deliberate approximation: a centroid step can reorder a top-w
//!   ranking without moving any argmax (exact only at `w == n`) — see
//!   [`AssignmentDelta::changed`]; use [`EpochCache::get_routed`]'s
//!   strict cluster-epoch keying when per-epoch exactness matters more
//!   than recompile cost.  Each slot also accumulates a **dirty set** —
//!   the tokens moved since the set was last drained
//!   ([`RoutingSession::take_dirty`]) — the worklist an incremental
//!   re-router consumes.  Dirty indices are positions in the `xs`
//!   batches handed to `update`, so the worklist is meaningful only
//!   when a slot's updates use one consistent batch shape (as
//!   serve-bench does).  An empty (`n == 0`) update is a strict no-op:
//!   no epoch bump, no dirty tokens.
//! * [`EpochCache`] — a generation-aware cache pairing a pinned
//!   [`PatternCache`](super::PatternCache) for static specs (local/strided
//!   head-plan parts, kept forever) with slot-owned routed compiles: each
//!   routed slot ((layer, head, sequence), see [`RouteSlot`]) holds
//!   exactly one live pattern tagged with the assignment epoch it was
//!   built from.  [`EpochCache::get_routed_at`] serves the live compile
//!   while the assignment epoch matches — including across cluster-epoch
//!   bumps that moved nothing, which count as
//!   [`EpochCacheStats::unchanged_epochs`] hits instead of evictions.
//!   Only a lookup whose assignment epoch moved drops the superseded
//!   compile (counted in [`CacheStats::evictions`] via the merged stats)
//!   and regenerates the spec via the caller's closure — so a pattern
//!   compiled from superseded assignments is never served, and the cache
//!   stays bounded at one live pattern per slot.
//! * [`BatchedAttention`] / [`sparse_attention_batch`] — cross-request
//!   batching: B independent sequences (`[B, n, d]` row-major q/k/v, one
//!   compiled pattern per sequence or one shared pattern) run through a
//!   single nnz-balanced sweep instead of B separate kernel calls,
//!   executed on the resident [`super::pool::WorkerPool`] by default
//!   ([`BatchedAttention::attention_with`] takes a per-call
//!   [`Execution`] override, [`BatchedAttention::attention_backend`] a
//!   per-call kernel [`Backend`](super::backend::Backend)).  The per-row
//!   math is exactly
//!   [`sparse_attention_rows`](super::sparse_attention_rows), making the
//!   batched output **bit-identical** to B independent
//!   [`sparse_attention`](super::sparse_attention) calls.
//!
//! # Slot lifecycle (birth → serve → re-route → retire)
//!
//! A [`RouteSlot`] is born on its first routed lookup: the miss runs the
//! caller's spec closure, compiles it, and parks the compile on the slot
//! tagged with the current assignment epoch.  While the slot's
//! assignment epoch holds, every lookup is an O(1) hit (cluster-epoch
//! bumps that moved nothing included).  When a k-means update moves
//! tokens, the next lookup evicts the stale compile and regenerates —
//! and with a [`MemberCache`] the regeneration itself re-ranks only the
//! clusters the update's [`AssignmentDelta`] touched (per-cluster
//! version counters; untouched centroids are bit-unchanged, so their
//! cached lists stay exact).  When the request ends — the stream closes,
//! the sequence is retired — the serving loop must call
//! [`EpochCache::evict_slot`] (as `rtx serve-bench` does after its sweep)
//! so the per-request compile is garbage-collected instead of leaking;
//! the eviction is counted in [`CacheStats::evictions`] and the slot's
//! next lookup (if any) recompiles from scratch.  Static head-plan
//! compiles are shared across requests and deliberately survive
//! retirement.
//!
//! Consumers: `rtx serve-bench` (`--sequences`/`--route-every`/`--pool`/
//! `--backend`/`--json`, printing epoch hit-rate, unchanged-epoch hits,
//! eviction count, dirty tokens, membership rows regenerated vs reused,
//! and per-backend plus batched-vs-sequential rows/sec),
//! `bench_complexity` (batched ≥ 2× sequential at B = 8; pool ≥ 1.3×
//! scoped; incremental regeneration counter-verified),
//! `examples/analyze_attention.rs`, the decode property tests,
//! and the stateful model-based suite (`tests/stateful.rs`).

use std::collections::{BTreeSet, HashMap};
use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::compiled::{CompiledPattern, MemoryBudget};
use super::engine::{CacheStats, PatternCache};
use super::pool::Execution;
use super::spec::AttentionSpec;
use crate::kmeans::{AssignmentDelta, SphericalKMeans};
use crate::util::json::Json;

// -------------------------------------------------------------- session

/// A routed cache slot: one (layer, head) of one request's sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RouteSlot {
    /// Transformer layer index.
    pub layer: usize,
    /// Head index within the layer.
    pub head: usize,
    /// Request/sequence index within a batch (0 for single-sequence use).
    pub seq: usize,
}

/// What one [`RoutingSession::update`] did to a slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteUpdate {
    /// The slot's cluster epoch after the update (bumped iff the batch
    /// was non-empty).
    pub epoch: u64,
    /// The slot's assignment epoch after the update (advanced to `epoch`
    /// iff the update moved at least one token between clusters).
    pub assignment_epoch: u64,
    /// The k-means delta: per-cluster counts plus the moved tokens.
    pub delta: AssignmentDelta,
}

impl RouteUpdate {
    /// Wire form: `{"epoch": E, "assignment_epoch": A, "delta": {...}}` —
    /// what the multi-process coordinator broadcasts after each k-means
    /// update so workers can bump (or drop) their installed compiles.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("epoch".to_string(), Json::Num(self.epoch as f64)),
            ("assignment_epoch".to_string(), Json::Num(self.assignment_epoch as f64)),
            ("delta".to_string(), self.delta.to_json()),
        ])
    }

    /// Parse the [`RouteUpdate::to_json`] wire form; round-trips to an
    /// identical value (`to_json ∘ from_json ≡ id`).
    pub fn from_json(j: &Json) -> Result<RouteUpdate> {
        let epoch = j
            .get("epoch")
            .and_then(Json::as_i64)
            .and_then(|e| u64::try_from(e).ok())
            .context("route update missing 'epoch'")?;
        let assignment_epoch = j
            .get("assignment_epoch")
            .and_then(Json::as_i64)
            .and_then(|e| u64::try_from(e).ok())
            .context("route update missing 'assignment_epoch'")?;
        let delta = AssignmentDelta::from_json(
            j.get("delta").context("route update missing 'delta'")?,
        )?;
        Ok(RouteUpdate { epoch, assignment_epoch, delta })
    }
}

/// Per-layer/per-head online k-means routing state for a decode session.
///
/// Owns one [`SphericalKMeans`] per (layer, head) slot plus that slot's
/// **cluster epoch** (bumped by every non-empty
/// [`RoutingSession::update`]), **assignment epoch** (advanced only when
/// an update's [`AssignmentDelta`] moved a token — the token the
/// [`EpochCache`] keys invalidation on), and **dirty set** (tokens moved
/// since [`RoutingSession::take_dirty`] last drained it).  Epochs advance
/// independently per slot (layers may re-route on different schedules).
/// A pattern compiled under an older *assignment* epoch is stale; a
/// pattern whose assignment epoch is current stays servable even while
/// the cluster epoch keeps bumping past it.
#[derive(Debug, Clone)]
pub struct RoutingSession {
    /// Process-unique id stamped at construction (clones share it — a
    /// clone's centroids are bit-identical, so member-cache reuse across
    /// the clone stays exact; a *new* session gets a fresh nonce so a
    /// surviving [`MemberCache`] can never mistake its versions for the
    /// old session's and serve stale lists).
    nonce: u64,
    layers: usize,
    heads: usize,
    k: usize,
    kms: Vec<SphericalKMeans>,
    epochs: Vec<u64>,
    assignment_epochs: Vec<u64>,
    dirty: Vec<BTreeSet<usize>>,
    /// Per-slot, per-cluster monotone version counters: bumped whenever an
    /// update EMA-moved that cluster's centroid (`delta.counts[c] > 0`).
    /// A cluster whose version has not moved since a membership list was
    /// built has a bit-unchanged centroid, so the list is still exact —
    /// the invariant the incremental regeneration path relies on.
    cluster_versions: Vec<Vec<u64>>,
    /// Per-slot dirty *cluster* sets: clusters touched since the set was
    /// last drained via [`RoutingSession::take_dirty_clusters`].
    dirty_clusters: Vec<BTreeSet<usize>>,
}

impl RoutingSession {
    /// One k-means instance per (layer, head), independently seeded.
    pub fn new(
        layers: usize,
        heads: usize,
        k: usize,
        dim: usize,
        decay: f32,
        seed: u64,
    ) -> Result<RoutingSession> {
        if layers == 0 || heads == 0 {
            bail!("routing session requires layers >= 1 and heads >= 1 (got {layers} x {heads})");
        }
        if k == 0 || dim == 0 {
            bail!("routing session requires k >= 1 clusters and dim >= 1 (got k = {k}, dim = {dim})");
        }
        let kms = (0..layers * heads)
            .map(|s| {
                SphericalKMeans::new(k, dim, decay, seed ^ (s as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
            })
            .collect();
        static NONCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        Ok(RoutingSession {
            nonce: NONCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            layers,
            heads,
            k,
            kms,
            epochs: vec![0; layers * heads],
            assignment_epochs: vec![0; layers * heads],
            dirty: vec![BTreeSet::new(); layers * heads],
            cluster_versions: vec![vec![0; k]; layers * heads],
            dirty_clusters: vec![BTreeSet::new(); layers * heads],
        })
    }

    fn slot(&self, layer: usize, head: usize) -> usize {
        assert!(
            layer < self.layers && head < self.heads,
            "slot ({layer}, {head}) out of bounds for {} x {} routing session",
            self.layers,
            self.heads
        );
        layer * self.heads + head
    }

    /// Number of layers the session routes.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Number of heads per layer.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Number of routing clusters per slot (the `k` of every slot's
    /// [`SphericalKMeans`]).
    pub fn clusters(&self) -> usize {
        self.k
    }

    /// The slot's current cluster epoch (0 until the first non-empty
    /// update).
    pub fn epoch(&self, layer: usize, head: usize) -> u64 {
        self.epochs[self.slot(layer, head)]
    }

    /// The slot's assignment epoch: the cluster epoch of the last update
    /// that actually moved a token (0 until one does).  The coherence
    /// token [`EpochCache::get_routed_at`] keys on.
    pub fn assignment_epoch(&self, layer: usize, head: usize) -> u64 {
        self.assignment_epochs[self.slot(layer, head)]
    }

    /// Tokens moved since the slot's dirty set was last drained, sorted
    /// ascending — the incremental re-route worklist.
    ///
    /// Indices are positions within the `xs` batches handed to
    /// [`RoutingSession::update`]: they identify tokens only if the
    /// slot's updates keep one consistent batch shape between drains
    /// (mixed-shape updates make the set a churn *count*, not a usable
    /// worklist).
    pub fn dirty_tokens(&self, layer: usize, head: usize) -> Vec<usize> {
        self.dirty[self.slot(layer, head)].iter().copied().collect()
    }

    /// Size of the slot's pending dirty set.
    pub fn dirty_len(&self, layer: usize, head: usize) -> usize {
        self.dirty[self.slot(layer, head)].len()
    }

    /// Drain and return the slot's dirty set (sorted ascending) — called
    /// by a consumer that has finished re-routing the moved tokens.  See
    /// [`RoutingSession::dirty_tokens`] for the index-space contract.
    pub fn take_dirty(&mut self, layer: usize, head: usize) -> Vec<usize> {
        let s = self.slot(layer, head);
        std::mem::take(&mut self.dirty[s]).into_iter().collect()
    }

    /// Clusters touched (centroid EMA-moved, i.e. `delta.counts[c] > 0`)
    /// since the slot's dirty-cluster set was last drained, sorted
    /// ascending — the cluster-granular worklist an incremental
    /// re-router consumes.  Single-consumer; multi-consumer flows (e.g.
    /// several sequences sharing one slot's centroids) should use the
    /// non-draining per-cluster versions a [`MemberCache`] snapshots
    /// instead.
    pub fn dirty_clusters(&self, layer: usize, head: usize) -> Vec<usize> {
        self.dirty_clusters[self.slot(layer, head)].iter().copied().collect()
    }

    /// Size of the slot's pending dirty-cluster set.
    pub fn dirty_cluster_len(&self, layer: usize, head: usize) -> usize {
        self.dirty_clusters[self.slot(layer, head)].len()
    }

    /// Drain and return the slot's dirty-cluster set (sorted ascending);
    /// see [`RoutingSession::dirty_clusters()`].
    pub fn take_dirty_clusters(&mut self, layer: usize, head: usize) -> Vec<usize> {
        let s = self.slot(layer, head);
        std::mem::take(&mut self.dirty_clusters[s]).into_iter().collect()
    }

    /// The slot's per-cluster version counters (length
    /// [`RoutingSession::clusters`]): `versions[c]` advances once per
    /// update whose mini-batch assigned at least one vector to cluster
    /// `c` — exactly the updates that EMA-moved its centroid.  A
    /// [`MemberCache`] snapshots this slice to decide which membership
    /// lists are stale.
    pub fn cluster_versions(&self, layer: usize, head: usize) -> &[u64] {
        &self.cluster_versions[self.slot(layer, head)]
    }

    /// The slot's k-means state (e.g. for cohesion diagnostics).
    pub fn kmeans(&self, layer: usize, head: usize) -> &SphericalKMeans {
        &self.kms[self.slot(layer, head)]
    }

    /// One online k-means step over `xs` (row-major [n, dim]) for a slot.
    ///
    /// A non-empty batch bumps the slot's cluster epoch; its assignment
    /// epoch advances (and the moved tokens join the slot's dirty set)
    /// only when the step's [`AssignmentDelta`] actually moved a token —
    /// so a pattern compiled at the previous assignment epoch goes stale
    /// only when memberships really changed.  An empty batch (`n == 0`)
    /// is a strict no-op: no epoch bump, no dirty tokens, no recompile
    /// forced downstream.
    pub fn update(&mut self, layer: usize, head: usize, xs: &[f32], n: usize) -> RouteUpdate {
        let s = self.slot(layer, head);
        let delta = self.kms[s].update(xs, n);
        if n > 0 {
            self.epochs[s] += 1;
            if delta.changed() {
                self.assignment_epochs[s] = self.epochs[s];
                self.dirty[s].extend(delta.moved_tokens());
            }
            for (c, &count) in delta.counts.iter().enumerate() {
                if count > 0 {
                    // this cluster's centroid EMA-moved: its top-w
                    // membership list may have changed
                    self.cluster_versions[s][c] += 1;
                    self.dirty_clusters[s].insert(c);
                }
            }
        }
        RouteUpdate {
            epoch: self.epochs[s],
            assignment_epoch: self.assignment_epochs[s],
            delta,
        }
    }

    /// Balanced top-w routing spec for a slot over the routing vectors
    /// `xs` (row-major [n, dim]) — Algorithm 1's content-based index
    /// sets at the slot's current centroids.
    pub fn routing_spec(
        &self,
        layer: usize,
        head: usize,
        xs: &[f32],
        n: usize,
        w: usize,
    ) -> AttentionSpec {
        self.kms[self.slot(layer, head)].routing_spec(xs, n, w)
    }

    /// Epoch-cached compiled routing pattern for `slot`: serves the live
    /// compile while the slot's *assignment* epoch is current — including
    /// across cluster-epoch bumps that moved nothing — and regenerates
    /// (evicting the stale compile) only after an
    /// [`RoutingSession::update`] that actually changed assignments.
    pub fn routed_pattern(
        &self,
        cache: &mut EpochCache,
        slot: RouteSlot,
        xs: &[f32],
        n: usize,
        w: usize,
    ) -> Arc<CompiledPattern> {
        cache.get_routed_at(
            slot,
            self.epoch(slot.layer, slot.head),
            self.assignment_epoch(slot.layer, slot.head),
            n,
            || self.routing_spec(slot.layer, slot.head, xs, n, w),
        )
    }

    /// Incremental (dirty-cluster-only) routing spec: equal to
    /// [`RoutingSession::routing_spec`] for the same arguments, but
    /// recomputes a cluster's top-w membership list only when that
    /// cluster's version moved since `members` last saw the slot —
    /// i.e. only clusters an [`AssignmentDelta`] actually touched.
    ///
    /// Exactness: an untouched cluster's centroid is bit-unchanged, so
    /// over identical routing vectors its top-w list is identical; any
    /// shape change (different `xs` contents, `n`, effective `w`, or a
    /// cache built against another slot/shape) conservatively falls back
    /// to a full rebuild.  Per-call and cumulative accounting lands in
    /// [`MemberCache::stats()`] — the regenerated-vs-total counter
    /// `rtx serve-bench` reports.
    pub fn routing_spec_cached(
        &self,
        layer: usize,
        head: usize,
        members: &mut MemberCache,
        xs: &[f32],
        n: usize,
        w: usize,
    ) -> AttentionSpec {
        let s = self.slot(layer, head);
        let km = &self.kms[s];
        let versions = &self.cluster_versions[s];
        members.regenerate((self.nonce, layer, head), km, versions, xs, n, w);
        AttentionSpec::routing(members.members.clone())
    }

    /// [`RoutingSession::routed_pattern`] through a [`MemberCache`]: the
    /// epoch-cache hit path is unchanged (no spec regeneration at all on
    /// an assignment-epoch hit), and when the spec *is* regenerated, only
    /// the delta-touched clusters are recomputed.
    #[allow(clippy::too_many_arguments)]
    pub fn routed_pattern_cached(
        &self,
        cache: &mut EpochCache,
        members: &mut MemberCache,
        slot: RouteSlot,
        xs: &[f32],
        n: usize,
        w: usize,
    ) -> Arc<CompiledPattern> {
        cache.get_routed_at(
            slot,
            self.epoch(slot.layer, slot.head),
            self.assignment_epoch(slot.layer, slot.head),
            n,
            || self.routing_spec_cached(slot.layer, slot.head, members, xs, n, w),
        )
    }

    /// Expert-choice spec for a slot over the routing vectors `xs`
    /// (row-major [n, dim]) — the capacity-bounded MoSA-style counterpart
    /// of [`RoutingSession::routing_spec`]: the slot's clusters pick
    /// their top-`capacity` argmax-assigned tokens.
    pub fn expert_choice_spec(
        &self,
        layer: usize,
        head: usize,
        xs: &[f32],
        n: usize,
        capacity: usize,
    ) -> AttentionSpec {
        self.kms[self.slot(layer, head)].expert_choice_spec(xs, n, capacity)
    }

    /// Incremental expert-choice spec: equal to
    /// [`RoutingSession::expert_choice_spec`] for the same arguments, but
    /// served through `members` so untouched clusters' selections are
    /// reused.  Reuse is stricter than the routing rule — see
    /// [`MemberCache`]: expert membership is an argmax over *all*
    /// centroids, so a cluster is reused only when its own version is
    /// unchanged **and** its recomputed bucket is identical (when no
    /// version moved at all, the assignment pass itself is skipped).  A
    /// capacity change is a shape change: full rebuild, never stale
    /// reuse.
    pub fn expert_choice_spec_cached(
        &self,
        layer: usize,
        head: usize,
        members: &mut MemberCache,
        xs: &[f32],
        n: usize,
        capacity: usize,
    ) -> AttentionSpec {
        let s = self.slot(layer, head);
        let km = &self.kms[s];
        let versions = &self.cluster_versions[s];
        members.regenerate_expert((self.nonce, layer, head), km, versions, xs, n, capacity);
        AttentionSpec::expert_choice(members.members.clone(), capacity)
            .expect("cached expert-choice lists are capacity-bounded by construction")
    }
}

// ------------------------------------------------------- spec families

/// Which content-based family serves the routed (odd) heads of a serve
/// plan — selected by `rtx serve --spec` and carried by both the
/// in-process loop and the multi-process coordinator so the two stay
/// bit-identical per family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpecFamily {
    /// Balanced top-w token-choice routing (the paper's Algorithm 1).
    #[default]
    Routing,
    /// MoSA-style expert-choice: clusters pick their top-capacity
    /// argmax-assigned tokens, bounding per-cluster nnz by construction.
    ExpertChoice,
    /// Condensate-style calibrated score-threshold attend-sets over the
    /// routing vectors' pairwise scores (content-only: uses neither the
    /// k-means state nor the member cache).
    Threshold,
}

impl SpecFamily {
    /// Parse a `--spec` flag value / `spec_family` JSON field.
    pub fn parse(name: &str) -> Result<SpecFamily> {
        match name {
            "routing" => Ok(SpecFamily::Routing),
            "expert-choice" => Ok(SpecFamily::ExpertChoice),
            "threshold" => Ok(SpecFamily::Threshold),
            other => bail!(
                "unknown spec family '{other}' (expected routing | expert-choice | threshold)"
            ),
        }
    }

    /// The canonical spelling (the `--spec` flag value and the
    /// `spec_family` field of the serve `--json` schema).
    pub fn name(&self) -> &'static str {
        match self {
            SpecFamily::Routing => "routing",
            SpecFamily::ExpertChoice => "expert-choice",
            SpecFamily::Threshold => "threshold",
        }
    }
}

/// Build one routed slot's content-based spec under `family` — the single
/// construction the in-process serve loop and the multi-process
/// coordinator both call, which is what keeps their outputs bit-identical
/// per family.  `w` doubles as the routing top-w and the expert-choice
/// capacity; [`SpecFamily::Threshold`] ignores the session and member
/// cache entirely and cuts the content scores via
/// [`threshold_content_spec`].
#[allow(clippy::too_many_arguments)]
pub fn routed_family_spec(
    family: SpecFamily,
    session: &RoutingSession,
    layer: usize,
    head: usize,
    members: &mut MemberCache,
    xs: &[f32],
    n: usize,
    w: usize,
) -> AttentionSpec {
    match family {
        SpecFamily::Routing => session.routing_spec_cached(layer, head, members, xs, n, w),
        SpecFamily::ExpertChoice => {
            session.expert_choice_spec_cached(layer, head, members, xs, n, w)
        }
        SpecFamily::Threshold => threshold_content_spec(xs, n),
    }
}

/// The serve plan's threshold family: pairwise dot-product scores of the
/// routing vectors (`xs` row-major [n, dim]), cut at 0.0 with a per-row
/// floor of 1 — self-similarity is a non-negative dot, so every
/// finite-vector row keeps at least itself, and NaN-poisoned rows are
/// quarantined by [`AttentionSpec::threshold_from_scores`].  The score
/// matrix is materialized at O(n²), which confines this family to
/// moderate `n` (or precomputed scores via `threshold_from_scores`
/// directly).
pub fn threshold_content_spec(xs: &[f32], n: usize) -> AttentionSpec {
    let dim = if n == 0 { 0 } else { xs.len() / n };
    debug_assert_eq!(dim * n, xs.len(), "xs must be row-major [n, dim]");
    let mut scores = vec![f32::NEG_INFINITY; n * n];
    for i in 0..n {
        let xi = &xs[i * dim..(i + 1) * dim];
        for j in 0..=i {
            scores[i * n + j] = crate::kmeans::dot(xi, &xs[j * dim..(j + 1) * dim]);
        }
    }
    AttentionSpec::threshold_from_scores(&scores, n, 0.0, 1)
        .expect("cut 0.0 is finite and the score matrix is [n, n]")
}

// ------------------------------------------------------- member cache

/// Counters for one [`MemberCache`] — the incremental-regeneration
/// savings signal (`rtx serve-bench` prints the aggregate).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RegenStats {
    /// Cluster membership lists recomputed (top-w re-ranked).
    pub regenerated: u64,
    /// Cluster membership lists served unchanged from the cache.
    pub reused: u64,
    /// Calls that rebuilt every list because the cache shape was stale
    /// (first use, different `xs`/`n`/`w`/capacity, a family switch, or
    /// another slot's snapshot).
    pub full_rebuilds: u64,
    /// Total [`RoutingSession::routing_spec_cached`] +
    /// [`RoutingSession::expert_choice_spec_cached`] calls.
    pub calls: u64,
    /// Heap bytes of membership state (lists, routing-vector snapshot,
    /// version vector) resident in the cache these counters were read
    /// from.  A merged aggregate sums each source at its merge time, so
    /// for run-wide stats this reads as "member bytes retired".
    pub bytes_resident: u64,
}

impl RegenStats {
    /// Total membership rows considered (`regenerated + reused`).
    pub fn rows_total(&self) -> u64 {
        self.regenerated + self.reused
    }

    /// Fraction of membership rows served without recomputation; 0.0
    /// before any call.
    pub fn reuse_rate(&self) -> f64 {
        if self.rows_total() == 0 {
            0.0
        } else {
            self.reused as f64 / self.rows_total() as f64
        }
    }

    /// Fold another counter set into this one — the serve layer resets a
    /// retired slot's [`MemberCache`] and folds its counters into the
    /// run-wide aggregate first, so per-request GC never loses accounting.
    pub fn merge(&mut self, other: RegenStats) {
        self.regenerated += other.regenerated;
        self.reused += other.reused;
        self.full_rebuilds += other.full_rebuilds;
        self.calls += other.calls;
        self.bytes_resident += other.bytes_resident;
    }
}

/// Caller-owned cache of one routed stream's membership lists — balanced
/// top-w ([`RoutingSession::routing_spec_cached`]) or expert-choice
/// ([`RoutingSession::expert_choice_spec_cached`]) — enabling
/// dirty-cluster-only spec regeneration.
///
/// One `MemberCache` belongs to one consumer of one slot's centroids
/// (e.g. one `(layer, head, sequence)` routed stream): it remembers the
/// routing vectors, shape, selection family, membership lists, and the
/// per-cluster version snapshot they were built at.  On the next call
/// with the same vectors, shape, and family, only stale clusters are
/// re-ranked — version-moved ones for routing top-w; version-moved or
/// bucket-changed ones for expert-choice — and everything else is
/// reused, exactly.  Any mismatch — including NaN-poisoned vectors,
/// which never compare equal, and a capacity or family change — falls
/// back to a full rebuild, so the cache can be wrong only in cost, never
/// in content.
#[derive(Debug, Default)]
pub struct MemberCache {
    /// (session nonce, layer, head) the snapshot was taken against — a
    /// cache wandering between slots, or surviving a session that was
    /// dropped and rebuilt, must full-rebuild rather than trust another
    /// centroid state's version counters.
    slot: (u64, usize, usize),
    versions: Vec<u64>,
    xs: Vec<f32>,
    n: usize,
    /// Effective membership width (`w.min(n)` for routing top-w,
    /// `capacity.min(n)` for expert-choice), so `w = 5, n = 3` and
    /// `w = 9, n = 3` share one cache entry (identical lists).  A
    /// capacity change is a width change: it forces a full rebuild.
    w: usize,
    /// Selection rule the snapshot was built under — routing top-w and
    /// expert-choice lists are never interchangeable, even at equal `w`.
    family: MemberFamily,
    /// Expert-choice only: the argmax bucket partition the selections
    /// were ranked from.  Expert membership is global (a moved centroid
    /// can pull tokens out of an *untouched* cluster's bucket), so a
    /// cluster's cached list is reusable only when its version **and**
    /// its bucket are unchanged.
    buckets: Vec<Vec<usize>>,
    members: Vec<Vec<usize>>,
    valid: bool,
    stats: RegenStats,
    /// Shared meter the snapshot's heap bytes are charged against, if
    /// any.  A `MemberCache` is a single-snapshot cache — its one entry
    /// is by definition the current step's, so the budget only meters
    /// (it never evicts membership state).
    budget: Option<MemoryBudget>,
    /// Bytes currently charged to `budget` (tracked even without one so
    /// [`RegenStats::bytes_resident`] stays meaningful).
    charged: usize,
}

impl Clone for MemberCache {
    fn clone(&self) -> MemberCache {
        if let Some(b) = &self.budget {
            b.charge(self.charged);
        }
        MemberCache {
            slot: self.slot,
            versions: self.versions.clone(),
            xs: self.xs.clone(),
            n: self.n,
            w: self.w,
            family: self.family,
            buckets: self.buckets.clone(),
            members: self.members.clone(),
            valid: self.valid,
            stats: self.stats,
            budget: self.budget.clone(),
            charged: self.charged,
        }
    }
}

/// Which selection rule a [`MemberCache`] snapshot holds; see
/// [`MemberCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
enum MemberFamily {
    #[default]
    Routing,
    ExpertChoice,
}

impl Drop for MemberCache {
    fn drop(&mut self) {
        if let Some(b) = &self.budget {
            b.release(self.charged);
        }
    }
}

impl MemberCache {
    /// An empty cache; the first use is always a full rebuild.
    pub fn new() -> MemberCache {
        MemberCache::default()
    }

    /// An empty cache whose membership-state heap bytes are metered
    /// against `budget` (and released when the cache is dropped).
    pub fn with_budget(budget: MemoryBudget) -> MemberCache {
        let mut cache = MemberCache::default();
        cache.budget = Some(budget);
        cache
    }

    /// Heap bytes held by the cached snapshot: membership lists plus the
    /// routing-vector and version-vector copies shape checks compare.
    pub fn heap_bytes(&self) -> usize {
        let members: usize = self.members.iter().map(|m| std::mem::size_of_val(m.as_slice())).sum();
        let buckets: usize = self.buckets.iter().map(|b| std::mem::size_of_val(b.as_slice())).sum();
        members
            + buckets
            + std::mem::size_of_val(self.versions.as_slice())
            + std::mem::size_of_val(self.xs.as_slice())
    }

    /// Re-meter after a mutation: charge growth, release shrinkage.
    fn recharge(&mut self) {
        let now = self.heap_bytes();
        if let Some(b) = &self.budget {
            if now > self.charged {
                b.charge(now - self.charged);
            } else {
                b.release(self.charged - now);
            }
        }
        self.charged = now;
    }

    /// Cumulative regeneration counters (plus the resident-bytes gauge).
    pub fn stats(&self) -> RegenStats {
        let mut s = self.stats;
        s.bytes_resident = self.charged as u64;
        s
    }

    /// The cached membership lists (empty before first use).
    pub fn members(&self) -> &[Vec<usize>] {
        &self.members
    }

    /// Bring the cached lists up to date against `km` + `versions`; see
    /// [`RoutingSession::routing_spec_cached`].
    fn regenerate(
        &mut self,
        slot: (u64, usize, usize),
        km: &SphericalKMeans,
        versions: &[u64],
        xs: &[f32],
        n: usize,
        w: usize,
    ) {
        let w_eff = w.min(n);
        self.stats.calls += 1;
        let shape_ok = self.valid
            && self.slot == slot
            && self.family == MemberFamily::Routing
            && self.members.len() == km.k
            && self.versions.len() == km.k
            && self.n == n
            && self.w == w_eff
            && self.xs == xs;
        if !shape_ok {
            self.stats.full_rebuilds += 1;
            self.stats.regenerated += km.k as u64;
            self.members = km.top_w_members(xs, n, w);
            self.buckets = Vec::new();
            self.versions = versions.to_vec();
            self.xs = xs.to_vec();
            self.n = n;
            self.w = w_eff;
            self.family = MemberFamily::Routing;
            self.slot = slot;
            self.valid = true;
            self.recharge();
            return;
        }
        for c in 0..km.k {
            if self.versions[c] == versions[c] {
                self.stats.reused += 1;
            } else {
                self.members[c] = km.top_w_of(c, xs, n, w);
                self.versions[c] = versions[c];
                self.stats.regenerated += 1;
            }
        }
        self.recharge();
    }

    /// Bring the cached lists up to date under the expert-choice rule; see
    /// [`RoutingSession::expert_choice_spec_cached`].
    ///
    /// Unlike routing top-w — where a cluster's list depends only on its
    /// own centroid — an expert-choice selection is ranked over the
    /// cluster's argmax *bucket*, and the bucket partition is global: one
    /// moved centroid can pull tokens out of any cluster's bucket.  So
    /// when any version moved, the partition is recomputed once and a
    /// cluster is reused only if its version (centroid bits) **and** its
    /// bucket (membership set) both held still; when no version moved at
    /// all, every centroid is bit-unchanged and the assignment pass is
    /// skipped entirely.
    fn regenerate_expert(
        &mut self,
        slot: (u64, usize, usize),
        km: &SphericalKMeans,
        versions: &[u64],
        xs: &[f32],
        n: usize,
        capacity: usize,
    ) {
        let cap_eff = capacity.min(n);
        self.stats.calls += 1;
        let shape_ok = self.valid
            && self.slot == slot
            && self.family == MemberFamily::ExpertChoice
            && self.members.len() == km.k
            && self.versions.len() == km.k
            && self.buckets.len() == km.k
            && self.n == n
            && self.w == cap_eff
            && self.xs == xs;
        if !shape_ok {
            self.stats.full_rebuilds += 1;
            self.stats.regenerated += km.k as u64;
            self.buckets = km.assigned_buckets(xs, n);
            self.members = (0..km.k)
                .map(|c| km.top_capacity_of(c, &self.buckets[c], xs, n, capacity))
                .collect();
            self.versions = versions.to_vec();
            self.xs = xs.to_vec();
            self.n = n;
            self.w = cap_eff;
            self.family = MemberFamily::ExpertChoice;
            self.slot = slot;
            self.valid = true;
            self.recharge();
            return;
        }
        if self.versions == versions {
            self.stats.reused += km.k as u64;
            return;
        }
        let buckets = km.assigned_buckets(xs, n);
        for c in 0..km.k {
            if self.versions[c] == versions[c] && self.buckets[c] == buckets[c] {
                self.stats.reused += 1;
            } else {
                self.members[c] = km.top_capacity_of(c, &buckets[c], xs, n, capacity);
                self.versions[c] = versions[c];
                self.stats.regenerated += 1;
            }
        }
        self.buckets = buckets;
        self.recharge();
    }
}

// ---------------------------------------------------------------- cache

/// Slot-level hit/miss counters for an [`EpochCache`] (spec regeneration,
/// not compile work — see [`EpochCache::stats()`] for the compile side).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EpochCacheStats {
    /// Routed lookups served from the slot's live compile (its assignment
    /// epoch was current): the stored spec was reused without
    /// regeneration.  Includes the `unchanged_epochs` subset.
    pub epoch_hits: u64,
    /// Routed lookups that had to regenerate the spec (unseen slot, stale
    /// assignment epoch, or changed sequence length).
    pub epoch_misses: u64,
    /// The subset of `epoch_hits` where the cluster epoch had bumped past
    /// the compile but the assignments had not changed — each one is a
    /// recompile the incremental (dirty-set) flow skipped; the strict
    /// epoch-keyed flow would have evicted instead.
    pub unchanged_epochs: u64,
    /// Heap bytes of slot-owned routed compiles currently resident
    /// (gauge; the pinned static side is reported by
    /// [`EpochCache::stats()`] instead).
    pub bytes_resident: u64,
    /// Cumulative heap bytes freed by routed-slot drops — stale-epoch
    /// evictions, budget spills, and [`EpochCache::evict_slot`].
    pub bytes_evicted: u64,
}

impl EpochCacheStats {
    /// Total routed lookups (`epoch_hits + epoch_misses`).
    pub fn lookups(&self) -> u64 {
        self.epoch_hits + self.epoch_misses
    }

    /// Fraction of routed lookups served at the current epoch; 0.0 before
    /// any lookup.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.epoch_hits as f64 / self.lookups() as f64
        }
    }
}

#[derive(Debug, Clone)]
struct SlotEntry {
    /// Cluster epoch last observed for the slot (advances freely across
    /// unchanged-assignment bumps).
    epoch: u64,
    /// Assignment epoch the pattern was compiled from — the coherence
    /// token; a mismatch invalidates the entry.
    assignment_epoch: u64,
    n: usize,
    pattern: Arc<CompiledPattern>,
    /// Heap bytes charged to the cache's [`MemoryBudget`] for this
    /// compile (released on any drop path).
    bytes: usize,
    /// Logical-clock timestamp of the last lookup that served this entry
    /// — the LRU key for budget spills, and the step-protection token
    /// (`last_used >= step_mark` means "touched during the in-flight
    /// step": never spilled).
    last_used: u64,
}

/// Generation-aware compile cache for a decode loop (dirty-set flow).
///
/// Static head-plan specs go through [`EpochCache::get_static`], land in
/// a spec-keyed [`PatternCache`], and stay pinned for the lifetime of the
/// cache.  Routed patterns never enter that shared map: each
/// [`RouteSlot`] *owns* its one live compile, tagged with the assignment
/// epoch it was built from.  While the assignment epoch matches,
/// [`EpochCache::get_routed_at`] is an O(1) slot lookup returning the
/// shared `Arc` (no spec regeneration, no hashing of O(n) membership
/// lists) — even when the cluster epoch has bumped past the compile,
/// which is recorded as an [`EpochCacheStats::unchanged_epochs`] hit
/// rather than an eviction (the MoSA-style stability win: centroids
/// drifted, argmax assignments did not; see
/// [`AssignmentDelta::changed`](crate::kmeans::AssignmentDelta::changed)
/// for why this reuse is an approximation of top-w membership
/// stability).  When the assignment epoch moves — a
/// k-means update really moved tokens — the stale compile is dropped
/// (counted as an eviction in [`EpochCache::stats()`]) and the new spec is
/// built via the caller's closure and compiled.  A pattern from
/// superseded assignments is therefore never served, slot evictions can
/// never touch a pinned static compile (or another slot's), and the
/// cache holds at most one live routing pattern per slot.
/// [`EpochCache::evict_slot`] drops a slot eagerly (e.g. when its
/// request completes).
///
/// Under a byte cap ([`EpochCache::with_budget`]) the routed slots share
/// one [`MemoryBudget`] with the pinned static [`PatternCache`]: an
/// insert that pushes the meter over budget LRU-spills routed slots —
/// but never a pinned static compile, never the entry just inserted,
/// and never a slot touched since the last [`EpochCache::mark_step`]
/// call, so an in-flight step's working set cannot be evicted out from
/// under it (the cap is soft by exactly that protected set).
#[derive(Debug)]
pub struct EpochCache {
    cache: PatternCache,
    slots: HashMap<RouteSlot, SlotEntry>,
    /// Hit/miss/eviction counters for the routed (slot-owned) side,
    /// merged with the static side by [`EpochCache::stats()`].
    routed: CacheStats,
    stats: EpochCacheStats,
    /// Shared byte meter (unbounded by default); static compiles are
    /// charged through `cache`, routed slots directly.
    budget: MemoryBudget,
    /// Logical clock driving LRU order — bumped per routed lookup, never
    /// wall-clock, so spill order is deterministic and replayable.
    tick: u64,
    /// Entries with `last_used >= step_mark` are step-protected;
    /// `u64::MAX` (the initial state) protects nothing.
    step_mark: u64,
}

impl Default for EpochCache {
    fn default() -> EpochCache {
        EpochCache::with_budget(MemoryBudget::unbounded())
    }
}

impl Drop for EpochCache {
    /// Release the routed slots' charges (the static side's
    /// [`PatternCache`] drop releases its own).
    fn drop(&mut self) {
        for entry in self.slots.values() {
            self.budget.release(entry.bytes);
        }
    }
}

impl EpochCache {
    /// An empty, unbudgeted (metering-only) cache with zeroed counters.
    pub fn new() -> EpochCache {
        EpochCache::default()
    }

    /// An empty cache charging both sides — pinned statics and routed
    /// slots — against `budget`.  Clones of the budget handle observe
    /// the same meter, so one cap can govern several caches.
    pub fn with_budget(budget: MemoryBudget) -> EpochCache {
        EpochCache {
            cache: PatternCache::with_budget(budget.clone()),
            slots: HashMap::new(),
            routed: CacheStats::default(),
            stats: EpochCacheStats::default(),
            budget,
            tick: 0,
            step_mark: u64::MAX,
        }
    }

    /// The byte meter both sides charge against.
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// Start a new serve step: routed entries touched from here on are
    /// protected from budget spills until the next call, so a step's
    /// working set can never be evicted while the step is in flight.
    pub fn mark_step(&mut self) {
        self.step_mark = self.tick + 1;
    }

    /// Pinned lookup for static (epoch-free) specs: local, strided, and
    /// other content-independent head-plan parts.  Pinned entries are
    /// never spilled by the budget.
    pub fn get_static(&mut self, spec: &AttentionSpec, n: usize) -> Arc<CompiledPattern> {
        self.cache.get_or_compile_pinned(spec, n)
    }

    /// Strict epoch-keyed lookup for a routed slot: every epoch bump
    /// invalidates.  Equivalent to [`EpochCache::get_routed_at`] with
    /// `assignment_epoch == epoch` — for callers without assignment-delta
    /// tracking (every centroid move is treated as a membership change).
    pub fn get_routed(
        &mut self,
        slot: RouteSlot,
        epoch: u64,
        n: usize,
        make_spec: impl FnOnce() -> AttentionSpec,
    ) -> Arc<CompiledPattern> {
        self.get_routed_at(slot, epoch, epoch, n, make_spec)
    }

    /// Assignment-epoch-keyed lookup for a routed slot — the incremental
    /// (dirty-set) flow.  `make_spec` runs only when the slot is unseen
    /// or its stored assignment epoch/length is stale; a stale entry's
    /// compile is dropped (one eviction) first.  A lookup whose cluster
    /// `epoch` advanced while `assignment_epoch` did not serves the live
    /// compile and counts an [`EpochCacheStats::unchanged_epochs`] hit —
    /// the recompile the delta proved unnecessary.
    ///
    /// ```
    /// use routing_transformer::attention::{AttentionSpec, EpochCache, RouteSlot};
    /// let mut cache = EpochCache::new();
    /// let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
    /// let spec = AttentionSpec::routing(vec![vec![0, 1, 2]]);
    /// // compiled at cluster epoch 1, assignment epoch 1
    /// let a = cache.get_routed_at(slot, 1, 1, 8, || spec.clone());
    /// // centroids drifted (epoch 2) but no assignment moved: same compile
    /// let b = cache.get_routed_at(slot, 2, 1, 8, || unreachable!("served live"));
    /// assert!(std::sync::Arc::ptr_eq(&a, &b));
    /// assert_eq!(cache.epoch_stats().unchanged_epochs, 1);
    /// // assignments moved (epoch 3): the stale compile is evicted
    /// let c = cache.get_routed_at(slot, 3, 3, 8, || AttentionSpec::routing(vec![vec![0, 3]]));
    /// assert!(!std::sync::Arc::ptr_eq(&a, &c));
    /// assert_eq!(cache.stats().evictions, 1);
    /// ```
    pub fn get_routed_at(
        &mut self,
        slot: RouteSlot,
        epoch: u64,
        assignment_epoch: u64,
        n: usize,
        make_spec: impl FnOnce() -> AttentionSpec,
    ) -> Arc<CompiledPattern> {
        self.tick += 1;
        if let Some(entry) = self.slots.get_mut(&slot) {
            if entry.assignment_epoch == assignment_epoch && entry.n == n {
                if entry.epoch != epoch {
                    entry.epoch = epoch;
                    self.stats.unchanged_epochs += 1;
                }
                entry.last_used = self.tick;
                self.stats.epoch_hits += 1;
                self.routed.hits += 1;
                return Arc::clone(&entry.pattern);
            }
        }
        if let Some(stale) = self.slots.remove(&slot) {
            self.release_slot(stale.bytes);
        }
        self.stats.epoch_misses += 1;
        self.routed.misses += 1;
        let pattern = Arc::new(make_spec().compile(n));
        let bytes = pattern.heap_bytes();
        self.budget.charge(bytes);
        self.routed.bytes_resident += bytes as u64;
        self.slots.insert(
            slot,
            SlotEntry {
                epoch,
                assignment_epoch,
                n,
                pattern: Arc::clone(&pattern),
                bytes,
                last_used: self.tick,
            },
        );
        self.spill(slot);
        pattern
    }

    /// Book one routed compile's bytes out of the meter and counters.
    fn release_slot(&mut self, bytes: usize) {
        self.budget.release(bytes);
        self.routed.evictions += 1;
        self.routed.bytes_resident -= bytes as u64;
        self.routed.bytes_evicted += bytes as u64;
    }

    /// LRU-spill routed slots while the shared meter is over budget,
    /// never touching `keep` (the entry just inserted) or any slot
    /// touched since [`EpochCache::mark_step`].  `last_used` ticks are
    /// unique, so the victim order is deterministic even though the slot
    /// map itself is hashed.
    fn spill(&mut self, keep: RouteSlot) {
        while self.budget.over_budget() {
            let victim = self
                .slots
                .iter()
                .filter(|&(s, e)| *s != keep && e.last_used < self.step_mark)
                .min_by_key(|&(_, e)| e.last_used)
                .map(|(s, _)| *s);
            match victim {
                Some(s) => {
                    let e = self.slots.remove(&s).expect("victim was drawn from the map");
                    self.release_slot(e.bytes);
                }
                // everything left is pinned, step-protected, or the
                // fresh insert — the cap is soft by exactly that set
                None => break,
            }
        }
    }

    /// Drop one routed slot's live compile — a request ended, or the
    /// caller wants to force a recompile.  Counts one eviction when the
    /// slot was present and returns the heap bytes freed (`None` when
    /// the slot had no live compile), so GC reports can print bytes
    /// reclaimed per retirement.
    pub fn evict_slot(&mut self, slot: RouteSlot) -> Option<usize> {
        let entry = self.slots.remove(&slot)?;
        self.release_slot(entry.bytes);
        Some(entry.bytes)
    }

    /// Cluster epoch a slot's live pattern was last served at, if any.
    pub fn slot_epoch(&self, slot: RouteSlot) -> Option<u64> {
        self.slots.get(&slot).map(|e| e.epoch)
    }

    /// Assignment epoch a slot's live pattern was compiled from, if any.
    pub fn slot_assignment_epoch(&self, slot: RouteSlot) -> Option<u64> {
        self.slots.get(&slot).map(|e| e.assignment_epoch)
    }

    /// Compile-level counters across both sides: the pinned static
    /// [`PatternCache`] plus the slot-owned routed patterns (whose
    /// stale-epoch drops fill [`CacheStats::evictions`]).
    pub fn stats(&self) -> CacheStats {
        let s = self.cache.stats();
        CacheStats {
            hits: s.hits + self.routed.hits,
            misses: s.misses + self.routed.misses,
            evictions: s.evictions + self.routed.evictions,
            bytes_resident: s.bytes_resident + self.routed.bytes_resident,
            bytes_evicted: s.bytes_evicted + self.routed.bytes_evicted,
            band_compiles: s.band_compiles + self.routed.band_compiles,
        }
    }

    /// Slot-level epoch hit/miss counters (routed lookups only), plus
    /// the routed side's byte gauge.
    pub fn epoch_stats(&self) -> EpochCacheStats {
        let mut s = self.stats;
        s.bytes_resident = self.routed.bytes_resident;
        s.bytes_evicted = self.routed.bytes_evicted;
        s
    }

    /// Live compiles: pinned static entries + one per routed slot.
    pub fn len(&self) -> usize {
        self.cache.len() + self.slots.len()
    }

    /// True when neither a static nor a routed compile is live.
    pub fn is_empty(&self) -> bool {
        self.cache.is_empty() && self.slots.is_empty()
    }

    /// Drop every entry and reset all counters, releasing every charged
    /// byte back to the shared meter.
    pub fn clear(&mut self) {
        self.cache.clear();
        for (_, entry) in self.slots.drain() {
            self.budget.release(entry.bytes);
        }
        self.routed = CacheStats::default();
        self.stats = EpochCacheStats::default();
        self.step_mark = u64::MAX;
    }
}

// ---------------------------------------------------------------- batch

/// One worker's slice of a batch: contiguous rows of one sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
struct SeqRows {
    seq: usize,
    rows: Range<usize>,
}

/// Cross-request batching: B independent sequences evaluated by one
/// nnz-balanced worker sweep.
///
/// Construction takes one compiled pattern per sequence (all sharing one
/// sequence length `n`; use [`BatchedAttention::shared`] when every
/// sequence runs the same pattern) and a worker count, then splits the
/// *global* row space `[0, B·n)` into `workers` contiguous chunks of
/// (nearly) equal nnz — so a batch where one request routes densely and
/// another sparsely still spreads evenly, and chunks may span sequence
/// boundaries.  [`BatchedAttention::attention`] runs each chunk on its
/// own worker thread via the selected
/// [`Backend`](super::backend::Backend)'s row kernel (the scalar
/// [`Reference`](super::backend::Reference) by default), which makes the
/// output bit-identical to B independent
/// [`sparse_attention`](super::sparse_attention) calls.
#[derive(Debug, Clone)]
pub struct BatchedAttention {
    patterns: Vec<Arc<CompiledPattern>>,
    n: usize,
    /// Per-worker run lists, in global-row order; empty runs are dropped.
    plan: Vec<Vec<SeqRows>>,
}

impl BatchedAttention {
    /// Plan a batch over per-sequence patterns (`patterns.len()` = B).
    pub fn new(patterns: Vec<Arc<CompiledPattern>>, workers: usize) -> Result<BatchedAttention> {
        if workers == 0 {
            bail!("batched attention requires at least one worker (got workers = 0)");
        }
        let n = patterns.first().map(|p| p.n()).unwrap_or(0);
        if let Some(bad) = patterns.iter().find(|p| p.n() != n) {
            bail!(
                "every sequence in a batch must share one length (expected n = {n}, got {})",
                bad.n()
            );
        }
        let b = patterns.len();
        let rows_total = b * n;
        let total_nnz: usize = patterns.iter().map(|p| p.nnz()).sum();
        // prefix[g] = nnz of all global rows before g, where global row g
        // is row g % n of sequence g / n — the batch-wide analogue of the
        // CSR offsets ShardedPattern::balanced splits on
        let mut prefix = Vec::with_capacity(rows_total + 1);
        prefix.push(0usize);
        let mut base = 0usize;
        for p in &patterns {
            let offsets = p.offsets();
            for &o in &offsets[1..] {
                prefix.push(base + o);
            }
            base += p.nnz();
        }
        let mut bounds = Vec::with_capacity(workers + 1);
        bounds.push(0usize);
        for s in 1..workers {
            let target = ((total_nnz as u128 * s as u128) / workers as u128) as usize;
            bounds.push(prefix.partition_point(|&o| o < target).min(rows_total));
        }
        bounds.push(rows_total);
        let plan = bounds
            .windows(2)
            .map(|w| {
                let (mut gs, ge) = (w[0], w[1]);
                let mut runs = Vec::new();
                while gs < ge {
                    let seq = gs / n;
                    let seq_end = ((seq + 1) * n).min(ge);
                    runs.push(SeqRows { seq, rows: (gs - seq * n)..(seq_end - seq * n) });
                    gs = seq_end;
                }
                runs
            })
            .collect();
        Ok(BatchedAttention { patterns, n, plan })
    }

    /// Plan a batch of `b` sequences all running one shared pattern.
    pub fn shared(
        pattern: Arc<CompiledPattern>,
        b: usize,
        workers: usize,
    ) -> Result<BatchedAttention> {
        BatchedAttention::new(vec![pattern; b], workers)
    }

    /// Number of sequences B in the batch.
    pub fn batch(&self) -> usize {
        self.patterns.len()
    }

    /// The per-sequence compiled patterns (index = sequence).
    pub fn patterns(&self) -> &[Arc<CompiledPattern>] {
        &self.patterns
    }

    /// Shared per-sequence length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total non-zero entries across every sequence's pattern.
    pub fn nnz(&self) -> usize {
        self.patterns.iter().map(|p| p.nnz()).sum()
    }

    /// Exact multiply-accumulate count for one batched pass at head dim
    /// `d` (sum of the per-sequence [`CompiledPattern::cost`]s).
    pub fn cost(&self, d: usize) -> u64 {
        self.patterns.iter().map(|p| p.cost(d)).sum()
    }

    /// Number of planned worker chunks (the `workers` the plan was built
    /// with).
    pub fn num_workers(&self) -> usize {
        self.plan.len()
    }

    /// Rows assigned to each worker (diagnostic; sums to B·n).
    pub fn worker_rows(&self) -> Vec<usize> {
        self.plan
            .iter()
            .map(|runs| runs.iter().map(|r| r.rows.len()).sum())
            .collect()
    }

    /// Pattern entries (nnz) assigned to each worker — the shard-balance
    /// observable behind the serve-bench `max/min shard nnz` report; sums
    /// to the batch's total nnz.
    pub fn worker_nnz(&self) -> Vec<usize> {
        self.plan
            .iter()
            .map(|runs| {
                runs.iter()
                    .map(|r| {
                        let off = self.patterns[r.seq].offsets();
                        off[r.rows.end] - off[r.rows.start]
                    })
                    .sum()
            })
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn run_chunk(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        runs: &[SeqRows],
        backend: &dyn super::backend::Backend,
        out: &mut [f32],
    ) -> Result<()> {
        let stride = self.n * d;
        let mut rest = out;
        for run in runs {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(run.rows.len() * d);
            rest = tail;
            let base = run.seq * stride;
            backend.attention_rows(
                &q[base..base + stride],
                &k[base..base + stride],
                &v[base..base + stride],
                d,
                &self.patterns[run.seq],
                run.rows.clone(),
                head,
            )?;
        }
        Ok(())
    }

    /// Evaluate the whole batch: `q`/`k`/`v` are `[B, n, d]` row-major
    /// (sequence-major), the result is the matching `[B, n, d]` output,
    /// computed on the default execution strategy (the resident global
    /// [`super::pool::WorkerPool`]).  Bit-identical to evaluating each
    /// sequence independently with
    /// [`sparse_attention`](super::sparse_attention).
    pub fn attention(&self, q: &[f32], k: &[f32], v: &[f32], d: usize) -> Result<Vec<f32>> {
        self.attention_with(q, k, v, d, Execution::default())
    }

    /// [`BatchedAttention::attention`] with an explicit per-call
    /// [`Execution`] strategy (inline reference, scoped spawn-per-call
    /// baseline, or a resident pool) — all three are bit-identical.  One
    /// worker per non-empty chunk; a single-chunk plan runs on the
    /// calling thread.  Runs the [`Reference`](super::backend::Reference)
    /// kernel; see [`BatchedAttention::attention_backend`].
    pub fn attention_with(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        exec: Execution<'_>,
    ) -> Result<Vec<f32>> {
        self.attention_backend(q, k, v, d, exec, &super::backend::Reference)
    }

    /// [`BatchedAttention::attention_with`] with an explicit
    /// [`Backend`](super::backend::Backend): every chunk's rows run
    /// through `backend` instead of the scalar reference kernel.  All
    /// registered backends are bit-identical, so backend choice changes
    /// wall-clock only, never the output.
    pub fn attention_backend(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        exec: Execution<'_>,
        backend: &dyn super::backend::Backend,
    ) -> Result<Vec<f32>> {
        let b = self.patterns.len();
        if d == 0 {
            bail!("batched attention requires head dimension d >= 1");
        }
        let expect = b * self.n * d;
        if q.len() != expect || k.len() != expect || v.len() != expect {
            bail!(
                "q/k/v must each be [B = {b}, n = {}, d = {d}] row-major (got {}, {}, {})",
                self.n,
                q.len(),
                k.len(),
                v.len()
            );
        }
        let mut out = vec![0f32; expect];
        // carve the output into per-chunk slices (chunks are contiguous
        // and ordered in global rows), dropping empty chunks
        let mut work: Vec<(&[SeqRows], &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = &mut out;
        for runs in &self.plan {
            let rows: usize = runs.iter().map(|r| r.rows.len()).sum();
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(rows * d);
            rest = tail;
            if rows > 0 {
                work.push((runs.as_slice(), head));
            }
        }
        exec.run(work, |runs, head| self.run_chunk(q, k, v, d, runs, backend, head))?;
        Ok(out)
    }
}

/// One-shot convenience over [`BatchedAttention`]: evaluate B sequences
/// (`patterns.len()` = B, q/k/v `[B, n, d]` row-major) in one balanced
/// worker sweep.
pub fn sparse_attention_batch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    patterns: &[Arc<CompiledPattern>],
    workers: usize,
) -> Result<Vec<f32>> {
    BatchedAttention::new(patterns.to_vec(), workers)?.attention(q, k, v, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{sparse_attention, AttentionSpec};
    use crate::util::rng::Rng;

    fn random_qkv(rng: &mut Rng, rows: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut mk = |rng: &mut Rng| (0..rows * d).map(|_| rng.normal() as f32).collect();
        (mk(rng), mk(rng), mk(rng))
    }

    #[test]
    fn session_epochs_bump_per_slot() {
        let mut s = RoutingSession::new(2, 3, 4, 8, 0.5, 7).unwrap();
        assert_eq!((s.layers(), s.heads()), (2, 3));
        assert_eq!(s.epoch(1, 2), 0);
        assert_eq!(s.assignment_epoch(1, 2), 0);
        let xs: Vec<f32> = {
            let mut rng = Rng::new(1);
            (0..16 * 8).map(|_| rng.normal() as f32).collect()
        };
        assert_eq!(s.update(1, 2, &xs, 16).epoch, 1);
        assert_eq!(s.update(1, 2, &xs, 16).epoch, 2);
        assert_eq!(s.epoch(1, 2), 2);
        // the assignment epoch never runs ahead of the cluster epoch
        assert!(s.assignment_epoch(1, 2) <= 2);
        // other slots are untouched
        assert_eq!(s.epoch(0, 0), 0);
        assert_eq!(s.epoch(1, 1), 0);
        assert_eq!(s.dirty_len(0, 0), 0);
        // the spec reflects the slot's own centroids
        let spec = s.routing_spec(1, 2, &xs, 16, 4);
        assert_eq!(spec, s.kmeans(1, 2).routing_spec(&xs, 16, 4));
    }

    #[test]
    fn empty_update_is_a_noop_on_epochs_and_dirty_sets() {
        // regression: an n = 0 update used to bump the epoch and force a
        // recompile even though nothing could have changed
        let mut s = RoutingSession::new(1, 1, 2, 4, 0.5, 3).unwrap();
        let centroids_before = s.kmeans(0, 0).centroids.clone();
        let upd = s.update(0, 0, &[], 0);
        assert_eq!(upd.epoch, 0, "empty batch must not bump the cluster epoch");
        assert_eq!(upd.assignment_epoch, 0);
        assert!(!upd.delta.changed());
        assert_eq!(s.epoch(0, 0), 0);
        assert_eq!(s.dirty_len(0, 0), 0, "empty batch must not dirty the slot");
        assert_eq!(s.kmeans(0, 0).centroids, centroids_before);
        // and the cache keeps serving the live compile across it
        let mut cache = EpochCache::new();
        let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
        let xs: Vec<f32> = vec![0.5; 8 * 4];
        let p0 = s.routed_pattern(&mut cache, slot, &xs, 8, 2);
        s.update(0, 0, &[], 0);
        let p1 = s.routed_pattern(&mut cache, slot, &xs, 8, 2);
        assert!(Arc::ptr_eq(&p0, &p1), "no-op update must not invalidate the slot");
        assert_eq!(cache.stats().evictions, 0);
    }

    #[test]
    fn dirty_sets_track_moved_tokens_and_drain() {
        // whatever an update's delta moves must land in the slot's dirty
        // set, accumulate across updates, and drain exactly once
        let mut s = RoutingSession::new(1, 2, 2, 2, 0.0, 1).unwrap();
        let xs = vec![0.98, 0.2, 0.0, 1.0];
        let upd = s.update(0, 1, &xs, 2);
        assert_eq!(s.dirty_tokens(0, 1), upd.delta.moved_tokens().collect::<Vec<_>>());
        if upd.delta.changed() {
            assert_eq!(s.assignment_epoch(0, 1), 1, "a moving update advances the epoch");
        } else {
            assert_eq!(s.assignment_epoch(0, 1), 0, "a stable update must not");
        }
        let upd2 = s.update(0, 1, &xs, 2);
        let expect: BTreeSet<usize> =
            upd.delta.moved_tokens().chain(upd2.delta.moved_tokens()).collect();
        let expect: Vec<usize> = expect.into_iter().collect();
        assert_eq!(s.dirty_tokens(0, 1), expect);
        assert_eq!(s.take_dirty(0, 1), expect);
        assert_eq!(s.dirty_len(0, 1), 0, "take_dirty drains the set");
        assert_eq!(s.take_dirty(0, 1), Vec::<usize>::new());
        // the other head's slot is independent
        assert_eq!(s.dirty_len(0, 0), 0);
    }

    #[test]
    fn session_rejects_degenerate_shapes() {
        assert!(RoutingSession::new(0, 2, 4, 8, 0.5, 0).is_err());
        assert!(RoutingSession::new(2, 0, 4, 8, 0.5, 0).is_err());
        assert!(RoutingSession::new(2, 2, 0, 8, 0.5, 0).is_err());
        assert!(RoutingSession::new(2, 2, 4, 0, 0.5, 0).is_err());
        assert!(RoutingSession::new(1, 1, 1, 1, 0.5, 0).is_ok());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn session_slot_bounds_checked() {
        let s = RoutingSession::new(2, 2, 2, 4, 0.5, 0).unwrap();
        s.epoch(2, 0);
    }

    #[test]
    fn epoch_bump_evicts_stale_pattern_and_counts() {
        let mut cache = EpochCache::new();
        let slot = RouteSlot { layer: 0, head: 1, seq: 0 };
        let s0 = AttentionSpec::routing(vec![vec![0, 1, 2]]);
        let s1 = AttentionSpec::routing(vec![vec![0, 3, 4]]);
        let p0 = cache.get_routed(slot, 0, 8, || s0.clone());
        assert_eq!(*p0, s0.compile(8));
        assert_eq!(cache.stats().evictions, 0);
        assert_eq!(cache.slot_epoch(slot), Some(0));
        // same epoch: hit, same Arc, no spec regeneration
        let again = cache.get_routed(slot, 0, 8, || panic!("hit must not regenerate"));
        assert!(Arc::ptr_eq(&p0, &again));
        let es = cache.epoch_stats();
        assert_eq!((es.epoch_hits, es.epoch_misses, es.unchanged_epochs), (1, 1, 0));
        // epoch bump: stale compile evicted before the new one lands
        // (strict keying — no assignment-delta tracking on this path)
        let p1 = cache.get_routed(slot, 1, 8, || s1.clone());
        assert_eq!(*p1, s1.compile(8));
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.len(), 1, "one live routing pattern per slot");
        assert_eq!(cache.slot_epoch(slot), Some(1));
        assert_eq!(cache.slot_assignment_epoch(slot), Some(1));
        // the old epoch's pattern is gone: looking it up again recompiles
        let misses_before = cache.stats().misses;
        cache.get_static(&s0, 8);
        assert_eq!(cache.stats().misses, misses_before + 1, "stale compile must not linger");
    }

    #[test]
    fn static_specs_stay_pinned_across_churn() {
        let mut cache = EpochCache::new();
        let local = AttentionSpec::local(3).unwrap();
        let pinned = cache.get_static(&local, 12);
        let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
        for epoch in 0..5u64 {
            let members = vec![vec![epoch as usize, epoch as usize + 1]];
            cache.get_routed(slot, epoch, 12, || AttentionSpec::routing(members.clone()));
        }
        assert_eq!(cache.stats().evictions, 4);
        assert_eq!(cache.len(), 2, "pinned static + one live routed");
        let still = cache.get_static(&local, 12);
        assert!(Arc::ptr_eq(&pinned, &still), "static compile survives routing churn");
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.epoch_stats().lookups(), 0);
    }

    #[test]
    fn routed_pattern_tracks_session_assignment_epochs() {
        let n = 24;
        let dim = 8;
        let mut rng = Rng::new(3);
        let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let mut session = RoutingSession::new(1, 2, 3, dim, 0.3, 5).unwrap();
        let mut cache = EpochCache::new();
        let slot = RouteSlot { layer: 0, head: 1, seq: 0 };
        let p0 = session.routed_pattern(&mut cache, slot, &xs, n, 6);
        assert_eq!(*p0, session.routing_spec(0, 1, &xs, n, 6).compile(n));
        // no update: repeated fetches are epoch hits on the same compile
        let p0b = session.routed_pattern(&mut cache, slot, &xs, n, 6);
        assert!(Arc::ptr_eq(&p0, &p0b));
        // update moves centroids -> the delta decides whether the compile
        // survives: changed assignments evict and recompile, a stable
        // step keeps serving the live pattern as an unchanged-epoch hit
        let upd = session.update(0, 1, &xs, n);
        let p1 = session.routed_pattern(&mut cache, slot, &xs, n, 6);
        assert_eq!(cache.slot_epoch(slot), Some(1));
        if upd.delta.changed() {
            assert_eq!(*p1, session.routing_spec(0, 1, &xs, n, 6).compile(n));
            assert_eq!(cache.slot_assignment_epoch(slot), Some(1));
            assert_eq!(cache.stats().evictions, 1);
            let es = cache.epoch_stats();
            assert_eq!((es.epoch_hits, es.epoch_misses, es.unchanged_epochs), (1, 2, 0));
        } else {
            assert!(Arc::ptr_eq(&p0, &p1), "stable assignments keep the live compile");
            assert_eq!(cache.slot_assignment_epoch(slot), Some(0));
            assert_eq!(cache.stats().evictions, 0);
            let es = cache.epoch_stats();
            assert_eq!((es.epoch_hits, es.epoch_misses, es.unchanged_epochs), (2, 1, 1));
        }
    }

    #[test]
    fn unchanged_assignment_epoch_bump_is_a_hit_not_an_eviction() {
        let mut cache = EpochCache::new();
        let slot = RouteSlot { layer: 0, head: 0, seq: 0 };
        let spec = AttentionSpec::routing(vec![vec![0, 1, 2]]);
        // compiled at cluster epoch 1, assignment epoch 1
        let p = cache.get_routed_at(slot, 1, 1, 8, || spec.clone());
        // cluster epochs 2..=4 bump past the compile with assignments
        // frozen at 1: every lookup is a hit on the same Arc
        for epoch in 2..=4u64 {
            let again =
                cache.get_routed_at(slot, epoch, 1, 8, || panic!("unchanged must not regenerate"));
            assert!(Arc::ptr_eq(&p, &again));
            assert_eq!(cache.slot_epoch(slot), Some(epoch), "last-seen epoch advances");
            assert_eq!(cache.slot_assignment_epoch(slot), Some(1));
        }
        let es = cache.epoch_stats();
        assert_eq!(es.unchanged_epochs, 3);
        assert_eq!(es.epoch_hits, 3, "unchanged-epoch hits are hits");
        assert_eq!(cache.stats().evictions, 0, "no recompile, no eviction");
        // a same-epoch re-fetch is a plain hit, not an unchanged one
        cache.get_routed_at(slot, 4, 1, 8, || panic!("hit must not regenerate"));
        assert_eq!(cache.epoch_stats().unchanged_epochs, 3);
        // the moment assignments move, the stale compile goes
        let s2 = AttentionSpec::routing(vec![vec![0, 3, 4]]);
        let p2 = cache.get_routed_at(slot, 5, 5, 8, || s2.clone());
        assert_eq!(*p2, s2.compile(8));
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.slot_assignment_epoch(slot), Some(5));
    }

    #[test]
    fn incremental_spec_regen_equals_from_scratch_and_reuses_untouched() {
        // a sparse mini-batch EMA-moves only the clusters it assigns to;
        // the member cache must re-rank exactly those and reuse the rest
        let mut s = RoutingSession::new(1, 1, 4, 4, 0.5, 9).unwrap();
        let mut members = MemberCache::new();
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..16 * 4).map(|_| rng.normal() as f32).collect();
        let spec0 = s.routing_spec_cached(0, 0, &mut members, &xs, 16, 4);
        assert_eq!(spec0, s.routing_spec(0, 0, &xs, 16, 4));
        assert_eq!(members.stats().full_rebuilds, 1, "first use is a full rebuild");
        assert_eq!(members.stats().regenerated, 4);
        // no update in between: every list is reused
        let spec1 = s.routing_spec_cached(0, 0, &mut members, &xs, 16, 4);
        assert_eq!(spec1, spec0);
        assert_eq!(members.stats().reused, 4);
        // a one-vector update touches exactly one cluster
        let upd = s.update(0, 0, &xs[0..4], 1);
        let touched: Vec<usize> = upd
            .delta
            .counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(c, _)| c)
            .collect();
        assert_eq!(touched.len(), 1);
        assert_eq!(s.dirty_clusters(0, 0), touched);
        let before = members.stats();
        let spec2 = s.routing_spec_cached(0, 0, &mut members, &xs, 16, 4);
        assert_eq!(spec2, s.routing_spec(0, 0, &xs, 16, 4), "incremental == from-scratch");
        let after = members.stats();
        assert_eq!(after.regenerated - before.regenerated, 1, "only the touched cluster");
        assert_eq!(after.reused - before.reused, 3);
        assert_eq!(after.full_rebuilds, 1, "no spurious full rebuild");
        assert!(after.reuse_rate() > 0.5);
        // the cluster worklist drains exactly once
        assert_eq!(s.take_dirty_clusters(0, 0), touched);
        assert_eq!(s.dirty_cluster_len(0, 0), 0);
        assert_eq!(s.take_dirty_clusters(0, 0), Vec::<usize>::new());
        // changed content falls back to a (still exact) full rebuild
        let xs2: Vec<f32> = (0..16 * 4).map(|_| rng.normal() as f32).collect();
        let spec3 = s.routing_spec_cached(0, 0, &mut members, &xs2, 16, 4);
        assert_eq!(spec3, s.routing_spec(0, 0, &xs2, 16, 4));
        assert_eq!(members.stats().full_rebuilds, 2);
        // a changed effective width does too (w is clamped to n first)
        let spec4 = s.routing_spec_cached(0, 0, &mut members, &xs2, 16, 7);
        assert_eq!(spec4, s.routing_spec(0, 0, &xs2, 16, 7));
        assert_eq!(members.stats().full_rebuilds, 3);
    }

    #[test]
    fn expert_regen_equals_from_scratch_and_never_reuses_across_shapes() {
        let mut s = RoutingSession::new(1, 1, 4, 4, 0.5, 9).unwrap();
        let mut members = MemberCache::new();
        let mut rng = Rng::new(3);
        let xs: Vec<f32> = (0..16 * 4).map(|_| rng.normal() as f32).collect();
        let spec0 = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 3);
        assert_eq!(spec0, s.expert_choice_spec(0, 0, &xs, 16, 3));
        assert_eq!(members.stats().full_rebuilds, 1, "first use is a full rebuild");
        assert_eq!(members.stats().regenerated, 4);
        match &spec0 {
            AttentionSpec::ExpertChoice { clusters, capacity } => {
                assert!(clusters.iter().all(|m| m.len() <= *capacity));
            }
            _ => unreachable!(),
        }
        // no update in between: every list reused, no assignment pass
        let spec1 = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 3);
        assert_eq!(spec1, spec0);
        assert_eq!(members.stats().reused, 4);
        // a one-vector update EMA-moves one centroid, but expert buckets
        // are global: incremental must equal from-scratch regardless of
        // how many buckets that one moved centroid perturbed
        s.update(0, 0, &xs[0..4], 1);
        let before = members.stats();
        let spec2 = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 3);
        assert_eq!(spec2, s.expert_choice_spec(0, 0, &xs, 16, 3), "incremental == from-scratch");
        let after = members.stats();
        assert_eq!(after.regenerated + after.reused - before.regenerated - before.reused, 4);
        assert!(after.regenerated > before.regenerated, "the moved cluster re-ranks");
        assert_eq!(after.full_rebuilds, 1, "no spurious full rebuild");
        // full-batch drift steps stay exact too
        for step in 0..4 {
            s.update(0, 0, &xs, 16);
            let spec = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 3);
            assert_eq!(spec, s.expert_choice_spec(0, 0, &xs, 16, 3), "step {step}");
        }
        assert_eq!(members.stats().full_rebuilds, 1, "same shape: still incremental");
        // a capacity change is a shape change: full rebuild, never stale reuse
        let spec = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 2);
        assert_eq!(spec, s.expert_choice_spec(0, 0, &xs, 16, 2));
        assert_eq!(members.stats().full_rebuilds, 2);
        // so is a family switch — routing-w2 and expert-cap2 never alias
        s.routing_spec_cached(0, 0, &mut members, &xs, 16, 2);
        assert_eq!(members.stats().full_rebuilds, 3);
        let spec = s.expert_choice_spec_cached(0, 0, &mut members, &xs, 16, 2);
        assert_eq!(spec, s.expert_choice_spec(0, 0, &xs, 16, 2));
        assert_eq!(members.stats().full_rebuilds, 4);
    }

    #[test]
    fn threshold_content_spec_is_causal_and_never_empty() {
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..12 * 4).map(|_| rng.normal() as f32).collect();
        let spec = threshold_content_spec(&xs, 12);
        let p = spec.compile(12);
        assert!(p.is_causal());
        for i in 0..12 {
            assert!(p.row(i).contains(&i), "row {i}: self-similarity clears the 0 cut");
        }
        // poisoned rows are quarantined but the floor keeps finite ones alive
        let mut bad = xs.clone();
        bad[5 * 4] = f32::NAN;
        let p = threshold_content_spec(&bad, 12).compile(12);
        assert!(p.is_causal());
        assert!(!p.row(5).contains(&5), "NaN self-score is never admitted");
        assert_eq!(threshold_content_spec(&[], 0).compile(0).nnz(), 0);
        // the family dispatcher routes to the same construction
        let s = RoutingSession::new(1, 1, 2, 4, 0.5, 5).unwrap();
        let mut mc = MemberCache::new();
        assert_eq!(
            routed_family_spec(SpecFamily::Threshold, &s, 0, 0, &mut mc, &xs, 12, 3),
            spec
        );
        assert_eq!(
            routed_family_spec(SpecFamily::Routing, &s, 0, 0, &mut mc, &xs, 12, 3),
            s.routing_spec(0, 0, &xs, 12, 3)
        );
        assert_eq!(
            routed_family_spec(SpecFamily::ExpertChoice, &s, 0, 0, &mut mc, &xs, 12, 3),
            s.expert_choice_spec(0, 0, &xs, 12, 3)
        );
        assert!(SpecFamily::parse("expert-choice").is_ok());
        assert!(SpecFamily::parse("warp").is_err());
        assert_eq!(SpecFamily::parse("threshold").unwrap().name(), "threshold");
    }

    #[test]
    fn member_cache_rebuilds_for_a_replaced_session() {
        // same shape, same xs, but a *new* session (fresh centroids):
        // the surviving cache must full-rebuild, never trust the old
        // snapshot — while a clone (bit-identical state) keeps reusing
        let mut rng = Rng::new(6);
        let xs: Vec<f32> = (0..12 * 4).map(|_| rng.normal() as f32).collect();
        let s1 = RoutingSession::new(1, 1, 3, 4, 0.5, 7).unwrap();
        let mut members = MemberCache::new();
        s1.routing_spec_cached(0, 0, &mut members, &xs, 12, 3);
        let clone = s1.clone();
        clone.routing_spec_cached(0, 0, &mut members, &xs, 12, 3);
        assert_eq!(members.stats().full_rebuilds, 1, "a clone shares the nonce and reuses");
        assert_eq!(members.stats().reused, 3);
        let s2 = RoutingSession::new(1, 1, 3, 4, 0.5, 99).unwrap();
        let spec = s2.routing_spec_cached(0, 0, &mut members, &xs, 12, 3);
        assert_eq!(members.stats().full_rebuilds, 2, "a replaced session must rebuild");
        assert_eq!(spec, s2.routing_spec(0, 0, &xs, 12, 3), "and serve ITS centroids' lists");
    }

    #[test]
    fn retired_sequence_slots_are_garbage_collected() {
        // stream-close GC: evict_slot drops the per-request compile,
        // counts the eviction, and leaves statics + other requests alone
        let mut session = RoutingSession::new(1, 1, 2, 4, 0.5, 8).unwrap();
        let mut cache = EpochCache::new();
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let local = AttentionSpec::local(2).unwrap();
        cache.get_static(&local, 8);
        let a = RouteSlot { layer: 0, head: 0, seq: 0 };
        let b = RouteSlot { layer: 0, head: 0, seq: 1 };
        session.routed_pattern(&mut cache, a, &xs, 8, 4);
        session.routed_pattern(&mut cache, b, &xs, 8, 4);
        assert_eq!(cache.len(), 3);
        let evictions = cache.stats().evictions;
        assert!(cache.evict_slot(a).is_some(), "request 0 completes: its slot is collected");
        assert_eq!(cache.stats().evictions, evictions + 1, "GC counts as an eviction");
        assert_eq!(cache.len(), 2, "the static and the live request survive");
        assert_eq!(cache.slot_epoch(a), None, "the retired compile is gone");
        // a later lookup for the retired slot recompiles from scratch
        let misses = cache.epoch_stats().epoch_misses;
        session.routed_pattern(&mut cache, a, &xs, 8, 4);
        assert_eq!(cache.epoch_stats().epoch_misses, misses + 1);
        assert_eq!(
            cache.evict_slot(RouteSlot { layer: 0, head: 0, seq: 9 }),
            None,
            "absent is a no-op"
        );
    }

    #[test]
    fn evict_slot_drops_exactly_one_slot() {
        let mut cache = EpochCache::new();
        let a = RouteSlot { layer: 0, head: 0, seq: 0 };
        let b = RouteSlot { layer: 0, head: 0, seq: 1 };
        cache.get_routed(a, 0, 8, || AttentionSpec::routing(vec![vec![0, 1]]));
        cache.get_routed(b, 0, 8, || AttentionSpec::routing(vec![vec![2, 3]]));
        let pinned = cache.get_static(&AttentionSpec::local(2).unwrap(), 8);
        assert_eq!(cache.len(), 3);
        let freed = cache.evict_slot(a).expect("present slot evicts");
        assert_eq!(
            freed,
            AttentionSpec::routing(vec![vec![0, 1]]).compile(8).heap_bytes(),
            "evict_slot reports the compile's heap bytes"
        );
        assert_eq!(cache.evict_slot(a), None, "absent slot is a no-op");
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.len(), 2, "the other slot and the pinned static survive");
        assert_eq!(cache.slot_epoch(a), None);
        assert_eq!(cache.slot_epoch(b), Some(0));
        assert!(Arc::ptr_eq(&pinned, &cache.get_static(&AttentionSpec::local(2).unwrap(), 8)));
        // the evicted slot recompiles on its next lookup
        let misses = cache.stats().misses;
        cache.get_routed(a, 0, 8, || AttentionSpec::routing(vec![vec![0, 1]]));
        assert_eq!(cache.stats().misses, misses + 1);
    }

    #[test]
    fn budgeted_epoch_cache_spills_lru_but_never_pinned_or_step_touched() {
        use super::super::compiled::MemoryBudget;
        let n = 32;
        let local = AttentionSpec::local(2).unwrap();
        // every slot compiles the same spec, so all routed entries have
        // identical heap bytes and the spill arithmetic is exact
        let routed_spec = AttentionSpec::routing(vec![(0..n).collect()]);
        let slot_bytes = routed_spec.compile(n).heap_bytes();
        let static_bytes = local.compile(n).heap_bytes();
        // room for the pinned static plus two and a half routed compiles
        let max = static_bytes + 2 * slot_bytes + slot_bytes / 2;
        let budget = MemoryBudget::bytes(max);
        let mut cache = EpochCache::with_budget(budget.clone());
        let slot = |seq: usize| RouteSlot { layer: 0, head: 0, seq };
        cache.get_static(&local, n);
        for seq in 0..3 {
            cache.get_routed(slot(seq), 0, n, || routed_spec.clone());
        }
        // third insert went over budget: the LRU slot (seq 0) spilled
        assert!(budget.resident() <= max, "spill restored the cap");
        assert_eq!(cache.slot_epoch(slot(0)), None, "LRU victim spilled");
        assert_eq!(cache.slot_epoch(slot(1)), Some(0));
        assert_eq!(cache.slot_epoch(slot(2)), Some(0));
        // touching seq 1 makes seq 2 the LRU victim for the next insert
        cache.get_routed(slot(1), 0, n, || unreachable!("hit: served live"));
        cache.get_routed(slot(3), 0, n, || routed_spec.clone());
        assert_eq!(cache.slot_epoch(slot(2)), None, "recency decides the victim");
        assert_eq!(cache.slot_epoch(slot(1)), Some(0), "recently touched survives");
        // a step's working set is protected: over-budget inserts spill
        // nothing when every other slot was touched this step
        cache.mark_step();
        cache.get_routed(slot(1), 0, n, || unreachable!("hit: served live"));
        cache.get_routed(slot(3), 0, n, || unreachable!("hit: served live"));
        cache.get_routed(slot(4), 0, n, || routed_spec.clone());
        for seq in [1, 3, 4] {
            assert_eq!(cache.slot_epoch(slot(seq)), Some(0), "step-touched slot survives");
        }
        assert!(budget.over_budget(), "the cap is soft by the protected set");
        // the pinned static never spills, even while over budget
        assert_eq!(cache.cache.len(), 1, "pinned static survived every spill");
        // next step: protection lapses and the cap is restored
        cache.mark_step();
        cache.get_routed(slot(5), 0, n, || routed_spec.clone());
        assert!(budget.resident() <= max, "unprotected LRU slots spilled");
        assert_eq!(cache.slot_epoch(slot(1)), None);
        assert_eq!(cache.slot_epoch(slot(3)), None);
        assert_eq!(cache.slot_epoch(slot(4)), Some(0));
        assert_eq!(cache.slot_epoch(slot(5)), Some(0));
        let es = cache.epoch_stats();
        assert_eq!(es.bytes_resident, 2 * slot_bytes as u64, "gauge tracks live slots");
        assert_eq!(
            es.bytes_evicted,
            4 * slot_bytes as u64,
            "seqs 0, 2, 1, 3 were spilled and their bytes accounted"
        );
        let total = cache.stats();
        assert_eq!(
            total.bytes_resident,
            (static_bytes + 2 * slot_bytes) as u64,
            "merged gauge covers the pinned static too"
        );
        drop(cache);
        assert_eq!(budget.resident(), 0, "dropping the cache releases every charge");
    }

    #[test]
    fn batched_matches_independent_calls_bitwise() {
        let mut rng = Rng::new(42);
        let n = 20;
        let d = 8;
        let patterns: Vec<Arc<CompiledPattern>> = vec![
            Arc::new(AttentionSpec::local(4).unwrap().compile(n)),
            Arc::new(AttentionSpec::Full.compile(n)),
            Arc::new(AttentionSpec::routing(vec![vec![0, 3, 7, 11], vec![2, 5, 19]]).compile(n)),
        ];
        let b = patterns.len();
        let (q, k, v) = random_qkv(&mut rng, b * n, d);
        for workers in [1usize, 2, 3, 7] {
            let batch = BatchedAttention::new(patterns.clone(), workers).unwrap();
            assert_eq!(batch.batch(), b);
            assert_eq!(batch.num_workers(), workers);
            assert_eq!(batch.worker_rows().iter().sum::<usize>(), b * n);
            assert_eq!(
                batch.worker_nnz().iter().sum::<usize>(),
                patterns.iter().map(|p| p.nnz()).sum::<usize>(),
                "per-worker nnz partitions the batch total"
            );
            let out = batch.attention(&q, &k, &v, d).unwrap();
            let mut expect = Vec::with_capacity(b * n * d);
            for (s, p) in patterns.iter().enumerate() {
                let lo = s * n * d;
                let hi = lo + n * d;
                expect.extend(sparse_attention(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, p).unwrap());
            }
            assert_eq!(out, expect, "batched must be bit-identical at workers = {workers}");
        }
        // free-function form agrees too
        let via_fn = sparse_attention_batch(&q, &k, &v, d, &patterns, 2).unwrap();
        assert_eq!(via_fn, BatchedAttention::new(patterns, 2).unwrap().attention(&q, &k, &v, d).unwrap());
    }

    #[test]
    fn shared_pattern_batch() {
        let mut rng = Rng::new(9);
        let n = 12;
        let d = 4;
        let pattern = Arc::new(AttentionSpec::local(3).unwrap().compile(n));
        let b = 4;
        let (q, k, v) = random_qkv(&mut rng, b * n, d);
        let batch = BatchedAttention::shared(Arc::clone(&pattern), b, 3).unwrap();
        assert_eq!(batch.nnz(), b * pattern.nnz());
        assert_eq!(batch.cost(d), b as u64 * pattern.cost(d));
        let out = batch.attention(&q, &k, &v, d).unwrap();
        for s in 0..b {
            let lo = s * n * d;
            let hi = lo + n * d;
            let single = sparse_attention(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, &pattern).unwrap();
            assert_eq!(&out[lo..hi], single.as_slice(), "sequence {s} must match");
        }
    }

    #[test]
    fn batch_degenerate_shapes() {
        // empty batch: no rows, empty output
        let empty = BatchedAttention::new(Vec::new(), 2).unwrap();
        assert_eq!(empty.batch(), 0);
        assert_eq!(empty.attention(&[], &[], &[], 4).unwrap(), Vec::<f32>::new());
        // n = 0 sequences
        let z = Arc::new(AttentionSpec::Full.compile(0));
        let batch = BatchedAttention::new(vec![Arc::clone(&z), z], 3).unwrap();
        assert_eq!(batch.attention(&[], &[], &[], 4).unwrap(), Vec::<f32>::new());
        // n = 1
        let one = Arc::new(AttentionSpec::Full.compile(1));
        let batch = BatchedAttention::shared(one, 2, 2).unwrap();
        let out = batch.attention(&[1.0, 2.0], &[0.5, 0.5], &[3.0, -4.0], 1).unwrap();
        assert_eq!(out, vec![3.0, -4.0]);
        // mismatched sequence lengths, zero workers, bad shapes, d = 0
        let p8 = Arc::new(AttentionSpec::Full.compile(8));
        let p9 = Arc::new(AttentionSpec::Full.compile(9));
        assert!(BatchedAttention::new(vec![Arc::clone(&p8), p9], 2).is_err());
        assert!(BatchedAttention::new(vec![Arc::clone(&p8)], 0).is_err());
        let batch = BatchedAttention::new(vec![p8], 2).unwrap();
        assert!(batch.attention(&[0.0; 8], &[0.0; 8], &[0.0; 8], 0).is_err());
        assert!(batch.attention(&[0.0; 7], &[0.0; 8], &[0.0; 8], 1).is_err());
    }

    #[test]
    fn decode_loop_end_to_end() {
        // a miniature serving loop: 2 sequences, 1 layer x 2 heads (head 0
        // static local, head 1 routed), routing re-fit every 2 steps
        let n = 32;
        let d = 8;
        let b = 2;
        let steps = 6;
        let mut rng = Rng::new(17);
        let mut session = RoutingSession::new(1, 2, 4, d, 0.5, 2).unwrap();
        let mut cache = EpochCache::new();
        let local = AttentionSpec::local(4).unwrap();
        let (q, k, v) = random_qkv(&mut rng, b * n, d);
        let mut xs: Vec<Vec<f32>> = (0..b)
            .map(|_| (0..n * d).map(|_| rng.normal() as f32).collect())
            .collect();
        // delta-aware accounting: a re-fit after the slots are populated
        // costs one eviction + recompile per slot only when it moved a
        // token; a stable re-fit is an unchanged-epoch hit per slot
        let mut changed_refits = 0u64;
        let mut unchanged_refits = 0u64;
        for step in 0..steps {
            if step % 2 == 0 {
                for x in xs.iter_mut().flat_map(|s| s.iter_mut()) {
                    *x = 0.9 * *x + 0.1 * rng.normal() as f32;
                }
                let all: Vec<f32> = xs.concat();
                let upd = session.update(0, 1, &all, b * n);
                if step > 0 {
                    if upd.delta.changed() {
                        changed_refits += 1;
                    } else {
                        unchanged_refits += 1;
                    }
                }
            }
            let static_p = cache.get_static(&local, n);
            let routed: Vec<Arc<CompiledPattern>> = (0..b)
                .map(|s| {
                    let slot = RouteSlot { layer: 0, head: 1, seq: s };
                    session.routed_pattern(&mut cache, slot, &xs[s], n, n / 4)
                })
                .collect();
            for patterns in [vec![Arc::clone(&static_p); b], routed] {
                let batch = BatchedAttention::new(patterns.clone(), 3).unwrap();
                let out = batch.attention(&q, &k, &v, d).unwrap();
                for (s, p) in patterns.iter().enumerate() {
                    let lo = s * n * d;
                    let hi = lo + n * d;
                    let single =
                        sparse_attention(&q[lo..hi], &k[lo..hi], &v[lo..hi], d, p).unwrap();
                    assert_eq!(&out[lo..hi], single.as_slice());
                }
            }
        }
        // 3 re-fits: the first populates both slots; each later one costs
        // per-slot evictions/recompiles only when its delta moved tokens
        let b64 = b as u64;
        assert_eq!(cache.stats().evictions, b64 * changed_refits);
        let es = cache.epoch_stats();
        assert_eq!(es.lookups(), (steps * b) as u64);
        assert_eq!(
            es.epoch_misses,
            b64 * (1 + changed_refits),
            "one regeneration per slot per changed assignment epoch"
        );
        assert_eq!(
            es.unchanged_epochs,
            b64 * unchanged_refits,
            "stable re-fits must be served as unchanged-epoch hits"
        );
        assert!(cache.len() <= 1 + b, "bounded: pinned static + one routed per slot");
    }
}

//! Batched/sharded pattern-evaluation engine over [`CompiledPattern`] —
//! the serving-scale layer on top of the spec→compile pipeline.
//!
//! Compiling a spec is O(nnz); a serving loop that recompiles the same
//! head plan for every head, layer, and decode step throws the paper's
//! O(n^1.5 d) win away on pattern construction.  This module adds the
//! three pieces that make compiled sparsity *reusable and executable*:
//!
//! * [`PatternCache`] — deduplicates compiles across heads/layers/steps.
//!   Entries are keyed by the spec's normalized identity plus the sequence
//!   length (constructors normalize specs, so structural equality is
//!   exactly canonical-JSON equality — the hot path hashes the spec
//!   directly instead of re-serializing it) and reports hit/miss stats so
//!   serving can watch its amortization.
//! * [`ShardedPattern`] — contiguous per-shard row ranges over one
//!   pattern, split by row count ([`ShardedPattern::by_rows`]) or by nnz
//!   so every worker gets equal work ([`ShardedPattern::balanced`]).
//!   Per-shard `nnz`/`cost` let a scheduler place shards; shard nnz always
//!   sums to the pattern's `nnz()`.
//! * [`sparse_attention`] / [`sparse_attention_rows`] — a host-side f32
//!   reference kernel: per-row softmax(q·kᵀ/√d) over exactly the CSR
//!   attend-set, then the weighted value gather.  Fully-masked rows (an
//!   empty S_i, e.g. an unrouted token) produce zeros, never NaN —
//!   mirroring the fully-masked-logit guard in [`crate::sampler`].
//!   [`dense_masked_attention`] is the O(n²d) masked-softmax oracle the
//!   kernel is validated against (both accumulate in f64, so they agree
//!   to final-rounding precision).
//!
//! The batched zero-allocation row gather itself lives on the pattern:
//! [`CompiledPattern::rows`] yields `(i, &[usize], &[u32])` slices
//! straight out of the CSR arrays.  Multi-worker execution runs on the
//! resident [`super::pool::WorkerPool`] by default (see
//! [`ShardedPattern::attention_with`] for the per-call
//! [`Execution`](super::pool::Execution) override).
//!
//! # Epoch/eviction lifecycle (dirty-set flow)
//!
//! The cache itself is spec-keyed and append-only: static specs (local /
//! strided / block-local head plans) are compiled once and stay pinned for
//! the lifetime of the process — a head plan holds a handful of distinct
//! specs, so there is nothing to evict.  Content-routed specs are
//! different: online k-means (Algorithm 1) moves centroids on every
//! `update`, so each update starts a new **cluster epoch**.  But moved
//! centroids do not necessarily move *assignments* — MoSA-style
//! expert-choice routing observes most assignments are stable step to
//! step — so [`crate::kmeans::SphericalKMeans::update`] reports the
//! assignment delta (which tokens changed cluster), and
//! [`super::decode::RoutingSession`] advances a slot's **assignment
//! epoch** (and extends its dirty token set) only when the delta is
//! non-empty.  [`PatternCache::evict`] remains the spec-keyed
//! invalidation primitive (drop every compiled length of one spec,
//! counted in [`CacheStats::evictions`]); [`super::decode::EpochCache`]
//! goes further for the decode loop: routed compiles never enter the
//! shared spec-keyed map at all — each (layer, head, sequence) slot owns
//! its one live pattern tagged with the assignment epoch it was built
//! from.  A lookup whose assignment epoch still matches is an O(1) hit
//! even when the cluster epoch has bumped past the compile (counted in
//! [`super::decode::EpochCacheStats::unchanged_epochs`] — a recompile
//! skipped, not an eviction); a lookup whose assignment epoch moved
//! drops the stale compile (an eviction in the merged stats) before the
//! new memberships are compiled.  The decode loop thus never serves a
//! pattern built from superseded *assignments*, a slot's eviction can
//! never collide with a pinned static compile, and the cache stays
//! bounded at one live routing pattern per slot plus the pinned statics.
//!
//! Consumers: `rtx serve-bench` (heads × layers × steps sweep printing
//! cache hit-rate, epoch hit-rate, evictions, and batched vs sequential
//! rows/sec, plus `--pool` pool-vs-scoped comparison rows),
//! `bench_complexity` (cached multi-head compile ≥ 5× over uncached;
//! batched ≥ 2× over sequential at B = 8; pool ≥ 1.3× over scoped
//! spawns), `examples/analyze_attention.rs`, the engine property tests,
//! and the stateful model-based suite (`tests/stateful.rs`).
//!
//! The per-row kernel itself is pluggable: [`super::backend`] abstracts
//! "execute these CSR rows" behind a registerable
//! [`Backend`](super::backend::Backend) trait (scalar reference, the
//! cache-blocked host kernel, and the `xla`-gated accelerator landing
//! slot), selected per call via [`ShardedPattern::attention_backend`].
//! See `ARCHITECTURE.md` for the full pipeline.

use std::collections::{BTreeMap, HashMap};
use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::compiled::{CompiledPattern, MemoryBudget};
use super::pool::Execution;
use super::spec::AttentionSpec;

// ---------------------------------------------------------------- cache

/// Hit/miss/eviction counters for a [`PatternCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from an existing compile.
    pub hits: u64,
    /// Lookups that had to compile (one compile per miss).
    pub misses: u64,
    /// Compiled patterns dropped by [`PatternCache::evict`] or spilled
    /// by the [`MemoryBudget`] LRU (one per `(spec, n)` entry removed) —
    /// the routing-churn signal a serving loop watches; see
    /// [`super::decode::EpochCache`].
    pub evictions: u64,
    /// Heap bytes of the patterns currently resident (a gauge, not a
    /// counter).
    pub bytes_resident: u64,
    /// Cumulative heap bytes freed by evictions and spills.
    pub bytes_evicted: u64,
    /// Band compiles folded in by banded consumers
    /// ([`super::spec::ChunkedPattern`]); always 0 for a plain
    /// monolithic cache.
    pub band_compiles: u64,
}

/// What an eviction freed: how many `(spec, n)` entries were dropped and
/// how many pattern heap bytes they held.  Returned by
/// [`PatternCache::evict`] / [`PatternCache::clear`] so GC reports can
/// print bytes reclaimed, not just entry counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Freed {
    /// `(spec, n)` entries removed.
    pub entries: usize,
    /// Pattern heap bytes those entries held.
    pub bytes: usize,
}

impl CacheStats {
    /// Total lookups (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served without compiling; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// One cached compile plus the bookkeeping the byte budget needs.
#[derive(Debug)]
struct CacheEntry {
    pattern: Arc<CompiledPattern>,
    /// `pattern.heap_bytes()`, frozen at insert (patterns are immutable).
    bytes: usize,
    /// LRU clock value of the last lookup that touched this entry.
    last_used: u64,
    /// Pinned entries (static head-plan compiles inserted via
    /// [`PatternCache::get_or_compile_pinned`]) are never LRU victims.
    pinned: bool,
}

/// Compile cache: (spec, n) → shared [`CompiledPattern`].
///
/// Serving reuses one pattern across every head and decode step that
/// shares a spec, so the cache hands out `Arc`s; a hit is a hash + spec
/// equality check (no serialization, no compile).  Static specs stay
/// pinned forever (a head plan holds a handful), with
/// [`PatternCache::evict`] available for spec-keyed invalidation; the
/// decode loop's per-epoch routing compiles are slot-owned by
/// [`super::decode::EpochCache`] and never enter this map at all.
///
/// A cache built with [`PatternCache::with_budget`] charges every
/// resident pattern's [`CompiledPattern::heap_bytes`] against the shared
/// [`MemoryBudget`] and LRU-spills unpinned entries whenever an insert
/// pushes the budget over — never the entry being returned from the
/// in-flight lookup, and never a pinned static, so the budget is a soft
/// cap that in-flight steps can trust.  [`PatternCache::new`] meters
/// against an unbounded budget (counters move, nothing spills).
#[derive(Debug)]
pub struct PatternCache {
    /// Outer map by spec (hashed structurally ≡ by canonical JSON, since
    /// constructors normalize), inner by sequence length.
    entries: HashMap<AttentionSpec, BTreeMap<usize, CacheEntry>>,
    stats: CacheStats,
    budget: MemoryBudget,
    /// LRU clock, bumped per lookup; deterministic (no wall-clock) so the
    /// stateful model harness can mirror eviction order exactly.
    tick: u64,
}

impl Default for PatternCache {
    fn default() -> PatternCache {
        PatternCache::new()
    }
}

impl PatternCache {
    /// An empty cache with zeroed counters and an unbounded budget.
    pub fn new() -> PatternCache {
        PatternCache::with_budget(MemoryBudget::unbounded())
    }

    /// An empty cache metering residency against `budget` (clones of one
    /// budget share the meter, so several caches can split one cap).
    pub fn with_budget(budget: MemoryBudget) -> PatternCache {
        PatternCache { entries: HashMap::new(), stats: CacheStats::default(), budget, tick: 0 }
    }

    /// The budget this cache charges.
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// The pattern for `(spec, n)`, compiling at most once per key.
    pub fn get_or_compile(&mut self, spec: &AttentionSpec, n: usize) -> Arc<CompiledPattern> {
        self.lookup(spec, n, false)
    }

    /// [`PatternCache::get_or_compile`], marking the entry pinned: a
    /// pinned entry is never an LRU spill victim (static head-plan
    /// compiles must not be evicted out from under an in-flight step).
    /// Pinning is sticky — a pinned entry stays pinned even when later
    /// looked up unpinned.
    pub fn get_or_compile_pinned(
        &mut self,
        spec: &AttentionSpec,
        n: usize,
    ) -> Arc<CompiledPattern> {
        self.lookup(spec, n, true)
    }

    fn lookup(&mut self, spec: &AttentionSpec, n: usize, pin: bool) -> Arc<CompiledPattern> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(spec).and_then(|by_n| by_n.get_mut(&n)) {
            self.stats.hits += 1;
            e.last_used = self.tick;
            e.pinned |= pin;
            return Arc::clone(&e.pattern);
        }
        self.stats.misses += 1;
        let pattern = Arc::new(spec.compile(n));
        let bytes = pattern.heap_bytes();
        self.budget.charge(bytes);
        self.stats.bytes_resident += bytes as u64;
        self.entries.entry(spec.clone()).or_default().insert(
            n,
            CacheEntry { pattern: Arc::clone(&pattern), bytes, last_used: self.tick, pinned: pin },
        );
        self.spill(spec, n);
        pattern
    }

    /// LRU-spill unpinned entries (other than the just-touched
    /// `(keep_spec, keep_n)`) until the shared budget is satisfied or
    /// nothing evictable remains.
    fn spill(&mut self, keep_spec: &AttentionSpec, keep_n: usize) {
        while self.budget.over_budget() {
            let victim = self
                .entries
                .iter()
                .flat_map(|(spec, by_n)| by_n.iter().map(move |(&n, e)| (spec, n, e)))
                .filter(|&(spec, n, e)| !e.pinned && !(spec == keep_spec && n == keep_n))
                .min_by_key(|(_, _, e)| e.last_used)
                .map(|(spec, n, _)| (spec.clone(), n));
            let Some((spec, n)) = victim else { break };
            let by_n = self.entries.get_mut(&spec).expect("victim spec present");
            let e = by_n.remove(&n).expect("victim entry present");
            if by_n.is_empty() {
                self.entries.remove(&spec);
            }
            self.release(e.bytes, 1);
        }
    }

    /// Shared accounting for dropping entries worth `bytes`.
    fn release(&mut self, bytes: usize, entries: u64) {
        self.budget.release(bytes);
        self.stats.evictions += entries;
        self.stats.bytes_resident -= bytes as u64;
        self.stats.bytes_evicted += bytes as u64;
    }

    /// Drop every compiled length of `spec`, counting one eviction per
    /// `(spec, n)` entry removed; returns the entries and pattern heap
    /// bytes freed.  The spec-keyed invalidation primitive: when content
    /// supersedes a compiled routing spec (see
    /// [`super::decode::EpochCache`] for the epoch bookkeeping), the old
    /// compile is dead weight and must not linger.
    pub fn evict(&mut self, spec: &AttentionSpec) -> Freed {
        match self.entries.remove(spec) {
            Some(by_n) => {
                let entries = by_n.len();
                let bytes: usize = by_n.values().map(|e| e.bytes).sum();
                self.release(bytes, entries as u64);
                Freed { entries, bytes }
            }
            None => Freed::default(),
        }
    }

    /// Cached `(spec, n)` entries.
    pub fn len(&self) -> usize {
        self.entries.values().map(BTreeMap::len).sum()
    }

    /// True when no compile is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit/miss/eviction counters since construction (or [`PatternCache::clear`]).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop all entries and reset the counters, releasing every budget
    /// charge; returns what was freed (not counted in the — just reset —
    /// eviction stats).
    pub fn clear(&mut self) -> Freed {
        let entries = self.len();
        let bytes: usize = self
            .entries
            .values()
            .flat_map(|by_n| by_n.values().map(|e| e.bytes))
            .sum();
        self.budget.release(bytes);
        self.entries.clear();
        self.stats = CacheStats::default();
        Freed { entries, bytes }
    }
}

impl Drop for PatternCache {
    /// Return every still-charged byte to the shared meter, so dropping
    /// a retired cache is indistinguishable (to the budget) from
    /// clearing it first.
    fn drop(&mut self) {
        let bytes: usize = self
            .entries
            .values()
            .flat_map(|by_n| by_n.values().map(|e| e.bytes))
            .sum();
        self.budget.release(bytes);
    }
}

// ---------------------------------------------------------------- shards

/// One worker's slice of a pattern: a contiguous row range plus its work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Position in [`ShardedPattern::shards()`].
    pub index: usize,
    /// Contiguous query rows `[start, end)` this shard owns.
    pub rows: Range<usize>,
    /// Non-zero entries inside `rows` (sums to the pattern's `nnz()`).
    pub nnz: usize,
}

impl Shard {
    /// Number of query rows this shard owns (possibly 0 when `k > n`).
    pub fn n_rows(&self) -> usize {
        self.rows.end - self.rows.start
    }

    /// Exact multiply-accumulate count for this shard at head dim `d`
    /// (same model as [`CompiledPattern::cost`]).
    pub fn cost(&self, d: usize) -> u64 {
        2 * self.nnz as u64 * d as u64
    }
}

/// A [`CompiledPattern`] split into contiguous row-range shards, so one
/// sequence's attention can be spread across workers.  Shards partition
/// `0..n` exactly: consecutive, disjoint, covering every row (some may be
/// empty when `n < k`).
#[derive(Debug, Clone)]
pub struct ShardedPattern {
    pattern: Arc<CompiledPattern>,
    shards: Vec<Shard>,
}

impl ShardedPattern {
    /// Split into `k` shards of (nearly) equal row counts.
    pub fn by_rows(pattern: Arc<CompiledPattern>, k: usize) -> Result<ShardedPattern> {
        if k == 0 {
            bail!("sharding requires at least one shard (got k = 0)");
        }
        let n = pattern.n();
        let per = n.div_ceil(k).max(1);
        let bounds: Vec<usize> = (0..=k).map(|s| (s * per).min(n)).collect();
        Ok(ShardedPattern::from_bounds(pattern, &bounds))
    }

    /// Split into `k` shards balancing nnz (work), using the CSR row
    /// offsets as a prefix sum: shard `s` ends at the first row where the
    /// running nnz reaches `total·(s+1)/k` (each split point is one binary
    /// search).  Row-count splits can leave one worker with most of the
    /// work (causal full attention: the last rows are the widest); nnz
    /// splits equalize wall-clock instead.
    pub fn balanced(pattern: Arc<CompiledPattern>, k: usize) -> Result<ShardedPattern> {
        if k == 0 {
            bail!("sharding requires at least one shard (got k = 0)");
        }
        let n = pattern.n();
        let total = pattern.nnz();
        let offsets = pattern.offsets();
        let mut bounds = Vec::with_capacity(k + 1);
        bounds.push(0usize);
        for s in 1..k {
            let target = ((total as u128 * s as u128) / k as u128) as usize;
            // first row whose prefix nnz reaches the target
            bounds.push(offsets.partition_point(|&o| o < target).min(n));
        }
        bounds.push(n);
        Ok(ShardedPattern::from_bounds(pattern, &bounds))
    }

    fn from_bounds(pattern: Arc<CompiledPattern>, bounds: &[usize]) -> ShardedPattern {
        let offsets = pattern.offsets();
        let shards = bounds
            .windows(2)
            .enumerate()
            .map(|(index, w)| Shard {
                index,
                rows: w[0]..w[1],
                nnz: offsets[w[1]] - offsets[w[0]],
            })
            .collect();
        ShardedPattern { pattern, shards }
    }

    /// The shared compiled pattern the shards slice.
    pub fn pattern(&self) -> &Arc<CompiledPattern> {
        &self.pattern
    }

    /// The shard list (consecutive, disjoint, covering `0..n`).
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Number of shards (the `k` the split was built with).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Run the sparse-attention kernel with one worker per shard, each
    /// writing its contiguous `[rows.start*d, rows.end*d)` slice of the
    /// output, on the default execution strategy (the resident global
    /// [`super::pool::WorkerPool`]).  Agrees bitwise with
    /// [`sparse_attention`] (identical per-row math, disjoint rows).
    pub fn attention(&self, q: &[f32], k: &[f32], v: &[f32], d: usize) -> Result<Vec<f32>> {
        self.attention_with(q, k, v, d, Execution::default())
    }

    /// [`ShardedPattern::attention`] with an explicit per-call
    /// [`Execution`] strategy (inline reference, scoped spawn-per-call
    /// baseline, or a resident pool) — all three are bit-identical.
    /// Runs the [`Reference`](super::backend::Reference) kernel; see
    /// [`ShardedPattern::attention_backend`] to pick another backend.
    ///
    /// Empty shards dispatch nothing, the first non-empty shard runs on
    /// the calling thread, and a single-worker split skips work
    /// distribution entirely.
    pub fn attention_with(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        exec: Execution<'_>,
    ) -> Result<Vec<f32>> {
        self.attention_backend(q, k, v, d, exec, &super::backend::Reference)
    }

    /// [`ShardedPattern::attention_with`] with an explicit
    /// [`Backend`](super::backend::Backend): every shard's rows run
    /// through `backend` instead of the scalar reference kernel.  The
    /// output honors the backend's declared
    /// [`Exactness`](super::backend::Exactness) contract versus
    /// [`Reference`](super::backend::Reference) — bitwise backends
    /// change wall-clock only, never the output; `Ulps(k)` backends
    /// stay within their declared per-element budget (compare via
    /// [`assert_outputs_match`](super::backend::assert_outputs_match)).
    pub fn attention_backend(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        exec: Execution<'_>,
        backend: &dyn super::backend::Backend,
    ) -> Result<Vec<f32>> {
        let n = self.pattern.n();
        check_qkv(q, k, v, n, d)?;
        let mut out = vec![0f32; n * d];
        let pattern = &*self.pattern;
        // carve the output into per-shard slices, dropping empty shards
        // (k > n sharding legitimately produces them)
        let mut work: Vec<(Range<usize>, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = &mut out;
        for shard in &self.shards {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(shard.n_rows() * d);
            rest = tail;
            if shard.n_rows() > 0 {
                work.push((shard.rows.clone(), head));
            }
        }
        exec.run(work, |rows, head| backend.attention_rows(q, k, v, d, pattern, rows, head))?;
        Ok(out)
    }
}

// ---------------------------------------------------------------- kernel

fn check_qkv(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Result<()> {
    if d == 0 {
        bail!("sparse attention requires head dimension d >= 1");
    }
    if q.len() != n * d || k.len() != n * d || v.len() != n * d {
        bail!(
            "q/k/v must each be [n = {n}, d = {d}] row-major (got {}, {}, {})",
            q.len(),
            k.len(),
            v.len()
        );
    }
    Ok(())
}

/// Shared argument validation for the row-range kernel contract — used by
/// [`sparse_attention_rows`] and every built-in
/// [`super::backend::Backend`] implementation, and public (re-exported as
/// `attention::backend::check_rows_args`) so external backends can reject
/// bad shapes with the exact same errors instead of re-implementing the
/// checks: d >= 1, q/k/v each `[n, d]`, `rows` within `0..n` and
/// non-inverted, `out` exactly `rows.len() * d`.
#[allow(clippy::too_many_arguments)]
pub fn check_rows_args(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    pattern: &CompiledPattern,
    rows: &Range<usize>,
    out: &[f32],
) -> Result<()> {
    let n = pattern.n();
    check_qkv(q, k, v, n, d)?;
    if rows.end > n || rows.start > rows.end {
        bail!("row range {}..{} out of bounds for n = {n}", rows.start, rows.end);
    }
    if out.len() != rows.len() * d {
        bail!("out must hold rows.len() * d = {} values (got {})", rows.len() * d, out.len());
    }
    Ok(())
}

/// Host-side f32 sparse-attention reference kernel: for every query row i,
/// softmax(q_i·k_jᵀ/√d) over exactly the pattern's attend-set S_i, then
/// the weighted sum of values.  Returns the `[n, d]` output row-major.
/// Scores and accumulation run in f64 so the result matches
/// [`dense_masked_attention`] to final-rounding precision.
pub fn sparse_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    pattern: &CompiledPattern,
) -> Result<Vec<f32>> {
    let n = pattern.n();
    check_qkv(q, k, v, n, d)?;
    let mut out = vec![0f32; n * d];
    sparse_attention_rows(q, k, v, d, pattern, 0..n, &mut out)?;
    Ok(out)
}

/// Shard-granular kernel: compute only the query rows in `rows`, writing
/// row i's output at `out[(i - rows.start) * d ..]` (`out` holds exactly
/// `rows.len() * d` values).  Q/K/V stay the full `[n, d]` buffers — keys
/// outside the shard are still attended.  Scratch buffers are reused
/// across rows; the row gather itself ([`CompiledPattern::rows`]) is
/// zero-allocation.  Fully-masked rows write zeros.
pub fn sparse_attention_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    pattern: &CompiledPattern,
    rows: Range<usize>,
    out: &mut [f32],
) -> Result<()> {
    check_rows_args(q, k, v, d, pattern, &rows, out)?;
    let scale = 1.0 / (d as f64).sqrt();
    let mut scores: Vec<f64> = Vec::new();
    let mut acc: Vec<f64> = vec![0.0; d];
    let start = rows.start;
    for (i, cols, _clusters) in pattern.rows(rows) {
        let oi = &mut out[(i - start) * d..(i - start + 1) * d];
        oi.fill(0.0);
        if cols.is_empty() {
            // fully-masked row: no keys, no distribution — zeros, not NaN
            continue;
        }
        let qi = &q[i * d..(i + 1) * d];
        scores.clear();
        let mut max = f64::NEG_INFINITY;
        for &j in cols {
            let kj = &k[j * d..(j + 1) * d];
            let s: f64 =
                qi.iter().zip(kj).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>() * scale;
            max = max.max(s);
            scores.push(s);
        }
        let mut z = 0.0f64;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            z += *s;
        }
        acc.fill(0.0);
        for (&e, &j) in scores.iter().zip(cols) {
            let w = e / z;
            let vj = &v[j * d..(j + 1) * d];
            for (a, &x) in acc.iter_mut().zip(vj) {
                *a += w * x as f64;
            }
        }
        for (o, &a) in oi.iter_mut().zip(&acc) {
            *o = a as f32;
        }
    }
    Ok(())
}

/// O(n²d) masked-softmax oracle: dense causal attention with every
/// (i, j) pair masked by `pattern.allowed`, computed with the same f64
/// internals as the sparse kernel.  Test/validation reference only —
/// never the serving path.
pub fn dense_masked_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    pattern: &CompiledPattern,
) -> Result<Vec<f32>> {
    let n = pattern.n();
    check_qkv(q, k, v, n, d)?;
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0f32; n * d];
    for i in 0..n {
        let qi = &q[i * d..(i + 1) * d];
        let mut scores: Vec<(usize, f64)> = Vec::new();
        let mut max = f64::NEG_INFINITY;
        for j in 0..n {
            if !pattern.allowed(i, j) {
                continue;
            }
            let kj = &k[j * d..(j + 1) * d];
            let s: f64 =
                qi.iter().zip(kj).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>() * scale;
            max = max.max(s);
            scores.push((j, s));
        }
        if scores.is_empty() {
            continue;
        }
        let z: f64 = scores.iter().map(|(_, s)| (s - max).exp()).sum();
        let oi = &mut out[i * d..(i + 1) * d];
        let mut acc = vec![0.0f64; d];
        for &(j, s) in &scores {
            let w = (s - max).exp() / z;
            let vj = &v[j * d..(j + 1) * d];
            for (a, &x) in acc.iter_mut().zip(vj) {
                *a += w * x as f64;
            }
        }
        for (o, &a) in oi.iter_mut().zip(&acc) {
            *o = a as f32;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::backend::{assert_outputs_match, Exactness};
    use crate::util::rng::Rng;

    fn random_qkv(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut mk = |rng: &mut Rng| (0..n * d).map(|_| rng.normal() as f32).collect();
        (mk(rng), mk(rng), mk(rng))
    }

    #[test]
    fn cache_compiles_once_per_key() {
        let mut cache = PatternCache::new();
        let local = AttentionSpec::local(4).unwrap();
        let a = cache.get_or_compile(&local, 16);
        let b = cache.get_or_compile(&local, 16);
        assert!(Arc::ptr_eq(&a, &b), "hit must reuse the same compile");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(s.bytes_resident, a.heap_bytes() as u64);
        assert_eq!(cache.len(), 1);
        // a different n or spec is a distinct entry
        cache.get_or_compile(&local, 32);
        cache.get_or_compile(&AttentionSpec::local(5).unwrap(), 16);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 3, 0));
        assert_eq!(cache.len(), 3);
        assert!((cache.stats().hit_rate() - 0.25).abs() < 1e-12);
        let freed = cache.clear();
        assert_eq!(freed.entries, 3);
        assert!(freed.bytes > 0);
        assert!(cache.is_empty());
        assert_eq!(cache.stats().lookups(), 0);
        assert_eq!(cache.stats().bytes_resident, 0);
    }

    #[test]
    fn evict_drops_every_length_and_counts() {
        let mut cache = PatternCache::new();
        let local = AttentionSpec::local(4).unwrap();
        let routed = AttentionSpec::routing(vec![vec![0, 1, 2]]);
        cache.get_or_compile(&routed, 8);
        cache.get_or_compile(&routed, 16);
        cache.get_or_compile(&local, 8);
        assert_eq!(cache.len(), 3);
        // both compiled lengths of the routed spec go at once
        let freed = cache.evict(&routed);
        assert_eq!(freed.entries, 2);
        assert_eq!(freed.bytes, routed.compile(8).heap_bytes() + routed.compile(16).heap_bytes());
        assert_eq!(cache.stats().evictions, 2);
        assert_eq!(cache.stats().bytes_evicted, freed.bytes as u64);
        assert_eq!(cache.len(), 1, "static spec must stay pinned");
        // evicting an absent spec is a no-op
        assert_eq!(cache.evict(&routed), Freed::default());
        assert_eq!(cache.stats().evictions, 2);
        // the next lookup recompiles (a miss, not a stale hit)
        let fresh = cache.get_or_compile(&routed, 8);
        assert_eq!(*fresh, routed.compile(8));
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn budgeted_cache_spills_lru_but_never_pinned() {
        let local = AttentionSpec::local(4).unwrap();
        let pin_bytes = local.compile(64).heap_bytes();
        // eight equal-shape routed specs (one 8-member cluster each, so
        // every compile costs the same bytes), budget fits ~2.5 of them
        let specs: Vec<AttentionSpec> = (0..8)
            .map(|k| AttentionSpec::routing(vec![(k..64).step_by(8).collect()]))
            .collect();
        let routed_bytes = specs[0].compile(64).heap_bytes();
        let budget = MemoryBudget::bytes(pin_bytes + 2 * routed_bytes + routed_bytes / 2);
        let mut cache = PatternCache::with_budget(budget.clone());
        cache.get_or_compile_pinned(&local, 64);
        for spec in &specs {
            cache.get_or_compile(spec, 64);
            assert!(
                budget.resident() <= budget.max_bytes().unwrap(),
                "no protected entry here, so the cap holds exactly"
            );
        }
        let s = cache.stats();
        assert!(s.evictions > 0, "inserting 8 routed compiles must spill");
        assert_eq!(s.bytes_resident, budget.resident() as u64);
        assert!(s.bytes_evicted > 0);
        // the pinned static survived every spill; the oldest routed did not
        assert!(Arc::ptr_eq(
            &cache.get_or_compile(&local, 64),
            &cache.get_or_compile_pinned(&local, 64)
        ));
        let hits_before = cache.stats().hits;
        cache.get_or_compile(&specs[0], 64);
        assert_eq!(cache.stats().hits, hits_before, "LRU victim was recompiled, not hit");
        // most-recent entries are the survivors
        let misses_before = cache.stats().misses;
        cache.get_or_compile(&specs[0], 64);
        assert_eq!(cache.stats().misses, misses_before, "just-inserted entry is protected");
    }

    #[test]
    fn cache_equals_fresh_compile() {
        let mut cache = PatternCache::new();
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(3).unwrap(),
            AttentionSpec::routing(vec![vec![0, 5, 9], vec![2, 3]]),
        ])
        .unwrap();
        assert_eq!(*cache.get_or_compile(&spec, 12), spec.compile(12));
    }

    #[test]
    fn shards_partition_rows_and_nnz() {
        let pattern = Arc::new(AttentionSpec::Full.compile(10));
        for k in [1usize, 2, 3, 7, 10, 15] {
            for sharded in [
                ShardedPattern::by_rows(Arc::clone(&pattern), k).unwrap(),
                ShardedPattern::balanced(Arc::clone(&pattern), k).unwrap(),
            ] {
                assert_eq!(sharded.num_shards(), k);
                let mut cursor = 0usize;
                let mut nnz = 0usize;
                for (s, shard) in sharded.shards().iter().enumerate() {
                    assert_eq!(shard.index, s);
                    assert_eq!(shard.rows.start, cursor, "shards must be contiguous");
                    cursor = shard.rows.end;
                    nnz += shard.nnz;
                    assert_eq!(shard.cost(4), 2 * shard.nnz as u64 * 4);
                }
                assert_eq!(cursor, 10, "shards must cover every row");
                assert_eq!(nnz, pattern.nnz(), "shard nnz must sum to pattern nnz");
            }
        }
        assert!(ShardedPattern::by_rows(pattern, 0).is_err());
    }

    #[test]
    fn balanced_shards_even_out_causal_skew() {
        // causal full attention: later rows are wider; nnz-balanced split
        // must give the first shard more rows than the last
        let pattern = Arc::new(AttentionSpec::Full.compile(64));
        let sharded = ShardedPattern::balanced(Arc::clone(&pattern), 4).unwrap();
        let shards = sharded.shards();
        assert!(shards[0].n_rows() > shards[3].n_rows());
        let target = pattern.nnz() / 4;
        for shard in shards {
            assert!(
                shard.nnz as f64 >= target as f64 * 0.5 && shard.nnz as f64 <= target as f64 * 1.5,
                "shard {} nnz {} vs target {target}",
                shard.index,
                shard.nnz
            );
        }
    }

    #[test]
    fn rows_iterator_matches_row_accessors() {
        let spec = AttentionSpec::routing(vec![vec![0, 2, 5], vec![1, 3, 4]]);
        let p = spec.compile(8);
        let mut seen = 0usize;
        for (i, cols, clusters) in p.rows(2..6) {
            assert_eq!(cols, p.row(i));
            assert_eq!(clusters, p.row_clusters(i));
            assert_eq!(cols.len(), clusters.len());
            seen += 1;
        }
        assert_eq!(seen, 4);
        // out-of-range tails clamp instead of panicking
        assert_eq!(p.rows(6..100).count(), 2);
        assert_eq!(p.rows(9..12).count(), 0);
    }

    #[test]
    fn sparse_attention_matches_dense_oracle() {
        let mut rng = Rng::new(42);
        let n = 48;
        let d = 16;
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(6).unwrap(),
            AttentionSpec::routing_balanced(n, 6).unwrap(),
        ])
        .unwrap();
        let pattern = spec.compile(n);
        let (q, k, v) = random_qkv(&mut rng, n, d);
        let sparse = sparse_attention(&q, &k, &v, d, &pattern).unwrap();
        let dense = dense_masked_attention(&q, &k, &v, d, &pattern).unwrap();
        for (a, b) in sparse.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "sparse {a} vs dense {b}");
        }
    }

    #[test]
    fn sharded_attention_agrees_with_single_shot() {
        let mut rng = Rng::new(7);
        let n = 33;
        let d = 8;
        let pattern = Arc::new(AttentionSpec::local(5).unwrap().compile(n));
        let (q, k, v) = random_qkv(&mut rng, n, d);
        let single = sparse_attention(&q, &k, &v, d, &pattern).unwrap();
        for shards in [1usize, 2, 5, 40] {
            let sharded = ShardedPattern::balanced(Arc::clone(&pattern), shards).unwrap();
            // same kernel on disjoint rows: held to bitwise equality
            assert_outputs_match(
                &single,
                &sharded.attention(&q, &k, &v, d).unwrap(),
                Exactness::Bitwise,
                "sharded vs single-shot",
            )
            .unwrap();
        }
    }

    #[test]
    fn all_masked_pattern_shards_partition_and_return_zeros() {
        // total nnz = 0: a routing spec with no clusters admits nothing.
        // The nnz-balance split must still partition the rows (no
        // divide-by-zero in the balance targets) and attention must
        // return zeros, matching the dense oracle.
        let n = 6;
        let d = 4;
        let mut rng = Rng::new(5);
        let (q, k, v) = random_qkv(&mut rng, n, d);
        for spec in [
            AttentionSpec::routing(vec![]),
            AttentionSpec::routing(vec![Vec::new(), Vec::new()]),
        ] {
            let pattern = Arc::new(spec.compile(n));
            assert_eq!(pattern.nnz(), 0, "all-masked pattern must have nnz 0");
            for shards in [1usize, 2, 4, 9] {
                for sharded in [
                    ShardedPattern::balanced(Arc::clone(&pattern), shards).unwrap(),
                    ShardedPattern::by_rows(Arc::clone(&pattern), shards).unwrap(),
                ] {
                    assert_eq!(sharded.num_shards(), shards);
                    let mut cursor = 0usize;
                    for shard in sharded.shards() {
                        assert_eq!(shard.rows.start, cursor, "shards must stay contiguous");
                        cursor = shard.rows.end;
                        assert_eq!(shard.nnz, 0);
                    }
                    assert_eq!(cursor, n, "shards must still cover every row");
                    let out = sharded.attention(&q, &k, &v, d).unwrap();
                    let zeros = vec![0f32; n * d];
                    assert_outputs_match(
                        &zeros,
                        &out,
                        Exactness::Bitwise,
                        "all-masked rows are zeros, not NaN",
                    )
                    .unwrap();
                }
            }
            assert_eq!(
                dense_masked_attention(&q, &k, &v, d, &pattern).unwrap(),
                vec![0f32; n * d]
            );
        }
    }

    #[test]
    fn fully_masked_rows_are_zero_not_nan() {
        // tokens 2 and 4 belong to no cluster: their rows are empty
        let spec = AttentionSpec::routing(vec![vec![0, 1, 3]]);
        let pattern = spec.compile(5);
        assert!(pattern.row(2).is_empty() && pattern.row(4).is_empty());
        let mut rng = Rng::new(1);
        let (q, k, v) = random_qkv(&mut rng, 5, 4);
        let out = sparse_attention(&q, &k, &v, 4, &pattern).unwrap();
        assert!(out.iter().all(|x| x.is_finite()), "masked rows must not poison the output");
        assert!(out[2 * 4..3 * 4].iter().all(|&x| x == 0.0));
        assert!(out[4 * 4..5 * 4].iter().all(|&x| x == 0.0));
        assert_outputs_match(
            &dense_masked_attention(&q, &k, &v, 4, &pattern).unwrap(),
            &out,
            Exactness::Bitwise,
            "sparse vs dense oracle on masked rows",
        )
        .unwrap();
    }

    #[test]
    fn degenerate_sizes_and_bad_shapes() {
        // n = 0: empty everything, no panic
        let p0 = AttentionSpec::Full.compile(0);
        assert_eq!(sparse_attention(&[], &[], &[], 4, &p0).unwrap(), Vec::<f32>::new());
        let s0 = ShardedPattern::balanced(Arc::new(p0), 3).unwrap();
        assert_eq!(s0.shards().iter().map(|s| s.nnz).sum::<usize>(), 0);
        assert_eq!(s0.attention(&[], &[], &[], 4).unwrap(), Vec::<f32>::new());
        // n = 1: softmax over the single diagonal entry returns v[0]
        let p1 = AttentionSpec::Full.compile(1);
        let out = sparse_attention(&[1.0, 2.0], &[0.5, 0.5], &[3.0, -4.0], 2, &p1).unwrap();
        assert!((out[0] - 3.0).abs() < 1e-6 && (out[1] + 4.0).abs() < 1e-6);
        // shape mismatches and d = 0 are errors, not UB
        let p = AttentionSpec::Full.compile(2);
        assert!(sparse_attention(&[0.0; 3], &[0.0; 4], &[0.0; 4], 2, &p).is_err());
        assert!(sparse_attention(&[], &[], &[], 0, &p).is_err());
        let mut out = [0f32; 2];
        assert!(sparse_attention_rows(&[0.0; 4], &[0.0; 4], &[0.0; 4], 2, &p, 1..3, &mut out)
            .is_err());
    }
}

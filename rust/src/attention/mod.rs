//! Attention sparsity-pattern library (host-side).
//!
//! Pure-Rust models of the sparsity patterns the paper discusses: causal
//! full attention, (blocked) local attention, strided attention (Child et
//! al. 2019) and cluster-routed attention (Algorithm 1).  These power the
//! Figure-1 renderer, the complexity model of Section 4.1
//! (`O(nkd + n²d/k)`), and the property-test suite that pins the semantics
//! shared with the L2 graph.

pub mod complexity;
pub mod patterns;

pub use complexity::{attention_flops, optimal_clusters, AttentionKind};
pub use patterns::{Pattern, PatternKind};

//! Attention sparsity subsystem: declarative specs compiled to CSR index
//! sets (host-side).
//!
//! The paper frames every sparse-attention scheme as a per-query index set
//! S_i ⊆ {0..i}; this module makes that framing the API, in two phases:
//!
//! 1. [`AttentionSpec`] — a declarative, serializable description of a
//!    scheme: causal full attention, (blocked) local attention, strided
//!    attention (Child et al. 2019), cluster-routed attention
//!    (Algorithm 1), expert-choice routing (per-cluster capacity-bounded
//!    top-k over disjoint argmax buckets), calibrated score-threshold
//!    attend sets, plus `Union`/`Intersect` composition for the mixed
//!    local+routing head plans of Sec. 4.2.  Constructors validate
//!    degenerate parameters; `flops_estimate`/`memory_estimate` keep the
//!    closed-form Section-4.1 cost model (`O(nkd + n²d/k)`, minimized at
//!    k ≈ √n, see [`optimal_clusters`]).
//! 2. [`CompiledPattern`] — the spec materialized once for a sequence
//!    length into CSR row offsets + sorted per-query key indices (with
//!    per-entry cluster ids for routed keys).  This is the single source
//!    of truth for "which keys may query i attend to": O(log w) `allowed`,
//!    O(1) `nnz`/`density`, zero-allocation `row(i)` attend-set slices and
//!    batched `rows(range)` gathers, an exact-FLOP `cost(d)`, and the
//!    Figure-1 ASCII/CSV renderers (clipped to [`RENDER_CLIP`] rows by
//!    default so large n stays printable).  For long contexts,
//!    [`AttentionSpec::compile_band`] materializes only a row range as a
//!    [`PatternBand`], and [`ChunkedPattern`] streams those bands on
//!    demand against a shared [`MemoryBudget`] (LRU spill over budget,
//!    bit-identical to the monolithic compile) so peak resident pattern
//!    bytes stay sublinear in n.
//! 3. [`engine`] — the serving layer over compiled patterns: a
//!    [`PatternCache`] deduplicating compiles across heads/layers/steps,
//!    [`ShardedPattern`] row-range shards with per-shard nnz/cost so one
//!    sequence splits across workers, and the host-side f32
//!    [`sparse_attention`] reference kernel validated against a dense
//!    masked-softmax oracle.
//! 4. [`decode`] — the decode-loop layer: [`RoutingSession`] owns
//!    per-layer/per-head online k-means state (serving classic routing
//!    and the expert-choice / threshold families via [`SpecFamily`] and
//!    the shared [`routed_family_spec`] builder) with a cluster **epoch**,
//!    an **assignment epoch** (advanced only when an update actually
//!    moved tokens between clusters), and a per-slot **dirty set**;
//!    [`EpochCache`] evicts compiled routing patterns only when their
//!    assignment epoch goes stale (an unchanged-assignment epoch bump is
//!    an `unchanged_epochs` hit; static specs stay pinned), and
//!    [`BatchedAttention`] packs B independent sequences into one
//!    nnz-balanced worker sweep, bit-identical to B separate
//!    [`sparse_attention`] calls.
//! 5. [`pool`] — the execution substrate: a resident, lazily-spawned
//!    [`WorkerPool`] (sized by `available_parallelism`, `RTX_WORKERS`
//!    override) replaces the old per-call scoped spawns; [`Execution`]
//!    picks inline / scoped / pool per call, all bit-identical.
//! 6. [`backend`] — the kernel layer: a registerable [`Backend`] trait
//!    ("execute these CSR rows against [n, d] Q/K/V") with the scalar
//!    [`Reference`] oracle, the cache-blocked [`Blocked`] host kernel
//!    (bit-identical, ≥ 1.5× faster), the fast-math [`Simd`] kernel
//!    (lane-widened f32, ≥ 3× faster within a declared ulps budget),
//!    and the `xla`-feature-gated accelerator landing slot; selected
//!    per call via [`ShardedPattern::attention_backend`] /
//!    [`BatchedAttention::attention_backend`].  Every backend declares
//!    its numerical contract via [`Backend::exactness`]
//!    ([`Exactness::Bitwise`] or [`Exactness::Ulps`]); verification
//!    sites compare through [`assert_outputs_match`] so bitwise
//!    backends stay pinned to bit-exactness.
//! 7. [`serve`] — the continuous-batching front-end: a deterministic
//!    open-loop arrival process ([`RequestQueue`]: seeded exponential
//!    interarrivals, Zipf content popularity), a [`Scheduler`] with
//!    per-request deadlines, admission control, and shed accounting
//!    (admit → decode steps → retire → [`EpochCache::evict_slot`] GC),
//!    and the [`run_serve`] loop that repacks the live batch every step
//!    and reports p50/p99 step latency from a streaming histogram —
//!    `rtx serve` against `rtx serve-bench`'s lock-step baseline.
//! 8. [`coordinator`] — the multi-process scale-out layer: a
//!    [`Coordinator`] owning all routing state splits each sweep's rows
//!    (nnz-balanced [`ShardedPattern`] ranges) across `rtx worker`
//!    subprocesses over a length-prefixed JSON protocol, shipping
//!    epoch-stamped spec installs and [`RouteUpdate`] deltas; an
//!    explicit Join → Ready → Busy → Crashed/Rejoined state machine
//!    with exactly-once grant accounting, behind a pluggable
//!    [`Transport`] (real children via [`ProcessTransport`], seeded
//!    fault injection via [`SimTransport`]), bit-identical to inline
//!    execution ([`run_serve_coordinated`] vs [`run_serve`]).
//!
//! Consumers: the `figure1`, `serve-bench`, and `serve` CLIs, the
//! complexity bench,
//! the Table-6 JSD analysis ([`crate::analysis::mean_pattern_jsd`]), the
//! k-means routing integration
//! ([`crate::kmeans::SphericalKMeans::routing_spec`]), the property
//! tests that pin the semantics shared with the L2 graph, and the
//! stateful model-based suite (`tests/stateful.rs`).  The full pipeline
//! (spec → compile → cache → shard/batch → execution → backend) is
//! documented in `ARCHITECTURE.md` at the repository root.

#![warn(missing_docs)]

pub mod backend;
pub mod compiled;
pub mod complexity;
pub mod coordinator;
pub mod decode;
pub mod engine;
pub mod pool;
pub mod serve;
pub mod spec;

pub use backend::{
    assert_outputs_match, ulps_distance, values_match, Backend, Blocked, Exactness, Reference,
    Simd,
};
pub use compiled::{CompiledPattern, MemoryBudget, PatternBand, RowIter, RowStats, NO_CLUSTER, RENDER_CLIP};
pub use complexity::optimal_clusters;
pub use coordinator::{
    fold_digest, read_frame, run_worker, write_frame, CoordStats, Coordinator, CoordinatorConfig,
    FaultCounters, ProcessTransport, SimTransport, Transport, TransportEvent, WorkerId, WorkerNode,
    WorkerState, DIGEST_SEED, MAX_FRAME_BYTES, PROTOCOL_VERSION, STATIC_STREAM,
};
pub use decode::{
    routed_family_spec, sparse_attention_batch, threshold_content_spec, BatchedAttention,
    EpochCache, EpochCacheStats, MemberCache, RegenStats, RouteSlot, RouteUpdate,
    RoutingSession, SpecFamily,
};
pub use engine::{
    dense_masked_attention, sparse_attention, sparse_attention_rows, CacheStats, Freed,
    PatternCache, Shard, ShardedPattern,
};
pub use pool::{Execution, WorkerPool};
pub use serve::{
    run_serve, run_serve_coordinated, ArrivalConfig, BatchEntry, OutcomeKind, RequestOutcome,
    RequestQueue, Retired, Scheduler, ServeOptions, ServeRequest, ServeStats, ServeSummary,
    StepFinish, StepPlan, Submission, JSON_SCHEMA_VERSION,
};
pub use spec::{AttentionSpec, ChunkedPattern, ChunkedRowIter};

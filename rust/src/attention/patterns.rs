//! Sparsity patterns + the Figure 1 renderer.
//!
//! A [`Pattern`] answers "which key positions may query `i` attend to?"
//! for every attention kind in the paper, and renders the 2-D attention
//! scheme figures (rows = outputs, columns = inputs) as ASCII or CSV.

use crate::kmeans::SphericalKMeans;

/// Which sparse-attention scheme a pattern models.
#[derive(Debug, Clone, PartialEq)]
pub enum PatternKind {
    /// Causal full attention: S_i = { j | j <= i }.
    Full,
    /// Sliding-window local attention: S_i = { j | i-w < j <= i }.
    Local { window: usize },
    /// Blocked local attention (the L1 kernel's semantics): query block b
    /// attends to blocks b-1 and b, causally.
    BlockLocal { window: usize },
    /// Strided attention (Child et al.): S_i = { j <= i | (i-j) % s == 0 }.
    Strided { stride: usize },
    /// Cluster routing (Algorithm 1): token i attends to j <= i iff some
    /// cluster selected both i and j.
    Routing { clusters: Vec<Vec<usize>> },
}

/// A sparsity pattern over a sequence of length `n`.
#[derive(Debug, Clone)]
pub struct Pattern {
    pub n: usize,
    pub kind: PatternKind,
}

impl Pattern {
    pub fn full(n: usize) -> Pattern {
        Pattern { n, kind: PatternKind::Full }
    }

    pub fn local(n: usize, window: usize) -> Pattern {
        Pattern { n, kind: PatternKind::Local { window } }
    }

    pub fn block_local(n: usize, window: usize) -> Pattern {
        Pattern { n, kind: PatternKind::BlockLocal { window } }
    }

    pub fn strided(n: usize, stride: usize) -> Pattern {
        Pattern { n, kind: PatternKind::Strided { stride } }
    }

    /// Routing pattern from balanced top-w cluster membership over the
    /// given routing vectors (row-major [n, dim]).
    pub fn routing_from_vectors(
        n: usize,
        xs: &[f32],
        km: &SphericalKMeans,
        w: usize,
    ) -> Pattern {
        Pattern { n, kind: PatternKind::Routing { clusters: km.top_w_members(xs, n, w) } }
    }

    /// Routing pattern from explicit cluster membership lists.
    pub fn routing(n: usize, clusters: Vec<Vec<usize>>) -> Pattern {
        Pattern { n, kind: PatternKind::Routing { clusters } }
    }

    /// May query `i` attend to key `j`?  Always causal (j <= i).
    pub fn allowed(&self, i: usize, j: usize) -> bool {
        if j > i || i >= self.n || j >= self.n {
            return false;
        }
        match &self.kind {
            PatternKind::Full => true,
            PatternKind::Local { window } => i - j < *window,
            PatternKind::BlockLocal { window } => i / window - j / window <= 1,
            PatternKind::Strided { stride } => (i - j) % stride == 0,
            PatternKind::Routing { clusters } => clusters
                .iter()
                .any(|members| members.contains(&i) && members.contains(&j)),
        }
    }

    /// The set S_i of key positions query `i` attends to.
    pub fn attend_set(&self, i: usize) -> Vec<usize> {
        (0..=i.min(self.n - 1)).filter(|&j| self.allowed(i, j)).collect()
    }

    /// Total non-zero entries of the attention matrix.
    pub fn nnz(&self) -> usize {
        (0..self.n).map(|i| self.attend_set(i).len()).sum()
    }

    /// ASCII rendering of the attention scheme, Figure-1 style: rows are
    /// outputs, columns inputs; routing membership is drawn with one
    /// letter per cluster.
    pub fn render_ascii(&self) -> String {
        let mut out = String::with_capacity(self.n * (self.n + 1));
        for i in 0..self.n {
            for j in 0..self.n {
                let ch = if !self.allowed(i, j) {
                    if j <= i {
                        '·'
                    } else {
                        ' '
                    }
                } else {
                    match &self.kind {
                        PatternKind::Routing { clusters } => {
                            let c = clusters
                                .iter()
                                .position(|m| m.contains(&i) && m.contains(&j))
                                .unwrap_or(0);
                            (b'A' + (c % 26) as u8) as char
                        }
                        _ => '#',
                    }
                };
                out.push(ch);
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering: `query,key,cluster` rows for every non-zero entry.
    pub fn render_csv(&self) -> String {
        let mut out = String::from("query,key,cluster\n");
        for i in 0..self.n {
            for j in self.attend_set(i) {
                let c = match &self.kind {
                    PatternKind::Routing { clusters } => clusters
                        .iter()
                        .position(|m| m.contains(&i) && m.contains(&j))
                        .map(|c| c.to_string())
                        .unwrap_or_default(),
                    _ => String::new(),
                };
                out.push_str(&format!("{i},{j},{c}\n"));
            }
        }
        out
    }

    /// Sparsity fraction (nnz / full causal nnz).
    pub fn density(&self) -> f64 {
        let full = self.n * (self.n + 1) / 2;
        self.nnz() as f64 / full as f64
    }

    /// Self-check: a valid causal pattern in which every token can attend
    /// at least to itself or is unattended (routing may drop tokens).
    pub fn is_causal(&self) -> bool {
        (0..self.n).all(|i| ((i + 1)..self.n).all(|j| !self.allowed(i, j)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_attends_everything_causal() {
        let p = Pattern::full(8);
        assert_eq!(p.attend_set(5), vec![0, 1, 2, 3, 4, 5]);
        assert!(p.is_causal());
        assert_eq!(p.nnz(), 36);
    }

    #[test]
    fn local_window_bound() {
        let p = Pattern::local(16, 4);
        assert_eq!(p.attend_set(10), vec![7, 8, 9, 10]);
        assert_eq!(p.attend_set(1), vec![0, 1]);
        assert!(p.is_causal());
    }

    #[test]
    fn block_local_two_blocks() {
        let p = Pattern::block_local(16, 4);
        // query 9 (block 2) sees blocks 1 and 2, causally
        assert_eq!(p.attend_set(9), vec![4, 5, 6, 7, 8, 9]);
        // block 0 sees only itself
        assert_eq!(p.attend_set(2), vec![0, 1, 2]);
    }

    #[test]
    fn strided_pattern() {
        let p = Pattern::strided(16, 4);
        assert_eq!(p.attend_set(9), vec![1, 5, 9]);
        assert!(p.is_causal());
    }

    #[test]
    fn routing_same_cluster_only() {
        let p = Pattern::routing(8, vec![vec![0, 2, 5], vec![1, 3, 4, 6, 7]]);
        assert!(p.allowed(5, 2));
        assert!(p.allowed(5, 0));
        assert!(!p.allowed(5, 3)); // different cluster
        assert!(!p.allowed(2, 5)); // causality
        assert!(p.is_causal());
    }

    #[test]
    fn density_ordering_matches_paper() {
        // local(w) and routing(k=sqrt n) are sparse; full is dense
        let n = 64;
        let full = Pattern::full(n);
        let local = Pattern::local(n, 8);
        let clusters: Vec<Vec<usize>> = (0..8).map(|c| (0..8).map(|i| c * 8 + i).collect()).collect();
        let routing = Pattern::routing(n, clusters);
        assert!(local.density() < full.density());
        assert!(routing.density() < full.density());
        assert!((full.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_render_shapes() {
        let p = Pattern::block_local(8, 2);
        let art = p.render_ascii();
        assert_eq!(art.lines().count(), 8);
        assert!(art.lines().all(|l| l.chars().count() == 8));
        // first char of first row is '#': token 0 attends to itself
        assert_eq!(art.lines().next().unwrap().chars().next().unwrap(), '#');
    }

    #[test]
    fn csv_render_contains_entries() {
        let p = Pattern::routing(4, vec![vec![0, 1, 2, 3]]);
        let csv = p.render_csv();
        assert!(csv.contains("3,0,0"));
        assert_eq!(csv.lines().count(), 1 + p.nnz());
    }
}

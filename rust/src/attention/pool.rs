//! Resident worker pool — the serving engine's persistent execution
//! substrate.
//!
//! Every multi-worker path used to pay a `thread::scope` spawn per shard
//! per call (the seam `run_on_workers` documented as "a future persistent
//! worker pool replaces exactly this function").  At decode-loop
//! granularity — thousands of small attention sweeps per second — those
//! per-call spawns are the residual per-step overhead the ROADMAP names.
//! [`WorkerPool`] removes it: worker threads are spawned **lazily** on
//! first use, then stay resident; a call hands its carved `(item, output
//! slice)` pairs to the shared queue, runs the first item on the calling
//! thread, helps drain the queue, and blocks until its batch completes.
//! The per-item math is identical to the scoped path, so pool output is
//! **bit-identical** to both the scoped-spawn path and the inline
//! single-thread path.
//!
//! # Sizing
//!
//! `WorkerPool::new()` (and the shared [`WorkerPool::global`] pool) sizes
//! itself to `std::thread::available_parallelism()`, overridable with the
//! `RTX_WORKERS` environment variable (`RTX_WORKERS=0` is legal: no
//! resident threads, every batch drains on the calling thread — useful
//! for debugging).  Workers are an upper bound, not a reservation:
//! threads spawn on demand, one per queued item, never beyond the
//! configured size.  The calling thread always participates, so a pool
//! of `w` workers executes a batch with up to `w + 1` threads.
//!
//! # Panic containment
//!
//! A closure that panics (or returns `Err`) inside [`WorkerPool::run`]
//! surfaces as an `Err` from `run` — never a hang, never a poisoned
//! pool: every queued job decrements its batch's pending count even when
//! the closure panics, the queue mutex is never held across user code,
//! and worker threads outlive any panic a job throws at them.
//! Subsequent `run` calls on the same pool succeed.  (The scoped and
//! inline execution modes keep their historical semantics: a panic on
//! the calling thread propagates.)
//!
//! [`Execution`] selects the strategy per call — `Inline` (bitwise
//! reference, no threads), `Scoped` (the pre-pool spawn-per-call path,
//! kept as the benchmark baseline), or `Pool` (default: the global
//! resident pool).  `bench_complexity` pins pool ≥ 1.3× scoped on a
//! decode-shaped loop (≥ 4 cores); `rtx serve-bench --pool` prints the
//! same comparison with a row-for-row equality check.
//!
//! # Scope: intra-process only
//!
//! This pool is the **intra-process** half of the fault story — panic
//! containment inside one address space — and is deliberately unchanged
//! by the multi-process layer: [`coordinator`](super::coordinator)
//! splits work across `rtx worker` OS processes (crash isolation,
//! horizontal scale) and each worker's kernel calls still run on this
//! pool's substrate semantics.  Thread-level parallelism and
//! process-level sharding compose, they do not replace each other.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use anyhow::{anyhow, Result};

/// Hard cap on configured workers — a typo'd `RTX_WORKERS=10000` must not
/// try to spawn ten thousand threads.
const MAX_WORKERS: usize = 256;

/// A queued unit of work; lifetime-erased (see the safety note in
/// [`WorkerPool::run`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a mutex, ignoring poisoning: jobs catch panics before unwinding
/// through any pool lock, so a poisoned state carries no torn data — and
/// the pool must stay usable after a worker panic regardless.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// State shared between the pool handle and its worker threads.
struct Shared {
    queue: Mutex<VecDeque<Job>>,
    /// Signaled when a job is queued or shutdown begins.
    available: Condvar,
    /// Written under the `queue` lock so sleeping workers cannot miss it.
    shutdown: AtomicBool,
    /// Jobs executed (by workers or by calling threads helping drain).
    jobs_run: AtomicU64,
    /// Batches dispatched through the queue (multi-item `run` calls).
    batches: AtomicU64,
}

struct SpawnState {
    spawned: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Per-`run` completion tracking: pending job count plus the first
/// failure (panic or `Err`) any job reported.
struct BatchState {
    progress: Mutex<BatchProgress>,
    done: Condvar,
}

struct BatchProgress {
    pending: usize,
    failure: Option<String>,
}

impl BatchState {
    fn new(pending: usize) -> Arc<BatchState> {
        Arc::new(BatchState {
            progress: Mutex::new(BatchProgress { pending, failure: None }),
            done: Condvar::new(),
        })
    }

    fn complete(&self, failure: Option<String>) {
        let mut g = lock(&self.progress);
        if let Some(msg) = failure {
            g.failure.get_or_insert(msg);
        }
        g.pending -= 1;
        if g.pending == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every job in the batch has completed; returns the
    /// first recorded failure, if any.
    fn wait_failure(&self) -> Option<String> {
        let mut g = lock(&self.progress);
        while g.pending > 0 {
            g = self.done.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.failure.take()
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = lock(&shared.queue);
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.available.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(job) = job else { return };
        // job wrappers catch panics themselves; this is the last line of
        // defense keeping the worker resident no matter what a job does
        let _ = catch_unwind(AssertUnwindSafe(job));
        shared.jobs_run.fetch_add(1, Ordering::Relaxed);
    }
}

/// Resolve a worker count from an optional `RTX_WORKERS`-style override,
/// falling back to the machine's parallelism; capped at [`MAX_WORKERS`].
fn worker_count(env_override: Option<&str>, fallback: usize) -> usize {
    env_override
        .and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or(fallback)
        .min(MAX_WORKERS)
}

/// A resident, lazily-spawned thread pool executing carved attention
/// work (see the module docs for sizing and panic semantics).
///
/// ```
/// use routing_transformer::attention::WorkerPool;
/// let pool = WorkerPool::with_workers(2);
/// let mut out = vec![0f32; 6];
/// let work: Vec<(usize, &mut [f32])> = out.chunks_mut(3).enumerate().collect();
/// pool.run(work, |i, slice| {
///     slice.fill(i as f32);
///     Ok(())
/// })
/// .unwrap();
/// assert_eq!(out, [0.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
/// // a failing job surfaces as Err and the pool stays usable
/// let mut out = vec![0f32; 6];
/// let work: Vec<(usize, &mut [f32])> = out.chunks_mut(3).enumerate().collect();
/// assert!(pool.run(work, |_, _| anyhow::bail!("boom")).is_err());
/// ```
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    spawn: Mutex<SpawnState>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("spawned", &self.spawned_workers())
            .field("jobs_run", &self.jobs_run())
            .finish()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl WorkerPool {
    /// A pool sized by [`WorkerPool::default_workers`]
    /// (`available_parallelism`, overridable via `RTX_WORKERS`).
    pub fn new() -> WorkerPool {
        WorkerPool::with_workers(WorkerPool::default_workers())
    }

    /// A pool with an explicit worker-thread bound.  `workers = 0` is
    /// legal: nothing is ever spawned and every batch drains on the
    /// calling thread (still panic-contained).
    pub fn with_workers(workers: usize) -> WorkerPool {
        WorkerPool {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                shutdown: AtomicBool::new(false),
                jobs_run: AtomicU64::new(0),
                batches: AtomicU64::new(0),
            }),
            workers: workers.min(MAX_WORKERS),
            spawn: Mutex::new(SpawnState { spawned: 0, handles: Vec::new() }),
        }
    }

    /// The default sizing rule: `RTX_WORKERS` when set and parseable,
    /// else `std::thread::available_parallelism()` (1 when unknown).
    pub fn default_workers() -> usize {
        let fallback = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        worker_count(std::env::var("RTX_WORKERS").ok().as_deref(), fallback)
    }

    /// The process-wide shared pool — what [`Execution::default`] uses,
    /// so every `attention` call in the process amortizes one set of
    /// resident workers.
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(WorkerPool::new)
    }

    /// Configured worker-thread bound (not necessarily spawned yet).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker threads actually spawned so far (lazy: 0 until the first
    /// multi-item [`WorkerPool::run`]).
    pub fn spawned_workers(&self) -> usize {
        lock(&self.spawn).spawned
    }

    /// Jobs executed through the queue (worker threads plus calling
    /// threads helping drain).
    pub fn jobs_run(&self) -> u64 {
        self.shared.jobs_run.load(Ordering::Relaxed)
    }

    /// Multi-item batches dispatched through the queue.
    pub fn batches(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    fn ensure_workers(&self, needed: usize) {
        let target = needed.min(self.workers);
        if target == 0 {
            return;
        }
        let mut spawn = lock(&self.spawn);
        while spawn.spawned < target {
            let shared = Arc::clone(&self.shared);
            let name = format!("rtx-pool-{}", spawn.spawned);
            match std::thread::Builder::new().name(name).spawn(move || worker_loop(shared)) {
                Ok(handle) => {
                    spawn.handles.push(handle);
                    spawn.spawned += 1;
                }
                // spawn failure is not fatal: the calling thread drains
                // whatever no worker picks up
                Err(_) => break,
            }
        }
    }

    /// Execute `(item, output-slice)` pairs, one closure call per pair:
    /// the calling thread runs the first pair, resident workers (plus the
    /// calling thread, which helps drain) run the rest, and the call
    /// returns only when every pair has finished.  Work distribution
    /// never changes the math — output is bit-identical to running the
    /// pairs inline in order.
    ///
    /// Any closure panic or `Err` surfaces as `Err` (first failure wins);
    /// the pool remains fully usable afterwards.
    pub fn run<T: Send>(
        &self,
        work: Vec<(T, &mut [f32])>,
        f: impl Fn(T, &mut [f32]) -> Result<()> + Sync,
    ) -> Result<()> {
        let m = work.len();
        if m == 0 {
            return Ok(());
        }
        if m == 1 {
            let (item, out) = work.into_iter().next().expect("len checked above");
            return match catch_unwind(AssertUnwindSafe(|| f(item, out))) {
                Ok(r) => r,
                Err(p) => Err(anyhow!("worker panicked: {}", panic_message(p))),
            };
        }
        self.ensure_workers(m - 1);
        let state = BatchState::new(m - 1);
        let f_ref: &(dyn Fn(T, &mut [f32]) -> Result<()> + Sync) = &f;
        let mut work = work.into_iter();
        let (item0, out0) = work.next().expect("len checked above");
        {
            let mut q = lock(&self.shared.queue);
            for (item, out) in work {
                let state = Arc::clone(&state);
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let failure = match catch_unwind(AssertUnwindSafe(|| f_ref(item, out))) {
                        Ok(Ok(())) => None,
                        Ok(Err(e)) => Some(e.to_string()),
                        Err(p) => Some(format!("worker panicked: {}", panic_message(p))),
                    };
                    state.complete(failure);
                });
                // SAFETY: the job borrows `f` and the caller's q/k/v and
                // output buffers, none of which are 'static.  Erasing the
                // lifetime is sound because this function does not return
                // until `state.wait_failure()` has observed pending == 0,
                // and every queued job calls `state.complete` exactly once
                // (the wrapper catches panics first) — so no job can
                // outlive the borrows it captures.  This is the same
                // contract `std::thread::scope` enforces with joins.
                let job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                };
                q.push_back(job);
            }
            self.shared.available.notify_all();
        }
        self.shared.batches.fetch_add(1, Ordering::Relaxed);
        // first item on the calling thread, panic-contained so we always
        // reach the completion wait below (jobs borrow our stack)
        let inline = match catch_unwind(AssertUnwindSafe(|| f_ref(item0, out0))) {
            Ok(r) => r,
            Err(p) => Err(anyhow!("worker panicked: {}", panic_message(p))),
        };
        // help drain: with few (or zero) workers the caller completes the
        // leftovers itself, so a batch can never deadlock on pool size
        loop {
            let job = {
                let mut q = lock(&self.shared.queue);
                q.pop_front()
            };
            let Some(job) = job else { break };
            let _ = catch_unwind(AssertUnwindSafe(job));
            self.shared.jobs_run.fetch_add(1, Ordering::Relaxed);
        }
        let failure = state.wait_failure();
        inline?;
        match failure {
            Some(msg) => Err(anyhow!("worker failed: {msg}")),
            None => Ok(()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // store under the queue lock so a worker between its empty
            // check and its wait cannot miss the shutdown notification
            let _q = lock(&self.shared.queue);
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.available.notify_all();
        let handles = std::mem::take(&mut lock(&self.spawn).handles);
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Per-call execution strategy for the carved attention sweeps
/// ([`super::ShardedPattern::attention_with`],
/// [`super::BatchedAttention::attention_with`]).  All three modes are
/// bit-identical; they differ only in scheduling cost.
#[derive(Clone, Copy, Debug)]
pub enum Execution<'a> {
    /// Everything on the calling thread, in order — the bitwise
    /// reference path (panics propagate).
    Inline,
    /// One scoped thread per work item beyond the first — the pre-pool
    /// spawn-per-call path, kept as the benchmark baseline
    /// (spawned-worker panics surface as `Err`; calling-thread panics
    /// propagate, after the scope joins).
    Scoped,
    /// A resident [`WorkerPool`] (all panics surface as `Err`).
    Pool(&'a WorkerPool),
}

impl Default for Execution<'_> {
    /// The global pool — the serving default.
    fn default() -> Self {
        Execution::Pool(WorkerPool::global())
    }
}

impl Execution<'_> {
    /// Run carved work under this strategy; see [`WorkerPool::run`] for
    /// the shared contract.
    pub fn run<T: Send>(
        self,
        work: Vec<(T, &mut [f32])>,
        f: impl Fn(T, &mut [f32]) -> Result<()> + Sync,
    ) -> Result<()> {
        match self {
            Execution::Inline => {
                for (item, out) in work {
                    f(item, out)?;
                }
                Ok(())
            }
            Execution::Scoped => run_scoped(work, f),
            Execution::Pool(pool) => pool.run(work, f),
        }
    }
}

/// The historical scoped-spawn runner: one worker thread per pair beyond
/// the first (which runs on the calling thread); zero or one pair runs
/// inline with no spawn at all.  Kept verbatim as the baseline the pool
/// is benchmarked against (`bench_complexity`, `rtx serve-bench --pool`).
pub(crate) fn run_scoped<T: Send>(
    work: Vec<(T, &mut [f32])>,
    f: impl Fn(T, &mut [f32]) -> Result<()> + Sync,
) -> Result<()> {
    if work.len() <= 1 {
        for (item, out) in work {
            f(item, out)?;
        }
        return Ok(());
    }
    std::thread::scope(|scope| -> Result<()> {
        let f = &f;
        let mut work = work.into_iter();
        let (item0, out0) = work.next().expect("len checked above");
        let handles: Vec<_> = work.map(|(item, out)| scope.spawn(move || f(item, out))).collect();
        f(item0, out0)?;
        for h in handles {
            h.join().map_err(|_| anyhow!("shard worker panicked"))??;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Carve `out` into `m` equal slices paired with their index.
    fn carve(out: &mut [f32], m: usize) -> Vec<(usize, &mut [f32])> {
        let per = out.len() / m;
        out.chunks_mut(per).take(m).enumerate().collect()
    }

    fn fill(i: usize, out: &mut [f32]) -> Result<()> {
        for (j, x) in out.iter_mut().enumerate() {
            *x = (i * 1000 + j) as f32;
        }
        Ok(())
    }

    fn expected(m: usize, per: usize) -> Vec<f32> {
        (0..m).flat_map(|i| (0..per).map(move |j| (i * 1000 + j) as f32)).collect()
    }

    #[test]
    fn pool_matches_inline_fill() {
        let pool = WorkerPool::with_workers(3);
        for m in [1usize, 2, 3, 5, 9] {
            let per = 4;
            let mut out = vec![0f32; m * per];
            pool.run(carve(&mut out, m), fill).unwrap();
            assert_eq!(out, expected(m, per), "m = {m}");
        }
        assert!(pool.jobs_run() >= 1);
        assert!(pool.batches() >= 4, "multi-item calls go through the queue");
    }

    #[test]
    fn pool_spawns_lazily_and_bounded() {
        let pool = WorkerPool::with_workers(2);
        assert_eq!(pool.spawned_workers(), 0, "no threads before first use");
        let mut out = vec![0f32; 8];
        pool.run(carve(&mut out, 2), fill).unwrap();
        let after_small = pool.spawned_workers();
        assert!((1..=2).contains(&after_small), "one queued item needs at most one worker");
        pool.run(carve(&mut out, 8), fill).unwrap();
        assert!(pool.spawned_workers() <= 2, "never beyond the configured bound");
    }

    #[test]
    fn zero_worker_pool_drains_on_caller() {
        let pool = WorkerPool::with_workers(0);
        let mut out = vec![0f32; 12];
        pool.run(carve(&mut out, 4), fill).unwrap();
        assert_eq!(out, expected(4, 3));
        assert_eq!(pool.spawned_workers(), 0);
        assert_eq!(pool.jobs_run(), 3, "caller drained every queued job");
    }

    #[test]
    fn panics_surface_as_err_and_pool_survives() {
        let pool = WorkerPool::with_workers(2);
        for panic_at in 0..4usize {
            let mut out = vec![0f32; 16];
            let err = pool
                .run(carve(&mut out, 4), |i, out| {
                    if i == panic_at {
                        panic!("injected panic at {i}");
                    }
                    fill(i, out)
                })
                .unwrap_err();
            assert!(err.to_string().contains("panicked"), "got: {err:#}");
            // the same pool keeps working after every induced panic
            let mut ok = vec![0f32; 16];
            pool.run(carve(&mut ok, 4), fill).unwrap();
            assert_eq!(ok, expected(4, 4));
        }
        // single-item calls are panic-contained too
        let mut one = vec![0f32; 2];
        let err = pool
            .run(carve(&mut one, 1), |_, _| -> Result<()> { panic!("solo") })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"));
    }

    #[test]
    fn errs_propagate_first_failure() {
        let pool = WorkerPool::with_workers(2);
        let mut out = vec![0f32; 8];
        let err = pool
            .run(carve(&mut out, 4), |i, out| {
                if i == 2 {
                    anyhow::bail!("item {i} rejected");
                }
                fill(i, out)
            })
            .unwrap_err();
        assert!(err.to_string().contains("rejected"), "got: {err:#}");
        pool.run(carve(&mut out, 4), fill).unwrap();
    }

    #[test]
    fn concurrent_runs_share_one_pool() {
        let pool = WorkerPool::with_workers(3);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let pool = &pool;
                scope.spawn(move || {
                    for m in [2usize, 5] {
                        let per = 6;
                        let mut out = vec![0f32; m * per];
                        pool.run(carve(&mut out, m), |i, o| fill(i + t, o)).unwrap();
                        let want: Vec<f32> = (0..m)
                            .flat_map(|i| (0..per).map(move |j| ((i + t) * 1000 + j) as f32))
                            .collect();
                        assert_eq!(out, want);
                    }
                });
            }
        });
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = WorkerPool::with_workers(2);
        let mut out = vec![0f32; 6];
        pool.run(carve(&mut out, 3), fill).unwrap();
        drop(pool); // must not hang or leak a wedged thread
    }

    #[test]
    fn worker_count_override_rules() {
        assert_eq!(worker_count(Some("6"), 2), 6);
        assert_eq!(worker_count(Some(" 8 "), 2), 8);
        assert_eq!(worker_count(Some("0"), 2), 0, "0 disables resident workers");
        assert_eq!(worker_count(Some("garbage"), 3), 3, "unparseable falls back");
        assert_eq!(worker_count(None, 5), 5);
        assert_eq!(worker_count(Some("99999"), 2), MAX_WORKERS, "capped");
    }

    #[test]
    fn execution_modes_agree_bitwise() {
        let pool = WorkerPool::with_workers(2);
        let m = 5;
        let per = 7;
        let mut inline = vec![0f32; m * per];
        Execution::Inline.run(carve(&mut inline, m), fill).unwrap();
        for exec in [Execution::Scoped, Execution::Pool(&pool), Execution::default()] {
            let mut out = vec![0f32; m * per];
            exec.run(carve(&mut out, m), fill).unwrap();
            assert_eq!(out, inline, "{exec:?} must match the inline reference");
        }
    }

    /// Timing guard (CI runs ignored tests in release): the pool must
    /// amortize the scoped path's per-call spawns on a decode-shaped
    /// loop of many small batches.  Gated on ≥ 4 cores — a 2-core host
    /// leaves no headroom for a reliable pin.
    #[test]
    #[ignore = "timing-sensitive: run with --release -- --include-ignored"]
    fn pool_amortizes_spawns_over_scoped() {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(0);
        let pool = WorkerPool::global();
        let m = 4usize;
        let per = 256usize;
        let steps = 400usize;
        let mut out = vec![0f32; m * per];
        // warm both paths (spawns the pool's workers once)
        pool.run(carve(&mut out, m), fill).unwrap();
        run_scoped(carve(&mut out, m), fill).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            pool.run(carve(&mut out, m), fill).unwrap();
        }
        let pool_dt = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        for _ in 0..steps {
            run_scoped(carve(&mut out, m), fill).unwrap();
        }
        let scoped_dt = t1.elapsed().as_secs_f64();
        let speedup = scoped_dt / pool_dt.max(1e-12);
        println!(
            "pool vs scoped over {steps} x {m}-way batches: {:.3} ms vs {:.3} ms ({speedup:.2}x)",
            pool_dt * 1e3,
            scoped_dt * 1e3
        );
        if cores >= 4 {
            assert!(
                speedup >= 1.3,
                "resident pool must be >= 1.3x over spawn-per-call (got {speedup:.2}x)"
            );
        } else {
            println!("({cores} cores: >= 1.3x pool pin skipped, needs >= 4 cores)");
        }
    }
}

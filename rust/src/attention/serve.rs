//! Continuous-batching serve layer — the asynchronous front-end over the
//! decode engine.
//!
//! `rtx serve-bench` drives a *fixed* set of sequences in lock-step: every
//! sequence is present for every step, which no deployment resembles.  This
//! module adds the missing layer: requests **arrive** over virtual time
//! (seeded exponential interarrivals, Zipf-skewed content popularity, so
//! the arrival process is exactly reproducible from one seed), are
//! **admitted** against per-request deadlines, **join** the decode batch
//! mid-flight, **retire** when their decode budget is spent, and have their
//! routed-pattern cache entries **GC'd** via [`EpochCache::evict_slot`].  A
//! request that cannot meet its deadline is *rejected* at submit or *shed*
//! from the wait queue — never silently dropped: every submitted request
//! ends in exactly one [`RequestOutcome`].
//!
//! The pieces:
//!
//! - [`ArrivalConfig`] / [`RequestQueue`] — the deterministic open-loop
//!   arrival process ([`ServeRequest`]s sorted by arrival step).
//! - [`Scheduler`] — slot lifecycle and admission control.  Purely
//!   virtual-time and deterministic, so the model-based property test in
//!   `tests/stateful.rs` can mirror it exactly.  Each decode step is a
//!   [`Scheduler::begin_step`] (shed newly-infeasible waiters, admit into
//!   free slots FIFO, snapshot the batch) followed by a
//!   [`Scheduler::finish_step`] (account one decode step, retire finished
//!   requests, GC their cache slots).
//! - [`run_serve`] — the actual serving loop: packs the live batch's
//!   q/k/v each step, runs every (layer, head) through
//!   [`BatchedAttention`] with the session's routed patterns, records
//!   per-step wall-clock into a
//!   [`StreamingHistogram`](crate::util::timing::StreamingHistogram), and
//!   returns a [`ServeSummary`] (p50/p99 step latency, rows/sec, shed and
//!   GC counters next to the cache/epoch/regen counters the lock-step
//!   bench already reports).
//!
//! Scheduling is measured in **virtual steps** (one decode step per tick)
//! so batch membership, deadlines, and outcomes are seed-reproducible;
//! only the recorded latencies are wall-clock.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::compiled::MemoryBudget;
use super::coordinator::{
    fold_digest, CoordStats, Coordinator, CoordinatorConfig, ProcessTransport, Transport,
    DIGEST_SEED,
};
use super::decode::{
    routed_family_spec, BatchedAttention, EpochCache, EpochCacheStats, MemberCache, RegenStats,
    RouteSlot, RoutingSession, SpecFamily,
};
use super::engine::CacheStats;
use super::pool::{Execution, WorkerPool};
use super::spec::{AttentionSpec, ChunkedPattern};
use crate::util::rng::{Rng, Zipf};
use crate::util::timing::StreamingHistogram;

/// Version stamped into every serve-layer `--json` line (`"schema"`).
/// PR 5's `serve-bench` schema carried no version field and is
/// retroactively schema 1; adding `p50_step_us`/`p99_step_us` and the
/// `serve` bench made it 2; the memory-bounded serving fields
/// (`max_pattern_bytes`, `band_rows`, `peak_pattern_bytes`,
/// `pattern_bytes_resident`, `pattern_bytes_evicted`, `band_compiles`,
/// `gc_bytes_reclaimed`) made it 3; the exactness contract made it 4
/// (`serve` lines document the `backend` field and add `exactness`;
/// `serve-bench` lines add per-backend `exactness` entries and emit
/// `sequential_rows_per_sec` only when more than one backend runs, so
/// single-backend sweeps skip the redundant per-step oracle); the
/// multi-process coordinator made it 5 (`serve` lines add `worker_procs`,
/// the `output_digest` hex string — the FNV-1a fold of every attention
/// output's f32 bit patterns, the cross-process bit-identity anchor —
/// and, when `worker_procs > 0`, the `coord` grant-ledger object); the
/// content-based spec families made it 6 (`serve` lines add
/// `spec_family` — `"routing"` | `"expert-choice"` | `"threshold"` —
/// plus the load-balance observables `max_cluster_nnz` and
/// `max_shard_nnz`/`min_shard_nnz`; the shard-nnz pair is reported by
/// the in-process batched path and 0 in banded/coordinated modes, whose
/// execution does not sweep through [`BatchedAttention`]).
pub const JSON_SCHEMA_VERSION: u64 = 6;

// ---------------------------------------------------------------- arrivals

/// One request in the open-loop workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeRequest {
    /// Unique request id (generation order).
    pub id: u64,
    /// Content id in `[0, contents)` — Zipf-skewed, so popular contents
    /// recur and exercise pattern/centroid reuse.
    pub content: usize,
    /// Virtual step the request becomes visible to the scheduler.
    pub arrival: u64,
    /// Decode steps of work the request needs (>= 1).
    pub work: u64,
    /// Absolute virtual step by which the request must have completed.  A
    /// request admitted at step `t` completes at `t + work`; it is
    /// feasible at time `now` iff `now + work <= deadline`.
    pub deadline: u64,
}

/// Parameters of the deterministic arrival process.
#[derive(Debug, Clone)]
pub struct ArrivalConfig {
    /// Total requests to generate.
    pub requests: usize,
    /// Mean arrivals per virtual step (the Poisson rate λ); interarrival
    /// gaps are `Rng::exponential(rate)`.
    pub rate: f64,
    /// Size of the content universe (Zipf support).
    pub contents: usize,
    /// Zipf skew exponent `s` (1.0–1.5 is text-like).
    pub zipf_s: f64,
    /// Inclusive decode-work range `[work_min, work_max]`, both >= 1.
    pub work: (u64, u64),
    /// Inclusive deadline-slack range: `deadline = arrival + work + slack`
    /// with `slack` uniform in `[slack_min, slack_max]`.  Queueing delay
    /// eats slack, so tight slack under load produces sheds.
    pub slack: (u64, u64),
    /// Seed for the whole process (contents, gaps, work, slack).
    pub seed: u64,
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        ArrivalConfig {
            requests: 64,
            rate: 1.0,
            contents: 64,
            zipf_s: 1.1,
            work: (4, 16),
            slack: (8, 64),
            seed: 0,
        }
    }
}

/// Arrival-ordered request stream the serve loop drains.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    pending: VecDeque<ServeRequest>,
}

impl RequestQueue {
    /// Generate the full workload up front from `cfg` — exactly
    /// reproducible from `cfg.seed`.  Content ids are drawn first (one
    /// [`Zipf::sample_n`] batch), then per-request gap/work/slack.
    pub fn generate(cfg: &ArrivalConfig) -> Result<RequestQueue> {
        if cfg.contents == 0 {
            bail!("arrival process requires a non-empty content universe");
        }
        if !(cfg.rate > 0.0 && cfg.rate.is_finite()) {
            bail!("arrival process requires a positive finite rate (got {})", cfg.rate);
        }
        if cfg.work.0 == 0 || cfg.work.1 < cfg.work.0 {
            bail!("work range must satisfy 1 <= work_min <= work_max (got {:?})", cfg.work);
        }
        if cfg.slack.1 < cfg.slack.0 {
            bail!("slack range must satisfy slack_min <= slack_max (got {:?})", cfg.slack);
        }
        let mut rng = Rng::new(cfg.seed);
        let zipf = Zipf::new(cfg.contents, cfg.zipf_s);
        let contents = zipf.sample_n(&mut rng, cfg.requests);
        let mut pending = VecDeque::with_capacity(cfg.requests);
        let mut t = 0.0f64;
        for (i, &content) in contents.iter().enumerate() {
            t += rng.exponential(cfg.rate);
            let arrival = t.floor() as u64;
            let work = cfg.work.0 + rng.below((cfg.work.1 - cfg.work.0 + 1) as usize) as u64;
            let slack = cfg.slack.0 + rng.below((cfg.slack.1 - cfg.slack.0 + 1) as usize) as u64;
            pending.push_back(ServeRequest {
                id: i as u64,
                content,
                arrival,
                work,
                deadline: arrival + work + slack,
            });
        }
        Ok(RequestQueue { pending })
    }

    /// Wrap an explicit request list (must be sorted by arrival).
    pub fn from_requests(requests: Vec<ServeRequest>) -> Result<RequestQueue> {
        if requests.windows(2).any(|w| w[0].arrival > w[1].arrival) {
            bail!("request queue must be sorted by arrival step");
        }
        Ok(RequestQueue { pending: requests.into() })
    }

    /// Requests still waiting to arrive.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when the stream is drained.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Arrival step of the next request, if any — the fast-forward target
    /// when the scheduler is idle.
    pub fn peek_arrival(&self) -> Option<u64> {
        self.pending.front().map(|r| r.arrival)
    }

    /// Pop every request with `arrival <= now` (arrival order).
    pub fn pop_arrived(&mut self, now: u64) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while self.pending.front().is_some_and(|r| r.arrival <= now) {
            out.push(self.pending.pop_front().expect("front checked above"));
        }
        out
    }
}

// --------------------------------------------------------------- scheduler

/// Verdict returned by [`Scheduler::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    /// Accepted into the wait queue (admission into a slot happens at the
    /// next [`Scheduler::begin_step`], FIFO).
    Queued,
    /// Refused at the door: even starting immediately the request could
    /// not finish by its deadline (`now + work > deadline`).
    Rejected,
}

/// Terminal state of a submitted request — exactly one per request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeKind {
    /// Ran its full decode budget and retired.
    Completed,
    /// Refused at submit (could never meet its deadline).
    Rejected,
    /// Dropped from the wait queue after queueing delay made the deadline
    /// unreachable.
    Shed,
}

/// Ledger entry: request `id` reached terminal state `kind` at virtual
/// step `at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestOutcome {
    /// Request id.
    pub id: u64,
    /// Which terminal state.
    pub kind: OutcomeKind,
    /// Virtual step of the transition (completions land at
    /// `admit_step + work`).
    pub at: u64,
}

/// One live request's view in a step's batch snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchEntry {
    /// Request id.
    pub id: u64,
    /// Slot index in `[0, capacity)` — doubles as the [`RouteSlot::seq`]
    /// key for the request's routed cache entries.
    pub slot: usize,
    /// The request's content id (drives its q/k/v and routing vectors).
    pub content: usize,
    /// Decode steps still owed *including* the step being planned.
    pub remaining: u64,
    /// The request's absolute deadline step.
    pub deadline: u64,
}

/// What [`Scheduler::begin_step`] decided for one step.
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// The virtual step this plan covers.
    pub step: u64,
    /// Requests admitted from the wait queue into slots this step (they
    /// are also in `batch`).
    pub admitted: Vec<BatchEntry>,
    /// Ids shed from the wait queue this step (deadline now unreachable).
    pub shed: Vec<u64>,
    /// The decode batch, ascending by slot.  Empty means an idle step.
    pub batch: Vec<BatchEntry>,
}

/// One retirement from [`Scheduler::finish_step`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Retired {
    /// Request id.
    pub id: u64,
    /// The slot freed (its routed cache entries were just GC'd).
    pub slot: usize,
    /// Completion step (`admit_step + work`).
    pub completed_at: u64,
}

/// What [`Scheduler::finish_step`] did at the end of one step.
#[derive(Debug, Clone)]
pub struct StepFinish {
    /// The virtual step just finished.
    pub step: u64,
    /// Requests whose decode budget reached zero this step.
    pub retired: Vec<Retired>,
    /// [`EpochCache::evict_slot`] evictions the retirements fired (only
    /// slots with a live routed compile count).
    pub gc_evictions: u64,
    /// Pattern heap bytes those evictions released — the per-retirement
    /// bytes-reclaimed figure the serve-bench GC report prints.
    pub gc_bytes: u64,
}

/// Aggregate scheduler counters — the request-lifecycle side of the serve
/// summary.  Invariant once the loop drains:
/// `submitted == completed + rejected + shed`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests offered via [`Scheduler::submit`].
    pub submitted: u64,
    /// Refused at submit (deadline unreachable even if started at once).
    pub rejected: u64,
    /// Accepted into the wait queue at submit.
    pub queued: u64,
    /// Granted a slot (each at most once).
    pub admitted: u64,
    /// Ran their full decode budget.
    pub completed: u64,
    /// Dropped from the wait queue on deadline infeasibility.
    pub shed: u64,
    /// begin/finish step cycles executed.
    pub steps: u64,
    /// Steps whose batch was empty.
    pub idle_steps: u64,
    /// Virtual steps skipped via [`Scheduler::fast_forward`].
    pub fast_forwarded: u64,
    /// Largest batch ever formed.
    pub peak_active: usize,
    /// Cache evictions fired by retirement GC.
    pub gc_evictions: u64,
}

impl ServeStats {
    /// Requests that reached a terminal state.
    pub fn resolved(&self) -> u64 {
        self.completed + self.rejected + self.shed
    }

    /// Completed fraction of submitted (1.0 when nothing was submitted).
    pub fn completion_rate(&self) -> f64 {
        if self.submitted == 0 {
            1.0
        } else {
            self.completed as f64 / self.submitted as f64
        }
    }
}

#[derive(Debug, Clone)]
struct Active {
    id: u64,
    content: usize,
    remaining: u64,
    deadline: u64,
}

/// Slot-lifecycle state machine: admit → decode steps → retire → GC.
///
/// Time is virtual (one [`Scheduler::begin_step`]/[`Scheduler::finish_step`]
/// cycle per step), so every decision — admission order, shed timing,
/// batch membership, completion step — is a pure function of the submitted
/// requests.  The model-based property in `tests/stateful.rs` replays the
/// same sequences against a naive reference model and requires exact
/// agreement, including the [`EpochCache`] eviction counters the
/// retirement GC drives.
#[derive(Debug)]
pub struct Scheduler {
    capacity: usize,
    layers: usize,
    heads: usize,
    now: u64,
    in_step: bool,
    waiting: VecDeque<ServeRequest>,
    active: BTreeMap<usize, Active>,
    free: BTreeSet<usize>,
    stats: ServeStats,
    outcomes: Vec<RequestOutcome>,
}

impl Scheduler {
    /// A scheduler with `capacity` concurrent slots serving a
    /// `layers` x `heads` model (the GC sweep on retirement evicts every
    /// (layer, head) routed entry of the freed slot).
    pub fn new(capacity: usize, layers: usize, heads: usize) -> Result<Scheduler> {
        if capacity == 0 {
            bail!("scheduler requires capacity >= 1 slots");
        }
        if layers == 0 || heads == 0 {
            bail!("scheduler requires layers >= 1 and heads >= 1 (got {layers} x {heads})");
        }
        Ok(Scheduler {
            capacity,
            layers,
            heads,
            now: 0,
            in_step: false,
            waiting: VecDeque::new(),
            active: BTreeMap::new(),
            free: (0..capacity).collect(),
            stats: ServeStats::default(),
            outcomes: Vec::new(),
        })
    }

    /// Concurrent-slot capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current virtual step.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Live (slot-holding) request count.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Requests queued for a slot.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// True when no request is active or waiting — the only state
    /// [`Scheduler::fast_forward`] may skip time from.
    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    /// Aggregate counters so far.
    pub fn stats(&self) -> ServeStats {
        self.stats
    }

    /// The terminal-state ledger (append-only, one entry per resolved
    /// request).
    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    /// Offer a request.  Admission control runs at the door: a request
    /// whose deadline is unreachable even if it started immediately
    /// (`now + work > deadline`, and any `work == 0` degenerate) is
    /// rejected — counted and ledgered, never silently dropped.  Feasible
    /// requests join the FIFO wait queue; slots are granted at the next
    /// [`Scheduler::begin_step`].
    ///
    /// Panics if called between `begin_step` and `finish_step`.
    pub fn submit(&mut self, req: ServeRequest) -> Submission {
        assert!(!self.in_step, "submit requests between steps, not mid-step");
        self.stats.submitted += 1;
        if req.work == 0 || self.now + req.work > req.deadline {
            self.stats.rejected += 1;
            self.outcomes.push(RequestOutcome {
                id: req.id,
                kind: OutcomeKind::Rejected,
                at: self.now,
            });
            return Submission::Rejected;
        }
        self.stats.queued += 1;
        self.waiting.push_back(req);
        Submission::Queued
    }

    /// Open one decode step: shed every waiter whose deadline became
    /// unreachable while it queued, admit waiters FIFO into free slots,
    /// and snapshot the batch (slot-ascending).  Call exactly once before
    /// the step's attention work; close with [`Scheduler::finish_step`].
    pub fn begin_step(&mut self) -> StepPlan {
        assert!(!self.in_step, "begin_step called twice without finish_step");
        self.in_step = true;
        self.stats.steps += 1;
        let now = self.now;

        // shed the whole queue's infeasible tail first, so a blocked-but-
        // doomed waiter can never shadow a feasible one behind it
        let mut shed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.waiting.len());
        for req in self.waiting.drain(..) {
            if now + req.work > req.deadline {
                self.stats.shed += 1;
                self.outcomes.push(RequestOutcome {
                    id: req.id,
                    kind: OutcomeKind::Shed,
                    at: now,
                });
                shed.push(req.id);
            } else {
                kept.push_back(req);
            }
        }
        self.waiting = kept;

        let mut admitted = Vec::new();
        while !self.waiting.is_empty() {
            let Some(&slot) = self.free.iter().next() else { break };
            let req = self.waiting.pop_front().expect("non-empty checked above");
            self.free.remove(&slot);
            self.active.insert(
                slot,
                Active {
                    id: req.id,
                    content: req.content,
                    remaining: req.work,
                    deadline: req.deadline,
                },
            );
            self.stats.admitted += 1;
            admitted.push(BatchEntry {
                id: req.id,
                slot,
                content: req.content,
                remaining: req.work,
                deadline: req.deadline,
            });
        }

        let batch: Vec<BatchEntry> = self
            .active
            .iter()
            .map(|(&slot, a)| BatchEntry {
                id: a.id,
                slot,
                content: a.content,
                remaining: a.remaining,
                deadline: a.deadline,
            })
            .collect();
        self.stats.peak_active = self.stats.peak_active.max(batch.len());
        if batch.is_empty() {
            self.stats.idle_steps += 1;
        }
        StepPlan { step: now, admitted, shed, batch }
    }

    /// Close the step opened by [`Scheduler::begin_step`]: every active
    /// request is charged one decode step; those whose budget reached zero
    /// retire — their slot returns to the free list and **every**
    /// (layer, head) routed entry for that slot is dropped via
    /// [`EpochCache::evict_slot`] (entries actually present count as
    /// evictions; heads that never compiled a routed pattern are no-ops).
    /// Advances virtual time by one step.
    pub fn finish_step(&mut self, cache: &mut EpochCache) -> StepFinish {
        assert!(self.in_step, "finish_step without a begin_step");
        self.in_step = false;
        let now = self.now;
        let mut retired = Vec::new();
        let mut gc_evictions = 0u64;
        let mut gc_bytes = 0u64;
        let slots: Vec<usize> = self.active.keys().copied().collect();
        for slot in slots {
            let a = self.active.get_mut(&slot).expect("key just listed");
            a.remaining -= 1;
            if a.remaining == 0 {
                let a = self.active.remove(&slot).expect("present");
                self.free.insert(slot);
                self.stats.completed += 1;
                self.outcomes.push(RequestOutcome {
                    id: a.id,
                    kind: OutcomeKind::Completed,
                    at: now + 1,
                });
                for layer in 0..self.layers {
                    for head in 0..self.heads {
                        if let Some(bytes) = cache.evict_slot(RouteSlot { layer, head, seq: slot })
                        {
                            gc_evictions += 1;
                            gc_bytes += bytes as u64;
                        }
                    }
                }
                retired.push(Retired { id: a.id, slot, completed_at: now + 1 });
            }
        }
        self.stats.gc_evictions += gc_evictions;
        self.now = now + 1;
        StepFinish { step: now, retired, gc_evictions, gc_bytes }
    }

    /// Skip virtual time forward to `to` — only legal while idle (no
    /// active or waiting request), i.e. the loop is waiting for the next
    /// arrival.  A `to` at or before `now` is a no-op.
    pub fn fast_forward(&mut self, to: u64) {
        assert!(!self.in_step, "fast_forward mid-step");
        assert!(self.is_idle(), "fast_forward requires an idle scheduler");
        if to > self.now {
            self.stats.fast_forwarded += to - self.now;
            self.now = to;
        }
    }
}

// -------------------------------------------------------------- serve loop

/// Everything [`run_serve`] needs: model shape, head plan parameters, and
/// the arrival process.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Sequence length of every request.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
    /// Transformer layers.
    pub layers: usize,
    /// Heads per layer (even heads: static local window; odd heads:
    /// local ∪ routed, the Sec. 4.2 plan `serve-bench` uses).
    pub heads: usize,
    /// Local attention window.
    pub window: usize,
    /// Routing clusters per (layer, head).
    pub clusters: usize,
    /// Top-w membership per cluster (doubles as the per-cluster capacity
    /// when `spec_family` is [`SpecFamily::ExpertChoice`]).
    pub top_w: usize,
    /// Which content-based family the odd heads' routed component uses:
    /// classic overlapping routing (default), capacity-bounded
    /// expert-choice routing, or the score-threshold attend set.
    pub spec_family: SpecFamily,
    /// Worker chunks per batched sweep (also the pool's parallelism cap).
    pub workers: usize,
    /// Concurrent request slots.
    pub capacity: usize,
    /// Re-fit the routing k-means every this many virtual steps.
    pub route_every: u64,
    /// Byte cap on resident pattern memory, 0 = unbounded.  Static
    /// compiles, routed compiles (or bands), and member-list snapshots
    /// all charge one shared [`MemoryBudget`]; over-budget inserts
    /// LRU-spill unpinned, non-step-touched entries.
    pub max_pattern_bytes: usize,
    /// Query rows per compiled band, 0 = monolithic compiles.  When set,
    /// attention streams band-by-band through [`ChunkedPattern`] so only
    /// O(band) pattern bytes are resident per sequence at a time — the
    /// long-context serving mode.
    pub band_rows: usize,
    /// The workload.
    pub arrivals: ArrivalConfig,
    /// Seed for per-content q/k/v and routing vectors and the k-means.
    pub seed: u64,
    /// OS worker subprocesses to split each attention call across
    /// (`rtx serve --workers N`).  0 = in-process execution; > 0 routes
    /// every sweep through the multi-process
    /// [`Coordinator`](super::coordinator::Coordinator), whose output is
    /// bit-identical to the in-process run (same `output_digest`).
    /// Requires monolithic mode (`band_rows == 0`, unbounded budget).
    pub worker_procs: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            n: 128,
            d: 32,
            layers: 2,
            heads: 4,
            window: 16,
            clusters: 8,
            top_w: 16,
            spec_family: SpecFamily::Routing,
            workers: 4,
            capacity: 4,
            route_every: 4,
            max_pattern_bytes: 0,
            band_rows: 0,
            arrivals: ArrivalConfig::default(),
            seed: 0,
            worker_procs: 0,
        }
    }
}

/// Everything one serve run produced — the `--json` line and the human
/// summary both render from this.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// Request-lifecycle counters.
    pub stats: ServeStats,
    /// Terminal-state ledger (every submitted request exactly once).
    pub outcomes: Vec<RequestOutcome>,
    /// Wall-clock per non-idle step, microseconds (p50/p99 source).
    pub step_us: StreamingHistogram,
    /// Attention output rows produced (`batch × n` summed over every
    /// (layer, head) sweep of every step).
    pub batched_rows: u64,
    /// Sparse MACs executed (2·nnz·d summed over every sweep).
    pub macs: u64,
    /// Wall-clock seconds spent in attention steps (histogram sum).
    pub elapsed_sec: f64,
    /// Pattern-compile counters (static + routed).
    pub cache: CacheStats,
    /// Assignment-epoch hit/miss counters.
    pub epoch: EpochCacheStats,
    /// Membership regeneration counters (all member caches folded).
    pub regen: RegenStats,
    /// Patterns still live after the last retirement GC (the pinned
    /// static pattern plus any slots active at drain — 1 when fully
    /// drained).
    pub live_patterns_after_gc: usize,
    /// Final virtual step (arrival span + drain tail).
    pub virtual_steps: u64,
    /// High-water mark of the shared byte meter over the run — the
    /// headline number the long-context mode exists to bound.
    pub peak_pattern_bytes: u64,
    /// Bytes still metered resident at drain (pinned statics, resident
    /// bands, member snapshots of slots never retired).
    pub pattern_bytes_resident: u64,
    /// Total bytes released over the run (budget spills, stale-epoch
    /// drops, retirement GC, member-list shrinkage).
    pub pattern_bytes_evicted: u64,
    /// Bands compiled by the banded path, recompiles after spills
    /// included (0 in monolithic mode).
    pub band_compiles: u64,
    /// Heap bytes released by retirement GC specifically.
    pub gc_bytes_reclaimed: u64,
    /// FNV-1a 64 fold of every attention output's `f32` bit patterns, in
    /// sweep order — the bit-identity anchor: an in-process run and a
    /// coordinated multi-process run of the same options must report the
    /// same digest.
    pub output_digest: u64,
    /// OS worker subprocesses the run executed on (0 = in-process).
    pub worker_procs: usize,
    /// The coordinator's grant/rejection ledger (multi-process runs only).
    pub coord: Option<CoordStats>,
    /// The content-based family the odd heads routed through.
    pub spec_family: SpecFamily,
    /// Largest per-cluster nnz observed across every routed compile of
    /// the run — the load-balance observable expert-choice exists to
    /// bound (≤ capacity·(capacity+1)/2 by construction there).  0 in
    /// banded mode, where routed compiles stream band-by-band.
    pub max_cluster_nnz: usize,
    /// Largest per-worker nnz of any batched sweep (in-process monolithic
    /// runs only; 0 in banded and coordinated modes).
    pub max_shard_nnz: usize,
    /// Smallest per-worker nnz of any batched sweep (companion bound;
    /// `max - min` is the shard imbalance the nnz-balanced packer
    /// minimizes).  0 in banded and coordinated modes.
    pub min_shard_nnz: usize,
}

impl ServeSummary {
    /// Attention rows per wall-clock second (0.0 when nothing ran).
    pub fn rows_per_sec(&self) -> f64 {
        if self.elapsed_sec > 0.0 {
            self.batched_rows as f64 / self.elapsed_sec
        } else {
            0.0
        }
    }

    /// Sparse MACs per wall-clock second (0.0 when nothing ran).
    pub fn macs_per_sec(&self) -> f64 {
        if self.elapsed_sec > 0.0 {
            self.macs as f64 / self.elapsed_sec
        } else {
            0.0
        }
    }
}

/// Per-slot request payload: q/k/v plus the routing vectors, all derived
/// from the request's *content* id, so popular contents replay identical
/// vectors (what makes Zipf skew matter to the caches).
struct SlotData {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    xs: Vec<f32>,
}

impl SlotData {
    fn generate(seed: u64, content: usize, n: usize, d: usize) -> SlotData {
        let mut rng = Rng::new(seed ^ (content as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        let mut mk = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal() as f32).collect() };
        SlotData { q: mk(n * d), k: mk(n * d), v: mk(n * d), xs: mk(n * d) }
    }
}

/// Run the continuous-batching serve loop to completion: generate the
/// workload, admit/decode/retire until the arrival stream drains and the
/// last slot frees, and aggregate the [`ServeSummary`].
///
/// Per non-idle step the loop re-fits the routing k-means on the live
/// batch's routing vectors (every `route_every` steps), packs the batch's
/// q/k/v into `[B, n, d]`, and sweeps every (layer, head): even heads
/// share the pinned static local pattern, odd heads use each slot's
/// routed pattern served through the [`EpochCache`] (assignment-epoch
/// keyed, dirty-cluster-only regeneration).  Batch membership changes
/// between steps are the point: the per-step wall-clock distribution —
/// not just its mean — is the serving cost, which is why the summary
/// reports p50/p99.
///
/// With `worker_procs > 0` the run executes through the multi-process
/// [`Coordinator`] instead — real `rtx worker` subprocesses spawned from
/// the current executable — and must produce the same `output_digest`
/// and cache/epoch/regen counters as the in-process run (pinned by
/// `tests/coordinator.rs` and the CI smoke).
pub fn run_serve(opts: &ServeOptions, backend: &dyn Backend) -> Result<ServeSummary> {
    if opts.worker_procs == 0 {
        return run_serve_in_process(opts, backend);
    }
    let transport = ProcessTransport::current_exe()?;
    let mut coord = Coordinator::new(coordinator_config(opts, backend), transport)?;
    for _ in 0..opts.worker_procs {
        coord.spawn_worker()?;
    }
    let result = run_serve_coordinated(opts, &mut coord);
    coord.shutdown();
    result
}

fn coordinator_config(opts: &ServeOptions, backend: &dyn Backend) -> CoordinatorConfig {
    CoordinatorConfig {
        n: opts.n,
        d: opts.d,
        layers: opts.layers,
        heads: opts.heads,
        window: opts.window,
        clusters: opts.clusters,
        top_w: opts.top_w,
        spec_family: opts.spec_family,
        capacity: opts.capacity,
        seed: opts.seed,
        backend: backend.name().to_string(),
        ..CoordinatorConfig::default()
    }
}

fn run_serve_in_process(opts: &ServeOptions, backend: &dyn Backend) -> Result<ServeSummary> {
    if opts.n == 0 || opts.d == 0 {
        bail!("serve requires n >= 1 and d >= 1 (got n = {}, d = {})", opts.n, opts.d);
    }
    if opts.window == 0 || opts.clusters == 0 || opts.top_w == 0 {
        bail!(
            "serve requires window, clusters, top_w >= 1 (got {}, {}, {})",
            opts.window,
            opts.clusters,
            opts.top_w
        );
    }
    if opts.workers == 0 {
        bail!("serve requires workers >= 1");
    }
    if opts.route_every == 0 {
        bail!("serve requires route_every >= 1");
    }
    let local = AttentionSpec::local(opts.window)?;
    let mut session =
        RoutingSession::new(opts.layers, opts.heads, opts.clusters, opts.d, 0.5, opts.seed)?;
    let budget = if opts.max_pattern_bytes > 0 {
        MemoryBudget::bytes(opts.max_pattern_bytes)
    } else {
        MemoryBudget::unbounded()
    };
    let banded = opts.band_rows > 0;
    let mut cache = EpochCache::with_budget(budget.clone());
    // monolithic mode pins one whole-sequence static compile; banded mode
    // serves the same spec from an LRU-windowed band set instead, so no
    // O(n) pattern is ever materialized
    let static_pattern = if banded { None } else { Some(cache.get_static(&local, opts.n)) };
    let mut static_chunked = if banded {
        Some(ChunkedPattern::new(local.clone(), opts.n, opts.band_rows, budget.clone()))
    } else {
        None
    };
    let mut queue = RequestQueue::generate(&opts.arrivals)?;
    let mut sched = Scheduler::new(opts.capacity, opts.layers, opts.heads)?;
    let pool = WorkerPool::global();

    let mut slot_data: Vec<Option<SlotData>> = (0..opts.capacity).map(|_| None).collect();
    let mut members: Vec<MemberCache> = (0..opts.layers * opts.heads * opts.capacity)
        .map(|_| MemberCache::with_budget(budget.clone()))
        .collect();
    let member_idx =
        |layer: usize, head: usize, slot: usize| (layer * opts.heads + head) * opts.capacity + slot;
    let mut regen = RegenStats::default();

    // banded mode's routed compiles: one lazily-banded pattern per live
    // (layer, head, slot), keyed like EpochCache slots and GC'd the same
    // way on retirement
    struct BandedSlot {
        epoch: u64,
        assignment_epoch: u64,
        chunked: ChunkedPattern,
    }
    let mut banded_routed: HashMap<RouteSlot, BandedSlot> = HashMap::new();
    let mut banded_cache = CacheStats::default();
    let mut banded_epoch = EpochCacheStats::default();
    // band_compiles of chunked patterns already dropped (stale or GC'd)
    let mut band_compiles_retired = 0u64;
    let mut gc_bytes_reclaimed = 0u64;

    let mut hist = StreamingHistogram::new();
    let mut batched_rows = 0u64;
    let mut macs = 0u64;
    let mut elapsed_sec = 0.0f64;
    let mut digest = DIGEST_SEED;
    let mut max_cluster_nnz = 0usize;
    let mut max_shard_nnz = 0usize;
    let mut min_shard_nnz = usize::MAX;

    while !queue.is_empty() || !sched.is_idle() {
        if sched.is_idle() {
            if let Some(next) = queue.peek_arrival() {
                sched.fast_forward(next);
            }
        }
        for req in queue.pop_arrived(sched.now()) {
            sched.submit(req);
        }
        let plan = sched.begin_step();
        // entries the coming lookups touch are step-protected: the budget
        // may spill only patterns no request is using this step
        cache.mark_step();
        for e in &plan.admitted {
            slot_data[e.slot] = Some(SlotData::generate(opts.seed, e.content, opts.n, opts.d));
        }
        if !plan.batch.is_empty() {
            let t0 = Instant::now();
            let b = plan.batch.len();
            // periodic k-means re-fit over the live batch's routing vectors
            if sched.now() % opts.route_every == 0 {
                let mut all = Vec::with_capacity(b * opts.n * opts.d);
                for e in &plan.batch {
                    let data = slot_data[e.slot].as_ref().expect("active slot has data");
                    all.extend_from_slice(&data.xs);
                }
                for layer in 0..opts.layers {
                    for head in (1..opts.heads).step_by(2) {
                        session.update(layer, head, &all, b * opts.n);
                    }
                }
            }
            // pack the live batch's q/k/v into [B, n, d]
            let stride = opts.n * opts.d;
            let mut q = Vec::with_capacity(b * stride);
            let mut k = Vec::with_capacity(b * stride);
            let mut v = Vec::with_capacity(b * stride);
            for e in &plan.batch {
                let data = slot_data[e.slot].as_ref().expect("active slot has data");
                q.extend_from_slice(&data.q);
                k.extend_from_slice(&data.k);
                v.extend_from_slice(&data.v);
            }
            if let Some(static_pattern) = &static_pattern {
                // monolithic mode: whole-sequence compiles, batched sweeps
                for layer in 0..opts.layers {
                    for head in 0..opts.heads {
                        let batch_att = if head % 2 == 0 {
                            BatchedAttention::shared(Arc::clone(static_pattern), b, opts.workers)?
                        } else {
                            let epoch = session.epoch(layer, head);
                            let ae = session.assignment_epoch(layer, head);
                            let patterns: Vec<_> = plan
                                .batch
                                .iter()
                                .map(|e| {
                                    let data = slot_data[e.slot].as_ref().expect("active slot");
                                    let mc = &mut members[member_idx(layer, head, e.slot)];
                                    cache.get_routed_at(
                                        RouteSlot { layer, head, seq: e.slot },
                                        epoch,
                                        ae,
                                        opts.n,
                                        || {
                                            AttentionSpec::union(vec![
                                                local.clone(),
                                                routed_family_spec(
                                                    opts.spec_family,
                                                    &session,
                                                    layer,
                                                    head,
                                                    mc,
                                                    &data.xs,
                                                    opts.n,
                                                    opts.top_w,
                                                ),
                                            ])
                                            .expect("non-empty union of valid specs")
                                        },
                                    )
                                })
                                .collect();
                            for p in &patterns {
                                max_cluster_nnz = max_cluster_nnz.max(p.max_cluster_nnz());
                            }
                            BatchedAttention::new(patterns, opts.workers)?
                        };
                        for nnz in batch_att.worker_nnz() {
                            max_shard_nnz = max_shard_nnz.max(nnz);
                            min_shard_nnz = min_shard_nnz.min(nnz);
                        }
                        let out = batch_att.attention_backend(
                            &q,
                            &k,
                            &v,
                            opts.d,
                            Execution::Pool(pool),
                            backend,
                        )?;
                        std::hint::black_box(&out);
                        digest = fold_digest(digest, &out);
                        batched_rows += (b * opts.n) as u64;
                        macs += batch_att.cost(opts.d);
                    }
                }
            } else {
                // banded mode: stream each sequence band-by-band, so peak
                // resident pattern bytes are bounded by the budget (plus
                // the in-flight band) instead of growing with n
                for layer in 0..opts.layers {
                    for head in 0..opts.heads {
                        if head % 2 == 0 {
                            let chunked = static_chunked.as_mut().expect("banded mode");
                            for (bi, _) in plan.batch.iter().enumerate() {
                                let lo = bi * stride;
                                let out = chunked.attention_backend(
                                    &q[lo..lo + stride],
                                    &k[lo..lo + stride],
                                    &v[lo..lo + stride],
                                    opts.d,
                                    backend,
                                )?;
                                std::hint::black_box(&out);
                                digest = fold_digest(digest, &out);
                                macs += chunked.cost(opts.d);
                            }
                        } else {
                            let epoch = session.epoch(layer, head);
                            let ae = session.assignment_epoch(layer, head);
                            for (bi, e) in plan.batch.iter().enumerate() {
                                let slot = RouteSlot { layer, head, seq: e.slot };
                                // mirror EpochCache::get_routed_at's
                                // assignment-epoch keying for chunked slots
                                let live = match banded_routed.get_mut(&slot) {
                                    Some(entry) if entry.assignment_epoch == ae => {
                                        if entry.epoch != epoch {
                                            entry.epoch = epoch;
                                            banded_epoch.unchanged_epochs += 1;
                                        }
                                        banded_epoch.epoch_hits += 1;
                                        banded_cache.hits += 1;
                                        true
                                    }
                                    _ => false,
                                };
                                if !live {
                                    if let Some(stale) = banded_routed.remove(&slot) {
                                        let bytes = stale.chunked.resident_bytes() as u64;
                                        banded_cache.evictions += 1;
                                        banded_cache.bytes_evicted += bytes;
                                        banded_epoch.bytes_evicted += bytes;
                                        band_compiles_retired += stale.chunked.band_compiles();
                                    }
                                    banded_epoch.epoch_misses += 1;
                                    banded_cache.misses += 1;
                                    let data = slot_data[e.slot].as_ref().expect("active slot");
                                    let mc = &mut members[member_idx(layer, head, e.slot)];
                                    let spec = AttentionSpec::union(vec![
                                        local.clone(),
                                        routed_family_spec(
                                            opts.spec_family,
                                            &session,
                                            layer,
                                            head,
                                            mc,
                                            &data.xs,
                                            opts.n,
                                            opts.top_w,
                                        ),
                                    ])
                                    .expect("non-empty union of valid specs");
                                    banded_routed.insert(
                                        slot,
                                        BandedSlot {
                                            epoch,
                                            assignment_epoch: ae,
                                            chunked: ChunkedPattern::new(
                                                spec,
                                                opts.n,
                                                opts.band_rows,
                                                budget.clone(),
                                            ),
                                        },
                                    );
                                }
                                let entry =
                                    banded_routed.get_mut(&slot).expect("present or just built");
                                let lo = bi * stride;
                                let out = entry.chunked.attention_backend(
                                    &q[lo..lo + stride],
                                    &k[lo..lo + stride],
                                    &v[lo..lo + stride],
                                    opts.d,
                                    backend,
                                )?;
                                std::hint::black_box(&out);
                                digest = fold_digest(digest, &out);
                                macs += entry.chunked.cost(opts.d);
                            }
                        }
                        batched_rows += (b * opts.n) as u64;
                    }
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            hist.record(dt * 1e6);
            elapsed_sec += dt;
        }
        let fin = sched.finish_step(&mut cache);
        gc_bytes_reclaimed += fin.gc_bytes;
        for r in &fin.retired {
            slot_data[r.slot] = None;
            for layer in 0..opts.layers {
                for head in 0..opts.heads {
                    if banded {
                        let slot = RouteSlot { layer, head, seq: r.slot };
                        if let Some(dead) = banded_routed.remove(&slot) {
                            let bytes = dead.chunked.resident_bytes() as u64;
                            banded_cache.evictions += 1;
                            banded_cache.bytes_evicted += bytes;
                            banded_epoch.bytes_evicted += bytes;
                            band_compiles_retired += dead.chunked.band_compiles();
                            gc_bytes_reclaimed += bytes;
                        }
                    }
                    let mc = &mut members[member_idx(layer, head, r.slot)];
                    regen.merge(mc.stats());
                    *mc = MemberCache::with_budget(budget.clone());
                }
            }
        }
    }
    for mc in &members {
        regen.merge(mc.stats());
    }

    // fold the banded side into the cache/epoch counters, then read the
    // meter while every cache is still alive: what is resident at drain
    let band_compiles = band_compiles_retired
        + static_chunked.as_ref().map_or(0, ChunkedPattern::band_compiles)
        + banded_routed.values().map(|s| s.chunked.band_compiles()).sum::<u64>();
    let routed_resident: u64 =
        banded_routed.values().map(|s| s.chunked.resident_bytes() as u64).sum();
    let s = cache.stats();
    let cache_stats = CacheStats {
        hits: s.hits + banded_cache.hits,
        misses: s.misses + banded_cache.misses,
        evictions: s.evictions + banded_cache.evictions,
        bytes_resident: s.bytes_resident
            + routed_resident
            + static_chunked.as_ref().map_or(0, |c| c.resident_bytes() as u64),
        bytes_evicted: s.bytes_evicted
            + banded_cache.bytes_evicted
            + static_chunked.as_ref().map_or(0, ChunkedPattern::bytes_evicted)
            + banded_routed.values().map(|s| s.chunked.bytes_evicted()).sum::<u64>(),
        band_compiles: s.band_compiles + band_compiles,
    };
    let es = cache.epoch_stats();
    let epoch_stats = EpochCacheStats {
        epoch_hits: es.epoch_hits + banded_epoch.epoch_hits,
        epoch_misses: es.epoch_misses + banded_epoch.epoch_misses,
        unchanged_epochs: es.unchanged_epochs + banded_epoch.unchanged_epochs,
        bytes_resident: es.bytes_resident + routed_resident,
        bytes_evicted: es.bytes_evicted + banded_epoch.bytes_evicted,
    };
    let live_patterns_after_gc =
        cache.len() + banded_routed.len() + usize::from(static_chunked.is_some());

    Ok(ServeSummary {
        stats: sched.stats(),
        outcomes: sched.outcomes().to_vec(),
        step_us: hist,
        batched_rows,
        macs,
        elapsed_sec,
        cache: cache_stats,
        epoch: epoch_stats,
        regen,
        live_patterns_after_gc,
        virtual_steps: sched.now(),
        peak_pattern_bytes: budget.peak() as u64,
        pattern_bytes_resident: budget.resident() as u64,
        pattern_bytes_evicted: budget.evicted(),
        band_compiles,
        gc_bytes_reclaimed,
        output_digest: digest,
        worker_procs: 0,
        coord: None,
        spec_family: opts.spec_family,
        max_cluster_nnz,
        max_shard_nnz,
        min_shard_nnz: if min_shard_nnz == usize::MAX { 0 } else { min_shard_nnz },
    })
}

/// The coordinator-backed serve loop: the same scheduler, workload, and
/// head plan as the in-process path, with every attention sweep executed
/// through `coord` (splitting rows across its workers, inline when none
/// are alive).  The coordinator owns the routing state, so the
/// cache/epoch/regen counters — and, because row-partitioned execution
/// of one backend is bitwise, the `output_digest` — evolve identically
/// to [`run_serve`] with `worker_procs == 0`.  Exposed generically over
/// [`Transport`] so tests drive it on a fault-injecting
/// [`SimTransport`](super::coordinator::SimTransport); `run_serve` wraps
/// it over real subprocesses.
///
/// Long-context serving (band_rows / max_pattern_bytes) is not
/// coordinated: bands are already the memory-bounded *single-process*
/// mode, and a coordinated run ships whole-sequence grants.
pub fn run_serve_coordinated<T: Transport>(
    opts: &ServeOptions,
    coord: &mut Coordinator<T>,
) -> Result<ServeSummary> {
    if opts.n == 0 || opts.d == 0 {
        bail!("serve requires n >= 1 and d >= 1 (got n = {}, d = {})", opts.n, opts.d);
    }
    if opts.window == 0 || opts.clusters == 0 || opts.top_w == 0 {
        bail!(
            "serve requires window, clusters, top_w >= 1 (got {}, {}, {})",
            opts.window,
            opts.clusters,
            opts.top_w
        );
    }
    if opts.route_every == 0 {
        bail!("serve requires route_every >= 1");
    }
    if opts.band_rows > 0 || opts.max_pattern_bytes > 0 {
        bail!(
            "coordinated serve supports monolithic mode only \
             (got band_rows = {}, max_pattern_bytes = {})",
            opts.band_rows,
            opts.max_pattern_bytes
        );
    }
    let mut queue = RequestQueue::generate(&opts.arrivals)?;
    let mut sched = Scheduler::new(opts.capacity, opts.layers, opts.heads)?;
    let mut slot_data: Vec<Option<SlotData>> = (0..opts.capacity).map(|_| None).collect();

    let mut hist = StreamingHistogram::new();
    let mut batched_rows = 0u64;
    let mut macs = 0u64;
    let mut elapsed_sec = 0.0f64;
    let mut digest = DIGEST_SEED;
    let mut gc_bytes_reclaimed = 0u64;

    while !queue.is_empty() || !sched.is_idle() {
        if sched.is_idle() {
            if let Some(next) = queue.peek_arrival() {
                sched.fast_forward(next);
            }
        }
        for req in queue.pop_arrived(sched.now()) {
            sched.submit(req);
        }
        let plan = sched.begin_step();
        coord.mark_step();
        for e in &plan.admitted {
            slot_data[e.slot] = Some(SlotData::generate(opts.seed, e.content, opts.n, opts.d));
        }
        if !plan.batch.is_empty() {
            let t0 = Instant::now();
            let b = plan.batch.len();
            if sched.now() % opts.route_every == 0 {
                let mut all = Vec::with_capacity(b * opts.n * opts.d);
                for e in &plan.batch {
                    let data = slot_data[e.slot].as_ref().expect("active slot has data");
                    all.extend_from_slice(&data.xs);
                }
                for layer in 0..opts.layers {
                    for head in (1..opts.heads).step_by(2) {
                        coord.update(layer, head, &all, b * opts.n)?;
                    }
                }
            }
            for layer in 0..opts.layers {
                for head in 0..opts.heads {
                    // batch order matches the in-process [B, n, d] pack,
                    // so the per-sequence digest folds concatenate to the
                    // same byte stream the batched sweep hashes
                    for e in &plan.batch {
                        let data = slot_data[e.slot].as_ref().expect("active slot has data");
                        let (out, cost) = if head % 2 == 0 {
                            coord.static_attention(&data.q, &data.k, &data.v)?
                        } else {
                            coord.routed_attention(
                                layer, head, e.slot, &data.xs, &data.q, &data.k, &data.v,
                            )?
                        };
                        std::hint::black_box(&out);
                        digest = fold_digest(digest, &out);
                        macs += cost;
                    }
                    batched_rows += (b * opts.n) as u64;
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            hist.record(dt * 1e6);
            elapsed_sec += dt;
        }
        let fin = sched.finish_step(coord.cache_mut());
        gc_bytes_reclaimed += fin.gc_bytes;
        for r in &fin.retired {
            slot_data[r.slot] = None;
            coord.retire_slot(r.slot)?;
        }
    }

    Ok(ServeSummary {
        stats: sched.stats(),
        outcomes: sched.outcomes().to_vec(),
        step_us: hist,
        batched_rows,
        macs,
        elapsed_sec,
        cache: coord.cache_stats(),
        epoch: coord.epoch_stats(),
        regen: coord.regen_total(),
        live_patterns_after_gc: coord.live_patterns(),
        virtual_steps: sched.now(),
        peak_pattern_bytes: coord.budget().peak() as u64,
        pattern_bytes_resident: coord.budget().resident() as u64,
        pattern_bytes_evicted: coord.budget().evicted(),
        band_compiles: 0,
        gc_bytes_reclaimed,
        output_digest: digest,
        worker_procs: coord.worker_count(),
        coord: Some(coord.stats()),
        spec_family: opts.spec_family,
        // the coordinated path ships whole-sequence grants and splits rows
        // worker-side, so the in-process shard/cluster observables are
        // reported as 0 (CI strips them from the bit-identity compare)
        max_cluster_nnz: 0,
        max_shard_nnz: 0,
        min_shard_nnz: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::backend::Blocked;

    fn req(id: u64, arrival: u64, work: u64, deadline: u64) -> ServeRequest {
        ServeRequest { id, content: id as usize, arrival, work, deadline }
    }

    #[test]
    fn generate_is_deterministic_and_sorted() {
        let cfg = ArrivalConfig { requests: 100, seed: 7, ..ArrivalConfig::default() };
        let a = RequestQueue::generate(&cfg).unwrap();
        let b = RequestQueue::generate(&cfg).unwrap();
        assert_eq!(a.len(), 100);
        let av: Vec<ServeRequest> = a.pending.iter().copied().collect();
        let bv: Vec<ServeRequest> = b.pending.iter().copied().collect();
        assert_eq!(av, bv, "same seed, same workload");
        assert!(av.windows(2).all(|w| w[0].arrival <= w[1].arrival), "sorted by arrival");
        for r in &av {
            assert!(r.work >= cfg.work.0 && r.work <= cfg.work.1);
            assert!(r.deadline >= r.arrival + r.work + cfg.slack.0);
            assert!(r.content < cfg.contents);
        }
        // ids are generation order
        assert_eq!(av.iter().map(|r| r.id).collect::<Vec<_>>(), (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn generate_zipf_skew_favors_content_zero() {
        let cfg = ArrivalConfig {
            requests: 2000,
            contents: 50,
            zipf_s: 1.2,
            seed: 11,
            ..ArrivalConfig::default()
        };
        let q = RequestQueue::generate(&cfg).unwrap();
        let mut counts = vec![0usize; 50];
        for r in &q.pending {
            counts[r.content] += 1;
        }
        assert!(counts[0] > counts[10], "Zipf head must dominate the tail");
    }

    #[test]
    fn generate_rejects_bad_config() {
        let bad_rate = ArrivalConfig { rate: 0.0, ..ArrivalConfig::default() };
        assert!(RequestQueue::generate(&bad_rate).is_err());
        let bad_work = ArrivalConfig { work: (0, 4), ..ArrivalConfig::default() };
        assert!(RequestQueue::generate(&bad_work).is_err());
        let bad_slack = ArrivalConfig { slack: (9, 3), ..ArrivalConfig::default() };
        assert!(RequestQueue::generate(&bad_slack).is_err());
        let bad_contents = ArrivalConfig { contents: 0, ..ArrivalConfig::default() };
        assert!(RequestQueue::generate(&bad_contents).is_err());
    }

    #[test]
    fn pop_arrived_respects_now() {
        let mut q = RequestQueue::from_requests(vec![
            req(0, 0, 2, 10),
            req(1, 3, 2, 10),
            req(2, 3, 2, 10),
            req(3, 9, 2, 20),
        ])
        .unwrap();
        assert_eq!(q.peek_arrival(), Some(0));
        assert_eq!(q.pop_arrived(0).len(), 1);
        assert_eq!(q.pop_arrived(2).len(), 0);
        assert_eq!(q.peek_arrival(), Some(3));
        let two = q.pop_arrived(5);
        assert_eq!(two.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_arrived(100).len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn from_requests_rejects_unsorted() {
        assert!(RequestQueue::from_requests(vec![req(0, 5, 1, 9), req(1, 2, 1, 9)]).is_err());
    }

    #[test]
    fn admit_decode_retire_with_gc() {
        let mut sched = Scheduler::new(2, 1, 2).unwrap();
        let mut cache = EpochCache::new();
        assert_eq!(sched.submit(req(0, 0, 2, 10)), Submission::Queued);
        let plan = sched.begin_step();
        assert_eq!(plan.batch.len(), 1);
        assert_eq!(plan.admitted.len(), 1);
        assert_eq!(plan.batch[0].slot, 0);
        assert_eq!(plan.batch[0].remaining, 2);
        // give the slot a live routed compile on head 1 only
        cache.get_routed_at(RouteSlot { layer: 0, head: 1, seq: 0 }, 1, 1, 8, || {
            AttentionSpec::routing(vec![vec![0, 1]])
        });
        let fin = sched.finish_step(&mut cache);
        assert!(fin.retired.is_empty(), "one of two steps done");
        let plan = sched.begin_step();
        assert_eq!(plan.batch[0].remaining, 1);
        let fin = sched.finish_step(&mut cache);
        assert_eq!(fin.retired.len(), 1);
        assert_eq!(fin.retired[0], Retired { id: 0, slot: 0, completed_at: 2 });
        // only the head-1 entry was live: exactly one GC eviction
        assert_eq!(fin.gc_evictions, 1);
        assert_eq!(cache.stats().evictions, 1);
        let s = sched.stats();
        assert_eq!(s.completed, 1);
        assert_eq!(s.gc_evictions, 1);
        assert_eq!(
            sched.outcomes(),
            &[RequestOutcome { id: 0, kind: OutcomeKind::Completed, at: 2 }]
        );
        assert!(sched.is_idle());
    }

    #[test]
    fn infeasible_submit_is_rejected() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        let mut cache = EpochCache::new();
        // burn time to step 5
        for _ in 0..5 {
            sched.begin_step();
            sched.finish_step(&mut cache);
        }
        assert_eq!(sched.submit(req(0, 0, 10, 12)), Submission::Rejected);
        assert_eq!(sched.submit(req(1, 0, 0, 100)), Submission::Rejected, "zero work");
        let s = sched.stats();
        assert_eq!(s.rejected, 2);
        assert_eq!(s.queued, 0);
        assert_eq!(sched.outcomes().len(), 2);
        assert!(sched.outcomes().iter().all(|o| o.kind == OutcomeKind::Rejected && o.at == 5));
    }

    #[test]
    fn queued_request_is_shed_when_deadline_slips() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        let mut cache = EpochCache::new();
        // slot hog: 6 steps of work
        assert_eq!(sched.submit(req(0, 0, 6, 20)), Submission::Queued);
        // feasible now (0 + 3 <= 4) but doomed behind the hog
        assert_eq!(sched.submit(req(1, 0, 3, 4)), Submission::Queued);
        let plan = sched.begin_step();
        assert_eq!(plan.admitted.len(), 1, "capacity 1 admits only the hog");
        assert_eq!(plan.batch[0].id, 0);
        assert!(plan.shed.is_empty(), "still feasible at step 0");
        sched.finish_step(&mut cache);
        // step 1: 1 + 3 > 4 → shed
        let plan = sched.begin_step();
        assert_eq!(plan.shed, vec![1]);
        assert_eq!(plan.batch.len(), 1);
        sched.finish_step(&mut cache);
        let s = sched.stats();
        assert_eq!(s.shed, 1);
        assert!(sched
            .outcomes()
            .iter()
            .any(|o| o.id == 1 && o.kind == OutcomeKind::Shed && o.at == 1));
    }

    #[test]
    fn fifo_admission_and_slot_order() {
        let mut sched = Scheduler::new(2, 1, 1).unwrap();
        let mut cache = EpochCache::new();
        for i in 0..4 {
            sched.submit(req(i, 0, 1, 100));
        }
        let plan = sched.begin_step();
        assert_eq!(plan.batch.iter().map(|e| e.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(plan.batch.iter().map(|e| e.slot).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(sched.waiting_len(), 2);
        let fin = sched.finish_step(&mut cache);
        assert_eq!(fin.retired.len(), 2, "work 1 retires immediately");
        let plan = sched.begin_step();
        assert_eq!(plan.batch.iter().map(|e| e.id).collect::<Vec<_>>(), vec![2, 3]);
        sched.finish_step(&mut cache);
        assert_eq!(sched.stats().completed, 4);
        assert_eq!(sched.stats().peak_active, 2);
    }

    #[test]
    fn fast_forward_skips_idle_gaps_only() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        sched.fast_forward(10);
        assert_eq!(sched.now(), 10);
        sched.fast_forward(3); // backwards: no-op
        assert_eq!(sched.now(), 10);
        assert_eq!(sched.stats().fast_forwarded, 10);
    }

    #[test]
    #[should_panic(expected = "idle")]
    fn fast_forward_panics_when_busy() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        sched.submit(req(0, 0, 2, 50));
        sched.fast_forward(5);
    }

    #[test]
    #[should_panic(expected = "begin_step called twice")]
    fn begin_step_twice_panics() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        sched.begin_step();
        sched.begin_step();
    }

    #[test]
    #[should_panic(expected = "without a begin_step")]
    fn finish_step_without_begin_panics() {
        let mut sched = Scheduler::new(1, 1, 1).unwrap();
        sched.finish_step(&mut EpochCache::new());
    }

    #[test]
    fn invalid_shapes_rejected() {
        assert!(Scheduler::new(0, 1, 1).is_err());
        assert!(Scheduler::new(1, 0, 1).is_err());
        assert!(Scheduler::new(1, 1, 0).is_err());
    }

    #[test]
    fn run_serve_resolves_every_request_exactly_once() {
        let opts = ServeOptions {
            n: 32,
            d: 8,
            layers: 2,
            heads: 2,
            window: 8,
            clusters: 4,
            top_w: 8,
            workers: 2,
            capacity: 2,
            route_every: 2,
            arrivals: ArrivalConfig {
                requests: 12,
                rate: 1.5,
                contents: 6,
                zipf_s: 1.1,
                work: (1, 4),
                slack: (0, 6),
                seed: 13,
            },
            seed: 13,
            ..ServeOptions::default()
        };
        let summary = run_serve(&opts, &Blocked).unwrap();
        let s = summary.stats;
        assert_eq!(s.submitted, 12);
        assert_eq!(s.resolved(), 12, "every request reaches a terminal state");
        assert_eq!(s.completed + s.rejected + s.shed, 12);
        // the ledger holds each id exactly once
        let mut ids: Vec<u64> = summary.outcomes.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
        assert!(s.completed >= 1, "a sane config completes something");
        // after full drain only the pinned static pattern survives GC
        assert_eq!(summary.live_patterns_after_gc, 1);
        // step latencies were recorded for every non-idle step
        assert_eq!(summary.step_us.count(), s.steps - s.idle_steps);
        if summary.step_us.count() > 0 {
            assert!(summary.step_us.p99() >= summary.step_us.p50());
            assert!(summary.step_us.p50() > 0.0);
            assert!(summary.rows_per_sec() > 0.0);
        }
        // deterministic replay: same opts, same schedule and counters
        let again = run_serve(&opts, &Blocked).unwrap();
        assert_eq!(again.stats, s);
        assert_eq!(again.outcomes, summary.outcomes);
        assert_eq!(again.batched_rows, summary.batched_rows);
        assert_eq!(again.macs, summary.macs);
    }

    #[test]
    fn run_serve_sheds_under_overload() {
        // capacity 1, long work, zero slack: queueing delay must shed
        let opts = ServeOptions {
            n: 16,
            d: 4,
            layers: 1,
            heads: 2,
            window: 4,
            clusters: 2,
            top_w: 4,
            workers: 1,
            capacity: 1,
            route_every: 4,
            arrivals: ArrivalConfig {
                requests: 16,
                rate: 4.0,
                contents: 4,
                zipf_s: 1.1,
                work: (4, 8),
                slack: (0, 1),
                seed: 3,
            },
            seed: 3,
            ..ServeOptions::default()
        };
        let summary = run_serve(&opts, &Blocked).unwrap();
        let s = summary.stats;
        assert_eq!(s.resolved(), 16);
        assert!(s.shed + s.rejected > 0, "overload must shed or reject, not stall");
        assert_eq!(summary.live_patterns_after_gc, 1);
    }

    #[test]
    fn zero_step_run_reports_finite_zero_latencies() {
        // a workload with no requests retires zero steps; the summary's
        // p50/p99/mean must follow the documented empty-histogram
        // convention (0.0) instead of leaking NaN into the json line
        let opts = ServeOptions {
            arrivals: ArrivalConfig { requests: 0, ..ArrivalConfig::default() },
            ..ServeOptions::default()
        };
        let summary = run_serve(&opts, &Blocked).unwrap();
        assert_eq!(summary.stats.submitted, 0);
        assert_eq!(summary.step_us.count(), 0);
        assert_eq!(summary.step_us.p50(), 0.0);
        assert_eq!(summary.step_us.p99(), 0.0);
        assert_eq!(summary.step_us.mean(), 0.0);
        assert!(summary.step_us.p50().is_finite() && summary.step_us.p99().is_finite());
        assert_eq!(summary.rows_per_sec(), 0.0, "no rows, no NaN throughput");
    }

    #[test]
    fn banded_budgeted_serve_matches_monolithic_lifecycle() {
        let mono_opts = ServeOptions {
            n: 32,
            d: 8,
            layers: 2,
            heads: 2,
            window: 8,
            clusters: 4,
            top_w: 8,
            workers: 2,
            capacity: 2,
            route_every: 2,
            arrivals: ArrivalConfig {
                requests: 12,
                rate: 1.5,
                contents: 6,
                zipf_s: 1.1,
                work: (1, 4),
                slack: (0, 6),
                seed: 13,
            },
            seed: 13,
            ..ServeOptions::default()
        };
        let mono = run_serve(&mono_opts, &Blocked).unwrap();
        // tight budget + small bands: the memory-bounded long-context mode
        let banded_opts = ServeOptions {
            max_pattern_bytes: 4 << 10,
            band_rows: 8,
            ..mono_opts.clone()
        };
        let sum = run_serve(&banded_opts, &Blocked).unwrap();
        // scheduling is pattern-representation-independent: identical
        // request lifecycle (GC eviction counters differ by design — the
        // banded path GCs chunked slots, not EpochCache slots)
        assert_eq!(sum.outcomes, mono.outcomes);
        assert_eq!(sum.stats.submitted, mono.stats.submitted);
        assert_eq!(sum.stats.completed, mono.stats.completed);
        assert_eq!(sum.stats.rejected, mono.stats.rejected);
        assert_eq!(sum.stats.shed, mono.stats.shed);
        assert_eq!(sum.stats.steps, mono.stats.steps);
        assert_eq!(sum.batched_rows, mono.batched_rows);
        assert_eq!(sum.macs, mono.macs, "band streaming attends the exact same nnz");
        // banded bookkeeping engaged and balanced
        assert!(sum.band_compiles > 0, "bands were compiled");
        assert!(sum.peak_pattern_bytes > 0);
        assert!(sum.pattern_bytes_evicted > 0, "the tight budget forced spills");
        assert!(sum.gc_bytes_reclaimed > 0, "retirement GC reclaimed chunked bytes");
        assert_eq!(
            sum.live_patterns_after_gc, 1,
            "after drain only the static chunked pattern survives"
        );
        assert_eq!(
            sum.epoch.lookups(),
            mono.epoch.lookups(),
            "every routed lookup is accounted in both modes"
        );
        // monolithic mode never compiles bands and reports its own bytes
        assert_eq!(mono.band_compiles, 0);
        assert!(mono.peak_pattern_bytes > 0);
        // deterministic replay holds for the banded mode too
        let again = run_serve(&banded_opts, &Blocked).unwrap();
        assert_eq!(again.outcomes, sum.outcomes);
        assert_eq!(again.macs, sum.macs);
        assert_eq!(again.band_compiles, sum.band_compiles);
        assert_eq!(again.peak_pattern_bytes, sum.peak_pattern_bytes);
    }

    #[test]
    fn spec_families_share_the_serve_lifecycle() {
        let base = ServeOptions {
            n: 32,
            d: 8,
            layers: 2,
            heads: 2,
            window: 8,
            clusters: 4,
            top_w: 8,
            workers: 2,
            capacity: 2,
            route_every: 2,
            arrivals: ArrivalConfig {
                requests: 12,
                rate: 1.5,
                contents: 6,
                zipf_s: 1.1,
                work: (1, 4),
                slack: (0, 6),
                seed: 13,
            },
            seed: 13,
            ..ServeOptions::default()
        };
        let routing = run_serve(&base, &Blocked).unwrap();
        assert_eq!(routing.spec_family, SpecFamily::Routing);
        // the batched sweeps populate the shard-nnz observables
        assert!(routing.max_shard_nnz > 0);
        assert!(routing.min_shard_nnz <= routing.max_shard_nnz);
        for family in [SpecFamily::ExpertChoice, SpecFamily::Threshold] {
            let opts = ServeOptions { spec_family: family, ..base.clone() };
            let sum = run_serve(&opts, &Blocked).unwrap();
            assert_eq!(sum.spec_family, family);
            // scheduling is spec-content-independent: identical lifecycle
            assert_eq!(sum.outcomes, routing.outcomes, "{family:?}");
            assert_eq!(sum.stats, routing.stats, "{family:?}");
            assert_eq!(sum.batched_rows, routing.batched_rows);
            assert_eq!(sum.live_patterns_after_gc, 1);
            assert!(sum.max_shard_nnz > 0);
            if family == SpecFamily::ExpertChoice {
                // the capacity bound: every cluster keeps <= top_w tokens,
                // so its causal pair count is <= cap*(cap+1)/2
                let cap = opts.top_w;
                assert!(
                    sum.max_cluster_nnz <= cap * (cap + 1) / 2,
                    "max_cluster_nnz {} over bound for capacity {cap}",
                    sum.max_cluster_nnz
                );
                assert!(sum.max_cluster_nnz > 0, "routed compiles were observed");
            }
            // deterministic replay per family (digest pins the outputs)
            let again = run_serve(&opts, &Blocked).unwrap();
            assert_eq!(again.output_digest, sum.output_digest, "{family:?}");
            assert_eq!(again.macs, sum.macs);
            assert_eq!(again.max_cluster_nnz, sum.max_cluster_nnz);
            assert_eq!(again.max_shard_nnz, sum.max_shard_nnz);
            assert_eq!(again.min_shard_nnz, sum.min_shard_nnz);
            // banded streaming attends the exact same nnz for every family
            let banded = ServeOptions { band_rows: 8, ..opts.clone() };
            let bsum = run_serve(&banded, &Blocked).unwrap();
            assert_eq!(bsum.macs, sum.macs, "{family:?} band == monolithic nnz");
            assert_eq!(bsum.outcomes, sum.outcomes);
            assert_eq!(bsum.max_shard_nnz, 0, "banded mode has no batched shards");
            assert_eq!(bsum.max_cluster_nnz, 0);
        }
        // families genuinely differ: expert-choice prunes the overlapping
        // routed sets, so its attended nnz (macs) must not match routing's
        let expert =
            run_serve(&ServeOptions { spec_family: SpecFamily::ExpertChoice, ..base.clone() }, &Blocked)
                .unwrap();
        assert_ne!(expert.macs, routing.macs, "expert-choice must change the attend sets");
    }

    #[test]
    fn run_serve_rejects_bad_options() {
        let mut opts = ServeOptions { n: 0, ..ServeOptions::default() };
        assert!(run_serve(&opts, &Blocked).is_err());
        opts.n = 16;
        opts.route_every = 0;
        assert!(run_serve(&opts, &Blocked).is_err());
    }
}

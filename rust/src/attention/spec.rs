//! Declarative attention-sparsity specs — phase one of the spec→compile
//! pipeline.
//!
//! An [`AttentionSpec`] describes *which* scheme restricts each query's
//! key set S_i (Sec. 3 of the paper), without fixing a sequence length:
//! causal full attention, (blocked) local attention, strided attention
//! (Child et al. 2019), content-routed attention (Algorithm 1), and
//! `Union`/`Intersect` composition for the mixed head plans of Sec. 4.2
//! (the paper's best models mix local and routing heads).  Constructors
//! validate degenerate parameters (zero windows/strides used to mean
//! divide-by-zero); [`AttentionSpec::compile`] materializes the spec for a
//! sequence length into a [`CompiledPattern`] CSR index set; and
//! [`AttentionSpec::flops_estimate`] keeps the closed-form Section-4.1
//! asymptotic cost model (`O(nkd + n²d/k)`, minimized at k ≈ √n).
//!
//! Specs serialize to/from JSON (`to_json`/`from_json`) so head plans can
//! live in manifests and configs.
//!
//! For long contexts every family is also *band-compilable*:
//! [`AttentionSpec::compile_band`] materializes just a contiguous row
//! range (bit-identical to the matching slice of a monolithic compile,
//! because all row construction here is keyed on the absolute row index),
//! and [`ChunkedPattern`] serves a whole pattern from lazily compiled,
//! LRU-evicted bands under a [`MemoryBudget`].

use std::ops::Range;

use anyhow::{anyhow, bail, Result};

use crate::attention::backend::Backend;
use crate::attention::compiled::{CompiledPattern, MemoryBudget, PatternBand, NO_CLUSTER};
use crate::util::json::Json;

/// A declarative sparse-attention scheme.  Always causal: every variant
/// only ever admits keys j <= i.  `Hash` (with the constructor
/// normalization) makes specs directly usable as compile-cache keys —
/// structural identity coincides with canonical-JSON identity.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AttentionSpec {
    /// Causal full attention: S_i = { j | j <= i }.
    Full,
    /// Sliding-window local attention: S_i = { j | i-w < j <= i }.
    Local { window: usize },
    /// Blocked local attention (the L1 kernel's semantics): query block b
    /// attends to blocks b-1 and b, causally.
    BlockLocal { window: usize },
    /// Strided attention (Child et al.): S_i = { j <= i | (i-j) % s == 0 }.
    Strided { stride: usize },
    /// Cluster routing (Algorithm 1): token i attends to j <= i iff some
    /// cluster selected both i and j.  Member lists are sorted + deduped.
    Routing { clusters: Vec<Vec<usize>> },
    /// Mixed head plan: a key is admitted if any part admits it.
    Union(Vec<AttentionSpec>),
    /// A key is admitted only if every part admits it.
    Intersect(Vec<AttentionSpec>),
}

impl AttentionSpec {
    /// Causal full attention (constructor-style alias for
    /// [`AttentionSpec::Full`]).
    pub fn full() -> AttentionSpec {
        AttentionSpec::Full
    }

    /// Local attention; rejects `window == 0` (an empty window would make
    /// every S_i empty and used to underflow in the old pattern code).
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let local = AttentionSpec::local(4).unwrap();
    /// let p = local.compile(16);
    /// assert_eq!(p.row(10), &[7, 8, 9, 10]);
    /// assert!(AttentionSpec::local(0).is_err(), "degenerate windows are rejected");
    /// ```
    pub fn local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::Local { window })
    }

    /// Blocked local attention; rejects `window == 0` (block index i/w
    /// would divide by zero).
    pub fn block_local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("block-local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::BlockLocal { window })
    }

    /// Strided attention; rejects `stride == 0` ((i-j) % 0 is UB-shaped).
    pub fn strided(stride: usize) -> Result<AttentionSpec> {
        if stride == 0 {
            bail!("strided attention requires stride >= 1 (got 0)");
        }
        Ok(AttentionSpec::Strided { stride })
    }

    /// Routing from explicit cluster membership lists.  Members are
    /// normalized (sorted ascending, deduped); membership beyond the
    /// compiled sequence length is ignored at compile time.
    pub fn routing(clusters: Vec<Vec<usize>>) -> AttentionSpec {
        let clusters = clusters
            .into_iter()
            .map(|mut m| {
                m.sort_unstable();
                m.dedup();
                m
            })
            .collect();
        AttentionSpec::Routing { clusters }
    }

    /// The balanced-cluster idealization of the Section-4.1 model: k
    /// contiguous clusters of w = max(n/k, 1) tokens each (tail tokens
    /// beyond k*w stay unrouted, exactly as the closed-form model assumes).
    pub fn routing_balanced(n: usize, k: usize) -> Result<AttentionSpec> {
        if k == 0 {
            bail!("routing requires at least one cluster (got k = 0)");
        }
        let w = (n / k).max(1);
        let clusters = (0..k)
            .map(|c| (c * w..((c + 1) * w).min(n)).collect())
            .collect();
        Ok(AttentionSpec::routing(clusters))
    }

    /// Mixed head plan: union of the parts' index sets.
    pub fn union(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("union of zero specs is undefined");
        }
        Ok(AttentionSpec::Union(parts))
    }

    /// Intersection of the parts' index sets.
    pub fn intersect(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("intersection of zero specs is undefined");
        }
        Ok(AttentionSpec::Intersect(parts))
    }

    /// Compile the spec for sequence length `n` into a CSR index set.
    /// Infallible: constructors validate parameters; hand-built enum
    /// values with zero windows/strides are clamped to 1 defensively.
    /// `n = 0` compiles to an empty pattern.
    pub fn compile(&self, n: usize) -> CompiledPattern {
        CompiledPattern::from_rows(n, build_rows(self, n))
    }

    /// Compile only the query rows in `row_range` (clamped to `0..n`,
    /// same contract as [`CompiledPattern::rows`]) into a
    /// [`PatternBand`].  Because every row built by this module depends
    /// only on its absolute index, the band is bit-identical to the
    /// matching slice of `self.compile(n)` — the property the banded
    /// long-context path rests on, pinned in `tests/proptests.rs`.
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let spec = AttentionSpec::local(4).unwrap();
    /// let band = spec.compile_band(1 << 20, 777..779);
    /// assert_eq!(band.row(777), spec.compile(1024).row(777));
    /// assert!(band.heap_bytes() < 1 << 10, "only the band is resident");
    /// ```
    pub fn compile_band(&self, n: usize, row_range: Range<usize>) -> PatternBand {
        let end = row_range.end.min(n);
        let start = row_range.start.min(end);
        PatternBand::from_rows(n, start, build_rows_range(self, n, start..end))
    }

    /// JSON encoding of the spec (declarative, nestable).
    pub fn to_json(&self) -> Json {
        let kind = |k: &str| ("kind".to_string(), Json::Str(k.to_string()));
        match self {
            AttentionSpec::Full => Json::Obj(vec![kind("full")]),
            AttentionSpec::Local { window } => Json::Obj(vec![
                kind("local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::BlockLocal { window } => Json::Obj(vec![
                kind("block_local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::Strided { stride } => Json::Obj(vec![
                kind("strided"),
                ("stride".to_string(), Json::Num(*stride as f64)),
            ]),
            AttentionSpec::Routing { clusters } => Json::Obj(vec![
                kind("routing"),
                (
                    "clusters".to_string(),
                    Json::Arr(
                        clusters
                            .iter()
                            .map(|m| {
                                Json::Arr(m.iter().map(|&i| Json::Num(i as f64)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            AttentionSpec::Union(parts) => Json::Obj(vec![
                kind("union"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
            AttentionSpec::Intersect(parts) => Json::Obj(vec![
                kind("intersect"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
        }
    }

    /// Decode a spec from JSON, re-running constructor validation.
    pub fn from_json(j: &Json) -> Result<AttentionSpec> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("attention spec json missing string 'kind'"))?;
        let field = |name: &str| -> Result<usize> {
            j.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("spec '{kind}' missing integer '{name}'"))
        };
        let parts = |name: &str| -> Result<Vec<AttentionSpec>> {
            j.get(name)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec '{kind}' missing array '{name}'"))?
                .iter()
                .map(AttentionSpec::from_json)
                .collect()
        };
        match kind {
            "full" => Ok(AttentionSpec::Full),
            "local" => AttentionSpec::local(field("window")?),
            "block_local" => AttentionSpec::block_local(field("window")?),
            "strided" => AttentionSpec::strided(field("stride")?),
            "routing" => {
                let arr = j
                    .get("clusters")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("routing spec missing array 'clusters'"))?;
                let clusters = arr
                    .iter()
                    .map(|m| {
                        m.as_arr()
                            .ok_or_else(|| anyhow!("routing cluster must be an array"))?
                            .iter()
                            .map(|v| {
                                v.as_usize()
                                    .ok_or_else(|| anyhow!("cluster member must be an integer"))
                            })
                            .collect::<Result<Vec<usize>>>()
                    })
                    .collect::<Result<Vec<Vec<usize>>>>()?;
                Ok(AttentionSpec::routing(clusters))
            }
            "union" => AttentionSpec::union(parts("parts")?),
            "intersect" => AttentionSpec::intersect(parts("parts")?),
            other => bail!("unknown attention spec kind '{other}'"),
        }
    }
}

/// Per-query (key, cluster-id) rows, sorted by key and deduped — the
/// intermediate representation `CompiledPattern::from_rows` packs into CSR.
fn build_rows(spec: &AttentionSpec, n: usize) -> Vec<Vec<(usize, u32)>> {
    build_rows_range(spec, n, 0..n)
}

/// [`build_rows`] restricted to query rows `range` (callers pass a
/// clamped `range ⊆ 0..n`; element r of the result is absolute row
/// `range.start + r`).  Every arm keys row content on the *absolute* row
/// index and postprocesses per row, which is what makes a band compile
/// bit-identical to the matching monolithic slice.
fn build_rows_range(
    spec: &AttentionSpec,
    n: usize,
    range: Range<usize>,
) -> Vec<Vec<(usize, u32)>> {
    debug_assert!(range.start <= range.end && range.end <= n);
    match spec {
        AttentionSpec::Full => {
            range.map(|i| (0..=i).map(|j| (j, NO_CLUSTER)).collect()).collect()
        }
        AttentionSpec::Local { window } => {
            let w = (*window).max(1);
            range
                .map(|i| {
                    (i.saturating_sub(w - 1)..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::BlockLocal { window } => {
            let w = (*window).max(1);
            range
                .map(|i| {
                    let start = (i / w).saturating_sub(1) * w;
                    (start..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::Strided { stride } => {
            let s = (*stride).max(1);
            range
                .map(|i| (i % s..=i).step_by(s).map(|j| (j, NO_CLUSTER)).collect())
                .collect()
        }
        AttentionSpec::Routing { clusters } => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); range.len()];
            for (c, members) in clusters.iter().enumerate() {
                // constructors normalize, but hand-built enums may not be
                // sorted/deduped/in-range — renormalize defensively
                let mut ms: Vec<usize> = members.iter().copied().filter(|&i| i < n).collect();
                ms.sort_unstable();
                ms.dedup();
                for (idx, &i) in ms.iter().enumerate() {
                    if !range.contains(&i) {
                        continue;
                    }
                    for &j in &ms[..=idx] {
                        rows[i - range.start].push((j, c as u32));
                    }
                }
            }
            for row in &mut rows {
                // sort by key then cluster; dedup keeps the lowest cluster
                // id for a key selected by several clusters (the renderer's
                // "first matching cluster" convention)
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Union(parts) => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); range.len()];
            for part in parts {
                let prows = build_rows_range(part, n, range.clone());
                for (row, prow) in rows.iter_mut().zip(prows) {
                    row.extend(prow);
                }
            }
            for row in &mut rows {
                // NO_CLUSTER sorts last, so routed entries keep their
                // cluster id when a key is admitted by several parts
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Intersect(parts) => {
            let mut iter = parts.iter();
            let first = match iter.next() {
                // empty intersection = no constraint (matches `all()`)
                None => return build_rows_range(&AttentionSpec::Full, n, range),
                Some(p) => p,
            };
            let mut rows = build_rows_range(first, n, range.clone());
            for part in iter {
                let prows = build_rows_range(part, n, range.clone());
                for (row, prow) in rows.iter_mut().zip(&prows) {
                    let mut out = Vec::new();
                    let (mut a, mut b) = (0usize, 0usize);
                    while a < row.len() && b < prow.len() {
                        match row[a].0.cmp(&prow[b].0) {
                            std::cmp::Ordering::Less => a += 1,
                            std::cmp::Ordering::Greater => b += 1,
                            std::cmp::Ordering::Equal => {
                                out.push((row[a].0, row[a].1.min(prow[b].1)));
                                a += 1;
                                b += 1;
                            }
                        }
                    }
                    *row = out;
                }
            }
            rows
        }
    }
}

/// A compiled pattern served from lazily compiled row bands under a
/// [`MemoryBudget`] — the long-context replacement for holding one
/// monolithic [`CompiledPattern`] resident.
///
/// The sequence is split into `ceil(n / band_rows)` contiguous bands;
/// [`rows`](Self::rows) / [`row`](Self::row) / [`nnz`](Self::nnz) /
/// [`cost`](Self::cost) keep the `CompiledPattern` API shape but compile
/// bands on first touch ([`AttentionSpec::compile_band`]) and LRU-spill
/// resident bands whenever the shared budget is over. Bands touched by
/// the in-flight call are never its own spill victims, so the budget is
/// a soft cap: peak residency can exceed it by the protected band(s).
/// Evaluation streams bands through any [`Backend`] unchanged
/// ([`attention_backend`](Self::attention_backend)) by padding each band
/// to an n-row pattern whose out-of-band rows are empty — bit-identical
/// output to evaluating the monolithic compile.
#[derive(Debug)]
pub struct ChunkedPattern {
    spec: AttentionSpec,
    n: usize,
    band_rows: usize,
    /// `ceil(n / band_rows)` slots; `None` = not resident.
    bands: Vec<Option<PatternBand>>,
    /// LRU clock per band (0 = never touched).
    last_used: Vec<u64>,
    tick: u64,
    budget: MemoryBudget,
    /// Cached total nnz once every band has been visited at least once.
    total_nnz: Option<usize>,
    band_compiles: u64,
    band_evictions: u64,
    bytes_evicted: u64,
}

impl ChunkedPattern {
    /// Serve `spec` at sequence length `n` from bands of `band_rows`
    /// query rows (clamped to >= 1), metering residency against
    /// `budget`.  Nothing is compiled until first touch.
    pub fn new(
        spec: AttentionSpec,
        n: usize,
        band_rows: usize,
        budget: MemoryBudget,
    ) -> ChunkedPattern {
        let band_rows = band_rows.max(1);
        let num_bands = n.div_ceil(band_rows);
        ChunkedPattern {
            spec,
            n,
            band_rows,
            bands: (0..num_bands).map(|_| None).collect(),
            last_used: vec![0; num_bands],
            tick: 0,
            budget,
            total_nnz: None,
            band_compiles: 0,
            band_evictions: 0,
            bytes_evicted: 0,
        }
    }

    /// Sequence length served.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Query rows per band (last band may be shorter).
    pub fn band_rows(&self) -> usize {
        self.band_rows
    }

    /// Total number of bands (`ceil(n / band_rows)`).
    pub fn num_bands(&self) -> usize {
        self.bands.len()
    }

    /// The spec being served.
    pub fn spec(&self) -> &AttentionSpec {
        &self.spec
    }

    /// The shared byte meter this pattern charges.
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// Bands compiled so far (recompiles after eviction count again).
    pub fn band_compiles(&self) -> u64 {
        self.band_compiles
    }

    /// Bands spilled to stay under budget.
    pub fn band_evictions(&self) -> u64 {
        self.band_evictions
    }

    /// Total bytes freed by spills.
    pub fn bytes_evicted(&self) -> u64 {
        self.bytes_evicted
    }

    /// Bands currently resident.
    pub fn resident_bands(&self) -> usize {
        self.bands.iter().filter(|b| b.is_some()).count()
    }

    /// Heap bytes of the currently resident bands.
    pub fn resident_bytes(&self) -> usize {
        self.bands.iter().flatten().map(PatternBand::heap_bytes).sum()
    }

    /// Make band `b` resident (compiling if spilled), bump its LRU
    /// clock, then spill over-budget bands outside `protected`.
    fn ensure_band(&mut self, b: usize, protected: Range<usize>) {
        self.tick += 1;
        self.last_used[b] = self.tick;
        if self.bands[b].is_none() {
            let start = b * self.band_rows;
            let end = ((b + 1) * self.band_rows).min(self.n);
            let band = self.spec.compile_band(self.n, start..end);
            self.budget.charge(band.heap_bytes());
            self.band_compiles += 1;
            self.bands[b] = Some(band);
        }
        self.spill(protected);
    }

    /// LRU-spill resident bands outside `protected` until the shared
    /// budget is satisfied (or only protected bands remain — the soft
    /// cap).
    fn spill(&mut self, protected: Range<usize>) {
        while self.budget.over_budget() {
            let victim = self
                .bands
                .iter()
                .enumerate()
                .filter(|&(i, band)| band.is_some() && !protected.contains(&i))
                .min_by_key(|&(i, _)| self.last_used[i])
                .map(|(i, _)| i);
            let Some(v) = victim else { break };
            let bytes = self.bands[v].take().expect("victim is resident").heap_bytes();
            self.budget.release(bytes);
            self.band_evictions += 1;
            self.bytes_evicted += bytes as u64;
        }
    }

    /// Band index owning absolute row `i < n`.
    fn band_of(&self, i: usize) -> usize {
        i / self.band_rows
    }

    /// Attend-set for absolute row `i` (empty for `i >= n`), compiling
    /// the owning band on demand — same contract as
    /// [`CompiledPattern::row`].
    pub fn row(&mut self, i: usize) -> &[usize] {
        if i >= self.n {
            return &[];
        }
        let b = self.band_of(i);
        self.ensure_band(b, b..b + 1);
        self.bands[b].as_ref().expect("band just ensured resident").row(i)
    }

    /// Iterate `(i, keys, clusters)` over `range` (clamped to `0..n`,
    /// same contract as [`CompiledPattern::rows`]); every band the range
    /// touches is made resident first and protected from spilling for
    /// the duration of the borrow.
    pub fn rows(&mut self, range: Range<usize>) -> ChunkedRowIter<'_> {
        let end = range.end.min(self.n);
        let start = range.start.min(end);
        if start < end {
            let b0 = self.band_of(start);
            let b1 = self.band_of(end - 1);
            for b in b0..=b1 {
                self.ensure_band(b, b0..b1 + 1);
            }
        }
        ChunkedRowIter { pattern: self, range: start..end }
    }

    /// Total non-zero entries; the first call streams every band through
    /// residency once (spilling as it goes), later calls are O(1).
    pub fn nnz(&mut self) -> usize {
        if let Some(total) = self.total_nnz {
            return total;
        }
        let mut total = 0usize;
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            total += self.bands[b].as_ref().expect("resident").nnz();
        }
        self.total_nnz = Some(total);
        total
    }

    /// Exact MAC count (`2 · nnz · d`, saturating) — same model as
    /// [`CompiledPattern::cost`].
    pub fn cost(&mut self, d: usize) -> u64 {
        u64::try_from(2u128 * self.nnz() as u128 * d as u128).unwrap_or(u64::MAX)
    }

    /// Evaluate the whole pattern with `backend`, streaming band by band
    /// so only O(band) pattern bytes are resident at once: each band is
    /// padded to an n-row pattern (out-of-band rows empty) and handed to
    /// [`Backend::attention_rows`] over exactly its row range, which
    /// touches the same CSR slices the monolithic pattern would — the
    /// output is bit-identical to `backend.attention` on
    /// `self.spec.compile(self.n)`.
    pub fn attention_backend(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        backend: &dyn Backend,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.n * d];
        let mut total = 0usize;
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            let band = self.bands[b].as_ref().expect("resident");
            let (start, end) = (band.start(), band.end());
            total += band.nnz();
            let padded = band.to_pattern();
            backend.attention_rows(q, k, v, d, &padded, start..end, &mut out[start * d..end * d])?;
        }
        self.total_nnz = Some(total);
        Ok(out)
    }

    /// Concatenate every band into a monolithic [`CompiledPattern`]
    /// (bit-identical to `self.spec.compile(self.n)`; used by the
    /// equivalence tests).  Materializes O(n) memory by definition.
    pub fn assemble(&mut self) -> CompiledPattern {
        let mut row_offsets = Vec::with_capacity(self.n + 1);
        row_offsets.push(0usize);
        let mut cols = Vec::new();
        let mut cluster_ids = Vec::new();
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            let band = self.bands[b].as_ref().expect("resident");
            for i in band.start()..band.end() {
                cols.extend_from_slice(band.row(i));
                cluster_ids.extend_from_slice(band.row_clusters(i));
                row_offsets.push(cols.len());
            }
        }
        self.total_nnz = Some(cols.len());
        CompiledPattern::from_parts(self.n, row_offsets, cols, cluster_ids)
    }
}

impl Drop for ChunkedPattern {
    /// Releasing the budget charge on drop is what lets serve GC count
    /// retired sequences' pattern bytes as reclaimed.
    fn drop(&mut self) {
        for band in self.bands.iter_mut() {
            if let Some(b) = band.take() {
                self.budget.release(b.heap_bytes());
            }
        }
    }
}

/// Iterator over `(i, keys, clusters)` rows of a [`ChunkedPattern`]; see
/// [`ChunkedPattern::rows`].
#[derive(Debug)]
pub struct ChunkedRowIter<'a> {
    pattern: &'a ChunkedPattern,
    range: Range<usize>,
}

impl<'a> Iterator for ChunkedRowIter<'a> {
    type Item = (usize, &'a [usize], &'a [u32]);

    fn next(&mut self) -> Option<Self::Item> {
        let i = self.range.next()?;
        let band = self.pattern.bands[self.pattern.band_of(i)]
            .as_ref()
            .expect("rows() made every band in range resident");
        Some((i, band.row(i), band.row_clusters(i)))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.range.size_hint()
    }
}

impl<'a> ExactSizeIterator for ChunkedRowIter<'a> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degenerate_params_rejected() {
        assert!(AttentionSpec::local(0).is_err());
        assert!(AttentionSpec::block_local(0).is_err());
        assert!(AttentionSpec::strided(0).is_err());
        assert!(AttentionSpec::routing_balanced(16, 0).is_err());
        assert!(AttentionSpec::union(vec![]).is_err());
        assert!(AttentionSpec::intersect(vec![]).is_err());
        assert!(AttentionSpec::local(1).is_ok());
        assert!(AttentionSpec::strided(1).is_ok());
    }

    #[test]
    fn hand_built_zero_params_clamp_instead_of_panicking() {
        // direct enum construction bypasses validation; compile must clamp
        let p = AttentionSpec::Local { window: 0 }.compile(4);
        assert_eq!(p.row(2), &[2]);
        let p = AttentionSpec::Strided { stride: 0 }.compile(4);
        assert_eq!(p.row(3), &[0, 1, 2, 3]);
        // clamped to blocks of 1: each query sees itself and its
        // predecessor, so rows are {0}, {0,1}, {1,2}, {2,3}
        let p = AttentionSpec::BlockLocal { window: 0 }.compile(4);
        assert_eq!(p.row(3), &[2, 3]);
        assert_eq!(p.nnz(), 7);
    }

    #[test]
    fn routing_normalizes_members() {
        let spec = AttentionSpec::routing(vec![vec![5, 2, 2, 0]]);
        match &spec {
            AttentionSpec::Routing { clusters } => assert_eq!(clusters[0], vec![0, 2, 5]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn routing_balanced_covers_prefix() {
        let spec = AttentionSpec::routing_balanced(10, 3).unwrap();
        match &spec {
            AttentionSpec::Routing { clusters } => {
                assert_eq!(clusters.len(), 3);
                // w = 3; tail token 9 stays unrouted, as the model assumes
                assert_eq!(clusters[0], vec![0, 1, 2]);
                assert_eq!(clusters[2], vec![6, 7, 8]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn json_roundtrip_nested() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(8).unwrap(),
            AttentionSpec::routing(vec![vec![0, 3, 9], vec![1, 2]]),
            AttentionSpec::intersect(vec![
                AttentionSpec::Full,
                AttentionSpec::strided(4).unwrap(),
            ])
            .unwrap(),
        ])
        .unwrap();
        let text = spec.to_json().to_string();
        let back = AttentionSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn compile_band_slices_match_monolithic() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::block_local(3).unwrap(),
            AttentionSpec::routing(vec![vec![0, 4, 9, 13], vec![2, 6, 11]]),
        ])
        .unwrap();
        let n = 17;
        let mono = spec.compile(n);
        // 5..8 straddles a BlockLocal block boundary (blocks 1 and 2)
        for range in [0..n, 5..8, 0..0, 9..9, 16..17, 12..40] {
            let band = spec.compile_band(n, range.clone());
            assert_eq!(band.start(), range.start.min(n));
            for i in band.start()..band.end() {
                assert_eq!(band.row(i), mono.row(i), "row {i} of band {range:?}");
                assert_eq!(band.row_clusters(i), mono.row_clusters(i));
            }
        }
        assert!(spec.compile_band(0, 0..10).is_empty());
    }

    #[test]
    fn chunked_pattern_matches_monolithic_under_tiny_budget() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(5).unwrap(),
            AttentionSpec::routing_balanced(64, 8).unwrap(),
        ])
        .unwrap();
        let n = 64;
        let mono = spec.compile(n);
        // budget far below the monolithic footprint forces real churn
        let budget = MemoryBudget::bytes(mono.heap_bytes() / 4);
        let mut chunked = ChunkedPattern::new(spec, n, 7, budget.clone());
        assert_eq!(chunked.num_bands(), 10);
        assert_eq!(chunked.resident_bands(), 0, "lazy until first touch");

        assert_eq!(chunked.nnz(), mono.nnz());
        assert_eq!(chunked.cost(16), mono.cost(16));
        for i in [0, 3, 40, 63, 64, 1000] {
            assert_eq!(chunked.row(i), mono.row(i));
        }
        let got: Vec<(usize, Vec<usize>, Vec<u32>)> =
            chunked.rows(10..30).map(|(i, ks, cs)| (i, ks.to_vec(), cs.to_vec())).collect();
        let want: Vec<(usize, Vec<usize>, Vec<u32>)> =
            mono.rows(10..30).map(|(i, ks, cs)| (i, ks.to_vec(), cs.to_vec())).collect();
        assert_eq!(got, want);
        assert_eq!(chunked.assemble(), mono, "band concatenation is bit-identical");

        assert!(chunked.band_compiles() > 10, "eviction churn forces recompiles");
        assert!(chunked.band_evictions() > 0);
        assert!(chunked.bytes_evicted() > 0);
        assert_eq!(budget.resident(), chunked.resident_bytes());
        // soft cap: only protected bands ride above the budget, and the
        // widest protected window above was rows(10..30) = 4 bands
        let max_band = (0..chunked.num_bands())
            .map(|b| chunked.spec().compile_band(n, b * 7..(b + 1) * 7).heap_bytes())
            .max()
            .unwrap();
        assert!(
            budget.peak() <= budget.max_bytes().unwrap() + 4 * max_band,
            "peak {} exceeds budget {} + 4 protected bands of {}",
            budget.peak(),
            budget.max_bytes().unwrap(),
            max_band
        );

        drop(chunked);
        assert_eq!(budget.resident(), 0, "drop releases every resident charge");
    }

    #[test]
    fn chunked_attention_is_bit_identical_to_monolithic() {
        use crate::attention::backend::Reference;
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(6).unwrap(),
            AttentionSpec::routing_balanced(48, 6).unwrap(),
        ])
        .unwrap();
        let (n, d) = (48, 4);
        let mut x = 0x9E37u32;
        let mut gen = || {
            x = x.wrapping_mul(0x0101_9E3B).wrapping_add(12345);
            (x >> 8) as f32 / (1 << 24) as f32 - 0.5
        };
        let q: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let k: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let v: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let mono = spec.compile(n);
        let want = Reference.attention(&q, &k, &v, d, &mono).unwrap();
        let mut chunked =
            ChunkedPattern::new(spec, n, 5, MemoryBudget::bytes(mono.heap_bytes() / 5));
        let got = chunked.attention_backend(&q, &k, &v, d, &Reference).unwrap();
        assert_eq!(got, want, "banded evaluation must be bit-identical");
        assert_eq!(chunked.nnz(), mono.nnz(), "nnz set for free during the sweep");

        let mut empty = ChunkedPattern::new(AttentionSpec::Full, 0, 4, MemoryBudget::unbounded());
        assert_eq!(empty.num_bands(), 0);
        assert_eq!(empty.nnz(), 0);
        assert!(empty.attention_backend(&[], &[], &[], d, &Reference).unwrap().is_empty());
    }

    #[test]
    fn from_json_rejects_bad_specs() {
        for bad in [
            r#"{"kind":"warp"}"#,
            r#"{"kind":"local"}"#,
            r#"{"kind":"local","window":0}"#,
            r#"{"window":3}"#,
            // fractional / negative params used to be silently truncated
            // or saturated by the lossy `as` casts in Json::as_usize
            r#"{"kind":"local","window":2.7}"#,
            r#"{"kind":"local","window":-1}"#,
            r#"{"kind":"strided","stride":3.5}"#,
            r#"{"kind":"block_local","window":1e30}"#,
            r#"{"kind":"routing","clusters":[[0,1.5]]}"#,
            r#"{"kind":"routing","clusters":[[-2,1]]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(AttentionSpec::from_json(&j).is_err(), "accepted {bad}");
        }
    }
}

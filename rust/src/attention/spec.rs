//! Declarative attention-sparsity specs — phase one of the spec→compile
//! pipeline.
//!
//! An [`AttentionSpec`] describes *which* scheme restricts each query's
//! key set S_i (Sec. 3 of the paper), without fixing a sequence length:
//! causal full attention, (blocked) local attention, strided attention
//! (Child et al. 2019), content-routed attention (Algorithm 1), the
//! newer content-based families — expert-choice routing (`ExpertChoice`,
//! capacity-bounded by construction, MoSA-style) and calibrated
//! score-threshold attend-sets (`Threshold`, Condensate-style) — and
//! `Union`/`Intersect` composition for the mixed head plans of Sec. 4.2
//! (the paper's best models mix local and routing heads).  Constructors
//! validate degenerate parameters (zero windows/strides used to mean
//! divide-by-zero); [`AttentionSpec::compile`] materializes the spec for a
//! sequence length into a [`CompiledPattern`] CSR index set; and
//! [`AttentionSpec::flops_estimate`] keeps the closed-form Section-4.1
//! asymptotic cost model (`O(nkd + n²d/k)`, minimized at k ≈ √n).
//!
//! Specs serialize to/from JSON (`to_json`/`from_json`) so head plans can
//! live in manifests and configs.
//!
//! For long contexts every family is also *band-compilable*:
//! [`AttentionSpec::compile_band`] materializes just a contiguous row
//! range (bit-identical to the matching slice of a monolithic compile,
//! because all row construction here is keyed on the absolute row index),
//! and [`ChunkedPattern`] serves a whole pattern from lazily compiled,
//! LRU-evicted bands under a [`MemoryBudget`].

use std::ops::Range;

use anyhow::{anyhow, bail, Result};

use crate::attention::backend::Backend;
use crate::attention::compiled::{CompiledPattern, MemoryBudget, PatternBand, NO_CLUSTER};
use crate::util::json::Json;

/// A declarative sparse-attention scheme.  Always causal: every variant
/// only ever admits keys j <= i.  `Hash` (with the constructor
/// normalization) makes specs directly usable as compile-cache keys —
/// structural identity coincides with canonical-JSON identity.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AttentionSpec {
    /// Causal full attention: S_i = { j | j <= i }.
    Full,
    /// Sliding-window local attention: S_i = { j | i-w < j <= i }.
    Local { window: usize },
    /// Blocked local attention (the L1 kernel's semantics): query block b
    /// attends to blocks b-1 and b, causally.
    BlockLocal { window: usize },
    /// Strided attention (Child et al.): S_i = { j <= i | (i-j) % s == 0 }.
    Strided { stride: usize },
    /// Cluster routing (Algorithm 1): token i attends to j <= i iff some
    /// cluster selected both i and j.  Member lists are sorted + deduped.
    Routing { clusters: Vec<Vec<usize>> },
    /// Expert-choice routing (MoSA-style): clusters pick their
    /// top-`capacity` member tokens instead of tokens picking clusters,
    /// so every member list — and hence every cluster's per-row nnz
    /// contribution — is bounded by `capacity` *by construction*.
    /// Admission is otherwise routing-shaped: token i attends to j <= i
    /// iff some cluster selected both.  Member lists are sorted + deduped.
    ExpertChoice { clusters: Vec<Vec<usize>>, capacity: usize },
    /// Calibrated score-threshold attention (Condensate-style): row i's
    /// attend-set is whatever cleared the score cut (plus a per-row
    /// floor), stored explicitly so the spec stays `Eq + Hash` — see
    /// [`AttentionSpec::threshold_from_scores`] for the calibrated
    /// builder.  Rows are sorted + deduped, entries causal (`j <= i`);
    /// query rows beyond the stored length compile empty.
    Threshold { rows: Vec<Vec<usize>> },
    /// Mixed head plan: a key is admitted if any part admits it.
    Union(Vec<AttentionSpec>),
    /// A key is admitted only if every part admits it.
    Intersect(Vec<AttentionSpec>),
}

impl AttentionSpec {
    /// Causal full attention (constructor-style alias for
    /// [`AttentionSpec::Full`]).
    pub fn full() -> AttentionSpec {
        AttentionSpec::Full
    }

    /// Local attention; rejects `window == 0` (an empty window would make
    /// every S_i empty and used to underflow in the old pattern code).
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let local = AttentionSpec::local(4).unwrap();
    /// let p = local.compile(16);
    /// assert_eq!(p.row(10), &[7, 8, 9, 10]);
    /// assert!(AttentionSpec::local(0).is_err(), "degenerate windows are rejected");
    /// ```
    pub fn local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::Local { window })
    }

    /// Blocked local attention; rejects `window == 0` (block index i/w
    /// would divide by zero).
    pub fn block_local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("block-local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::BlockLocal { window })
    }

    /// Strided attention; rejects `stride == 0` ((i-j) % 0 is UB-shaped).
    pub fn strided(stride: usize) -> Result<AttentionSpec> {
        if stride == 0 {
            bail!("strided attention requires stride >= 1 (got 0)");
        }
        Ok(AttentionSpec::Strided { stride })
    }

    /// Routing from explicit cluster membership lists.  Members are
    /// normalized (sorted ascending, deduped); membership beyond the
    /// compiled sequence length is ignored at compile time.
    pub fn routing(clusters: Vec<Vec<usize>>) -> AttentionSpec {
        let clusters = clusters
            .into_iter()
            .map(|mut m| {
                m.sort_unstable();
                m.dedup();
                m
            })
            .collect();
        AttentionSpec::Routing { clusters }
    }

    /// The balanced-cluster idealization of the Section-4.1 model: k
    /// contiguous clusters of w = max(n/k, 1) tokens each (tail tokens
    /// beyond k*w stay unrouted, exactly as the closed-form model assumes).
    pub fn routing_balanced(n: usize, k: usize) -> Result<AttentionSpec> {
        if k == 0 {
            bail!("routing requires at least one cluster (got k = 0)");
        }
        let w = (n / k).max(1);
        let clusters = (0..k)
            .map(|c| (c * w..((c + 1) * w).min(n)).collect())
            .collect();
        Ok(AttentionSpec::routing(clusters))
    }

    /// Expert-choice routing from explicit per-cluster selections.
    /// Member lists are normalized (sorted ascending, deduped); any
    /// cluster still longer than `capacity` after dedup is rejected, so
    /// the capacity bound is an invariant of the value, not a compile-time
    /// clamp.  `capacity == 0` therefore requires every cluster to be
    /// empty.
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let spec = AttentionSpec::expert_choice(vec![vec![4, 1], vec![]], 2).unwrap();
    /// assert_eq!(spec.compile(8).row(4), &[1, 4]);
    /// assert!(AttentionSpec::expert_choice(vec![vec![0, 1, 2]], 2).is_err());
    /// ```
    pub fn expert_choice(clusters: Vec<Vec<usize>>, capacity: usize) -> Result<AttentionSpec> {
        let clusters: Vec<Vec<usize>> = clusters
            .into_iter()
            .map(|mut m| {
                m.sort_unstable();
                m.dedup();
                m
            })
            .collect();
        for (c, m) in clusters.iter().enumerate() {
            if m.len() > capacity {
                bail!(
                    "expert-choice cluster {c} selected {} tokens, over capacity {capacity}",
                    m.len()
                );
            }
        }
        Ok(AttentionSpec::ExpertChoice { clusters, capacity })
    }

    /// Score-threshold attention from explicit per-row attend-sets (the
    /// JSON decode path; [`AttentionSpec::threshold_from_scores`] is the
    /// calibrated builder).  Rows are normalized (sorted ascending,
    /// deduped); an acausal entry `j > i` is rejected.
    pub fn threshold(rows: Vec<Vec<usize>>) -> Result<AttentionSpec> {
        let rows: Vec<Vec<usize>> = rows
            .into_iter()
            .map(|mut r| {
                r.sort_unstable();
                r.dedup();
                r
            })
            .collect();
        for (i, r) in rows.iter().enumerate() {
            if let Some(&j) = r.last() {
                if j > i {
                    bail!("threshold row {i} admits acausal key {j}");
                }
            }
        }
        Ok(AttentionSpec::Threshold { rows })
    }

    /// Calibrated score-threshold attention: for each query row i of the
    /// row-major `[n, n]` score matrix, admit key `j <= i` iff
    /// `scores[i*n + j]` is finite and `>= cut`; if fewer than `floor`
    /// keys cleared the cut, top up with the highest-scoring finite keys
    /// below it (score-descending, index-ascending tie-break) so no query
    /// row is empty unless every causal score is non-finite.  NaN and
    /// ±inf scores are quarantined — never admitted, by the cut or by the
    /// floor.  Rejects a non-finite `cut` and a wrong-sized matrix.
    pub fn threshold_from_scores(
        scores: &[f32],
        n: usize,
        cut: f32,
        floor: usize,
    ) -> Result<AttentionSpec> {
        if !cut.is_finite() {
            bail!("threshold cut must be finite (got {cut})");
        }
        if scores.len() != n * n {
            bail!("threshold scores must be [n, n] = {} values (got {})", n * n, scores.len());
        }
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut scored: Vec<(f32, usize)> = (0..=i)
                .filter_map(|j| {
                    let s = scores[i * n + j];
                    s.is_finite().then_some((s, j))
                })
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            // finite scores sort descending, so the cut keeps a prefix;
            // the floor widens that prefix (never past the finite set)
            let above = scored.partition_point(|&(s, _)| s >= cut);
            let keep = above.max(floor.min(scored.len()));
            let mut row: Vec<usize> = scored[..keep].iter().map(|&(_, j)| j).collect();
            row.sort_unstable();
            rows.push(row);
        }
        Ok(AttentionSpec::Threshold { rows })
    }

    /// Mixed head plan: union of the parts' index sets.
    pub fn union(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("union of zero specs is undefined");
        }
        Ok(AttentionSpec::Union(parts))
    }

    /// Intersection of the parts' index sets.
    pub fn intersect(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("intersection of zero specs is undefined");
        }
        Ok(AttentionSpec::Intersect(parts))
    }

    /// Compile the spec for sequence length `n` into a CSR index set.
    /// Infallible: constructors validate parameters; hand-built enum
    /// values with zero windows/strides are clamped to 1 defensively.
    /// `n = 0` compiles to an empty pattern.
    pub fn compile(&self, n: usize) -> CompiledPattern {
        CompiledPattern::from_rows(n, build_rows(self, n))
    }

    /// Compile only the query rows in `row_range` (clamped to `0..n`,
    /// same contract as [`CompiledPattern::rows`]) into a
    /// [`PatternBand`].  Because every row built by this module depends
    /// only on its absolute index, the band is bit-identical to the
    /// matching slice of `self.compile(n)` — the property the banded
    /// long-context path rests on, pinned in `tests/proptests.rs`.
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let spec = AttentionSpec::local(4).unwrap();
    /// let band = spec.compile_band(1 << 20, 777..779);
    /// assert_eq!(band.row(777), spec.compile(1024).row(777));
    /// assert!(band.heap_bytes() < 1 << 10, "only the band is resident");
    /// ```
    pub fn compile_band(&self, n: usize, row_range: Range<usize>) -> PatternBand {
        let end = row_range.end.min(n);
        let start = row_range.start.min(end);
        PatternBand::from_rows(n, start, build_rows_range(self, n, start..end))
    }

    /// JSON encoding of the spec (declarative, nestable).
    pub fn to_json(&self) -> Json {
        let kind = |k: &str| ("kind".to_string(), Json::Str(k.to_string()));
        match self {
            AttentionSpec::Full => Json::Obj(vec![kind("full")]),
            AttentionSpec::Local { window } => Json::Obj(vec![
                kind("local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::BlockLocal { window } => Json::Obj(vec![
                kind("block_local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::Strided { stride } => Json::Obj(vec![
                kind("strided"),
                ("stride".to_string(), Json::Num(*stride as f64)),
            ]),
            AttentionSpec::Routing { clusters } => Json::Obj(vec![
                kind("routing"),
                (
                    "clusters".to_string(),
                    Json::Arr(
                        clusters
                            .iter()
                            .map(|m| {
                                Json::Arr(m.iter().map(|&i| Json::Num(i as f64)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            AttentionSpec::ExpertChoice { clusters, capacity } => Json::Obj(vec![
                kind("expert_choice"),
                (
                    "clusters".to_string(),
                    Json::Arr(
                        clusters
                            .iter()
                            .map(|m| {
                                Json::Arr(m.iter().map(|&i| Json::Num(i as f64)).collect())
                            })
                            .collect(),
                    ),
                ),
                ("capacity".to_string(), Json::Num(*capacity as f64)),
            ]),
            AttentionSpec::Threshold { rows } => Json::Obj(vec![
                kind("threshold"),
                (
                    "rows".to_string(),
                    Json::Arr(
                        rows.iter()
                            .map(|r| {
                                Json::Arr(r.iter().map(|&j| Json::Num(j as f64)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            AttentionSpec::Union(parts) => Json::Obj(vec![
                kind("union"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
            AttentionSpec::Intersect(parts) => Json::Obj(vec![
                kind("intersect"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
        }
    }

    /// Decode a spec from JSON, re-running constructor validation.
    pub fn from_json(j: &Json) -> Result<AttentionSpec> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("attention spec json missing string 'kind'"))?;
        let field = |name: &str| -> Result<usize> {
            j.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("spec '{kind}' missing integer '{name}'"))
        };
        let parts = |name: &str| -> Result<Vec<AttentionSpec>> {
            j.get(name)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec '{kind}' missing array '{name}'"))?
                .iter()
                .map(AttentionSpec::from_json)
                .collect()
        };
        let lists = |name: &str| -> Result<Vec<Vec<usize>>> {
            j.get(name)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec '{kind}' missing array '{name}'"))?
                .iter()
                .map(|m| {
                    m.as_arr()
                        .ok_or_else(|| anyhow!("spec '{kind}' '{name}' entry must be an array"))?
                        .iter()
                        .map(|v| {
                            v.as_usize().ok_or_else(|| {
                                anyhow!("spec '{kind}' '{name}' member must be an integer")
                            })
                        })
                        .collect::<Result<Vec<usize>>>()
                })
                .collect()
        };
        match kind {
            "full" => Ok(AttentionSpec::Full),
            "local" => AttentionSpec::local(field("window")?),
            "block_local" => AttentionSpec::block_local(field("window")?),
            "strided" => AttentionSpec::strided(field("stride")?),
            "routing" => Ok(AttentionSpec::routing(lists("clusters")?)),
            "expert_choice" => AttentionSpec::expert_choice(lists("clusters")?, field("capacity")?),
            "threshold" => AttentionSpec::threshold(lists("rows")?),
            "union" => AttentionSpec::union(parts("parts")?),
            "intersect" => AttentionSpec::intersect(parts("parts")?),
            other => bail!("unknown attention spec kind '{other}'"),
        }
    }
}

/// Per-query (key, cluster-id) rows, sorted by key and deduped — the
/// intermediate representation `CompiledPattern::from_rows` packs into CSR.
fn build_rows(spec: &AttentionSpec, n: usize) -> Vec<Vec<(usize, u32)>> {
    build_rows_range(spec, n, 0..n)
}

/// [`build_rows`] restricted to query rows `range` (callers pass a
/// clamped `range ⊆ 0..n`; element r of the result is absolute row
/// `range.start + r`).  Every arm keys row content on the *absolute* row
/// index and postprocesses per row, which is what makes a band compile
/// bit-identical to the matching monolithic slice.
fn build_rows_range(
    spec: &AttentionSpec,
    n: usize,
    range: Range<usize>,
) -> Vec<Vec<(usize, u32)>> {
    debug_assert!(range.start <= range.end && range.end <= n);
    match spec {
        AttentionSpec::Full => {
            range.map(|i| (0..=i).map(|j| (j, NO_CLUSTER)).collect()).collect()
        }
        AttentionSpec::Local { window } => {
            let w = (*window).max(1);
            range
                .map(|i| {
                    (i.saturating_sub(w - 1)..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::BlockLocal { window } => {
            let w = (*window).max(1);
            range
                .map(|i| {
                    let start = (i / w).saturating_sub(1) * w;
                    (start..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::Strided { stride } => {
            let s = (*stride).max(1);
            range
                .map(|i| (i % s..=i).step_by(s).map(|j| (j, NO_CLUSTER)).collect())
                .collect()
        }
        AttentionSpec::Routing { clusters } => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); range.len()];
            for (c, members) in clusters.iter().enumerate() {
                // constructors normalize, but hand-built enums may not be
                // sorted/deduped/in-range — renormalize defensively
                let mut ms: Vec<usize> = members.iter().copied().filter(|&i| i < n).collect();
                ms.sort_unstable();
                ms.dedup();
                for (idx, &i) in ms.iter().enumerate() {
                    if !range.contains(&i) {
                        continue;
                    }
                    for &j in &ms[..=idx] {
                        rows[i - range.start].push((j, c as u32));
                    }
                }
            }
            for row in &mut rows {
                // sort by key then cluster; dedup keeps the lowest cluster
                // id for a key selected by several clusters (the renderer's
                // "first matching cluster" convention)
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::ExpertChoice { clusters, capacity } => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); range.len()];
            for (c, members) in clusters.iter().enumerate() {
                // constructors normalize and enforce the capacity bound,
                // but hand-built enums may not — renormalize and truncate
                // defensively (keyed only on n, so bands stay identical)
                let mut ms: Vec<usize> = members.iter().copied().filter(|&i| i < n).collect();
                ms.sort_unstable();
                ms.dedup();
                ms.truncate(*capacity);
                for (idx, &i) in ms.iter().enumerate() {
                    if !range.contains(&i) {
                        continue;
                    }
                    for &j in &ms[..=idx] {
                        rows[i - range.start].push((j, c as u32));
                    }
                }
            }
            for row in &mut rows {
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Threshold { rows: sets } => range
            .map(|i| {
                // constructors normalize (sorted, deduped, causal), but
                // hand-built enums may not — refilter per absolute row
                let mut row: Vec<(usize, u32)> = sets
                    .get(i)
                    .map(|r| {
                        r.iter()
                            .copied()
                            .filter(|&j| j <= i && j < n)
                            .map(|j| (j, NO_CLUSTER))
                            .collect()
                    })
                    .unwrap_or_default();
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
                row
            })
            .collect(),
        AttentionSpec::Union(parts) => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); range.len()];
            for part in parts {
                let prows = build_rows_range(part, n, range.clone());
                for (row, prow) in rows.iter_mut().zip(prows) {
                    row.extend(prow);
                }
            }
            for row in &mut rows {
                // NO_CLUSTER sorts last, so routed entries keep their
                // cluster id when a key is admitted by several parts
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Intersect(parts) => {
            let mut iter = parts.iter();
            let first = match iter.next() {
                // empty intersection = no constraint (matches `all()`)
                None => return build_rows_range(&AttentionSpec::Full, n, range),
                Some(p) => p,
            };
            let mut rows = build_rows_range(first, n, range.clone());
            for part in iter {
                let prows = build_rows_range(part, n, range.clone());
                for (row, prow) in rows.iter_mut().zip(&prows) {
                    let mut out = Vec::new();
                    let (mut a, mut b) = (0usize, 0usize);
                    while a < row.len() && b < prow.len() {
                        match row[a].0.cmp(&prow[b].0) {
                            std::cmp::Ordering::Less => a += 1,
                            std::cmp::Ordering::Greater => b += 1,
                            std::cmp::Ordering::Equal => {
                                out.push((row[a].0, row[a].1.min(prow[b].1)));
                                a += 1;
                                b += 1;
                            }
                        }
                    }
                    *row = out;
                }
            }
            rows
        }
    }
}

/// A compiled pattern served from lazily compiled row bands under a
/// [`MemoryBudget`] — the long-context replacement for holding one
/// monolithic [`CompiledPattern`] resident.
///
/// The sequence is split into `ceil(n / band_rows)` contiguous bands;
/// [`rows`](Self::rows) / [`row`](Self::row) / [`nnz`](Self::nnz) /
/// [`cost`](Self::cost) keep the `CompiledPattern` API shape but compile
/// bands on first touch ([`AttentionSpec::compile_band`]) and LRU-spill
/// resident bands whenever the shared budget is over. Bands touched by
/// the in-flight call are never its own spill victims, so the budget is
/// a soft cap: peak residency can exceed it by the protected band(s).
/// Evaluation streams bands through any [`Backend`] unchanged
/// ([`attention_backend`](Self::attention_backend)) by padding each band
/// to an n-row pattern whose out-of-band rows are empty — bit-identical
/// output to evaluating the monolithic compile.
#[derive(Debug)]
pub struct ChunkedPattern {
    spec: AttentionSpec,
    n: usize,
    band_rows: usize,
    /// `ceil(n / band_rows)` slots; `None` = not resident.
    bands: Vec<Option<PatternBand>>,
    /// LRU clock per band (0 = never touched).
    last_used: Vec<u64>,
    tick: u64,
    budget: MemoryBudget,
    /// Cached total nnz once every band has been visited at least once.
    total_nnz: Option<usize>,
    band_compiles: u64,
    band_evictions: u64,
    bytes_evicted: u64,
}

impl ChunkedPattern {
    /// Serve `spec` at sequence length `n` from bands of `band_rows`
    /// query rows (clamped to >= 1), metering residency against
    /// `budget`.  Nothing is compiled until first touch.
    pub fn new(
        spec: AttentionSpec,
        n: usize,
        band_rows: usize,
        budget: MemoryBudget,
    ) -> ChunkedPattern {
        let band_rows = band_rows.max(1);
        let num_bands = n.div_ceil(band_rows);
        ChunkedPattern {
            spec,
            n,
            band_rows,
            bands: (0..num_bands).map(|_| None).collect(),
            last_used: vec![0; num_bands],
            tick: 0,
            budget,
            total_nnz: None,
            band_compiles: 0,
            band_evictions: 0,
            bytes_evicted: 0,
        }
    }

    /// Sequence length served.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Query rows per band (last band may be shorter).
    pub fn band_rows(&self) -> usize {
        self.band_rows
    }

    /// Total number of bands (`ceil(n / band_rows)`).
    pub fn num_bands(&self) -> usize {
        self.bands.len()
    }

    /// The spec being served.
    pub fn spec(&self) -> &AttentionSpec {
        &self.spec
    }

    /// The shared byte meter this pattern charges.
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// Bands compiled so far (recompiles after eviction count again).
    pub fn band_compiles(&self) -> u64 {
        self.band_compiles
    }

    /// Bands spilled to stay under budget.
    pub fn band_evictions(&self) -> u64 {
        self.band_evictions
    }

    /// Total bytes freed by spills.
    pub fn bytes_evicted(&self) -> u64 {
        self.bytes_evicted
    }

    /// Bands currently resident.
    pub fn resident_bands(&self) -> usize {
        self.bands.iter().filter(|b| b.is_some()).count()
    }

    /// Heap bytes of the currently resident bands.
    pub fn resident_bytes(&self) -> usize {
        self.bands.iter().flatten().map(PatternBand::heap_bytes).sum()
    }

    /// Make band `b` resident (compiling if spilled), bump its LRU
    /// clock, then spill over-budget bands outside `protected`.
    fn ensure_band(&mut self, b: usize, protected: Range<usize>) {
        self.tick += 1;
        self.last_used[b] = self.tick;
        if self.bands[b].is_none() {
            let start = b * self.band_rows;
            let end = ((b + 1) * self.band_rows).min(self.n);
            let band = self.spec.compile_band(self.n, start..end);
            self.budget.charge(band.heap_bytes());
            self.band_compiles += 1;
            self.bands[b] = Some(band);
        }
        self.spill(protected);
    }

    /// LRU-spill resident bands outside `protected` until the shared
    /// budget is satisfied (or only protected bands remain — the soft
    /// cap).
    fn spill(&mut self, protected: Range<usize>) {
        while self.budget.over_budget() {
            let victim = self
                .bands
                .iter()
                .enumerate()
                .filter(|&(i, band)| band.is_some() && !protected.contains(&i))
                .min_by_key(|&(i, _)| self.last_used[i])
                .map(|(i, _)| i);
            let Some(v) = victim else { break };
            let bytes = self.bands[v].take().expect("victim is resident").heap_bytes();
            self.budget.release(bytes);
            self.band_evictions += 1;
            self.bytes_evicted += bytes as u64;
        }
    }

    /// Band index owning absolute row `i < n`.
    fn band_of(&self, i: usize) -> usize {
        i / self.band_rows
    }

    /// Attend-set for absolute row `i` (empty for `i >= n`), compiling
    /// the owning band on demand — same contract as
    /// [`CompiledPattern::row`].
    pub fn row(&mut self, i: usize) -> &[usize] {
        if i >= self.n {
            return &[];
        }
        let b = self.band_of(i);
        self.ensure_band(b, b..b + 1);
        self.bands[b].as_ref().expect("band just ensured resident").row(i)
    }

    /// Iterate `(i, keys, clusters)` over `range` (clamped to `0..n`,
    /// same contract as [`CompiledPattern::rows`]); every band the range
    /// touches is made resident first and protected from spilling for
    /// the duration of the borrow.
    pub fn rows(&mut self, range: Range<usize>) -> ChunkedRowIter<'_> {
        let end = range.end.min(self.n);
        let start = range.start.min(end);
        if start < end {
            let b0 = self.band_of(start);
            let b1 = self.band_of(end - 1);
            for b in b0..=b1 {
                self.ensure_band(b, b0..b1 + 1);
            }
        }
        ChunkedRowIter { pattern: self, range: start..end }
    }

    /// Total non-zero entries; the first call streams every band through
    /// residency once (spilling as it goes), later calls are O(1).
    pub fn nnz(&mut self) -> usize {
        if let Some(total) = self.total_nnz {
            return total;
        }
        let mut total = 0usize;
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            total += self.bands[b].as_ref().expect("resident").nnz();
        }
        self.total_nnz = Some(total);
        total
    }

    /// Exact MAC count (`2 · nnz · d`, saturating) — same model as
    /// [`CompiledPattern::cost`].
    pub fn cost(&mut self, d: usize) -> u64 {
        u64::try_from(2u128 * self.nnz() as u128 * d as u128).unwrap_or(u64::MAX)
    }

    /// Evaluate the whole pattern with `backend`, streaming band by band
    /// so only O(band) pattern bytes are resident at once: each band is
    /// padded to an n-row pattern (out-of-band rows empty) and handed to
    /// [`Backend::attention_rows`] over exactly its row range, which
    /// touches the same CSR slices the monolithic pattern would — the
    /// output is bit-identical to `backend.attention` on
    /// `self.spec.compile(self.n)`.
    pub fn attention_backend(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        backend: &dyn Backend,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.n * d];
        let mut total = 0usize;
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            let band = self.bands[b].as_ref().expect("resident");
            let (start, end) = (band.start(), band.end());
            total += band.nnz();
            let padded = band.to_pattern();
            backend.attention_rows(q, k, v, d, &padded, start..end, &mut out[start * d..end * d])?;
        }
        self.total_nnz = Some(total);
        Ok(out)
    }

    /// Concatenate every band into a monolithic [`CompiledPattern`]
    /// (bit-identical to `self.spec.compile(self.n)`; used by the
    /// equivalence tests).  Materializes O(n) memory by definition.
    pub fn assemble(&mut self) -> CompiledPattern {
        let mut row_offsets = Vec::with_capacity(self.n + 1);
        row_offsets.push(0usize);
        let mut cols = Vec::new();
        let mut cluster_ids = Vec::new();
        for b in 0..self.num_bands() {
            self.ensure_band(b, b..b + 1);
            let band = self.bands[b].as_ref().expect("resident");
            for i in band.start()..band.end() {
                cols.extend_from_slice(band.row(i));
                cluster_ids.extend_from_slice(band.row_clusters(i));
                row_offsets.push(cols.len());
            }
        }
        self.total_nnz = Some(cols.len());
        CompiledPattern::from_parts(self.n, row_offsets, cols, cluster_ids)
    }
}

impl Drop for ChunkedPattern {
    /// Releasing the budget charge on drop is what lets serve GC count
    /// retired sequences' pattern bytes as reclaimed.
    fn drop(&mut self) {
        for band in self.bands.iter_mut() {
            if let Some(b) = band.take() {
                self.budget.release(b.heap_bytes());
            }
        }
    }
}

/// Iterator over `(i, keys, clusters)` rows of a [`ChunkedPattern`]; see
/// [`ChunkedPattern::rows`].
#[derive(Debug)]
pub struct ChunkedRowIter<'a> {
    pattern: &'a ChunkedPattern,
    range: Range<usize>,
}

impl<'a> Iterator for ChunkedRowIter<'a> {
    type Item = (usize, &'a [usize], &'a [u32]);

    fn next(&mut self) -> Option<Self::Item> {
        let i = self.range.next()?;
        let band = self.pattern.bands[self.pattern.band_of(i)]
            .as_ref()
            .expect("rows() made every band in range resident");
        Some((i, band.row(i), band.row_clusters(i)))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.range.size_hint()
    }
}

impl<'a> ExactSizeIterator for ChunkedRowIter<'a> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degenerate_params_rejected() {
        assert!(AttentionSpec::local(0).is_err());
        assert!(AttentionSpec::block_local(0).is_err());
        assert!(AttentionSpec::strided(0).is_err());
        assert!(AttentionSpec::routing_balanced(16, 0).is_err());
        assert!(AttentionSpec::union(vec![]).is_err());
        assert!(AttentionSpec::intersect(vec![]).is_err());
        assert!(AttentionSpec::local(1).is_ok());
        assert!(AttentionSpec::strided(1).is_ok());
    }

    #[test]
    fn hand_built_zero_params_clamp_instead_of_panicking() {
        // direct enum construction bypasses validation; compile must clamp
        let p = AttentionSpec::Local { window: 0 }.compile(4);
        assert_eq!(p.row(2), &[2]);
        let p = AttentionSpec::Strided { stride: 0 }.compile(4);
        assert_eq!(p.row(3), &[0, 1, 2, 3]);
        // clamped to blocks of 1: each query sees itself and its
        // predecessor, so rows are {0}, {0,1}, {1,2}, {2,3}
        let p = AttentionSpec::BlockLocal { window: 0 }.compile(4);
        assert_eq!(p.row(3), &[2, 3]);
        assert_eq!(p.nnz(), 7);
    }

    #[test]
    fn routing_normalizes_members() {
        let spec = AttentionSpec::routing(vec![vec![5, 2, 2, 0]]);
        match &spec {
            AttentionSpec::Routing { clusters } => assert_eq!(clusters[0], vec![0, 2, 5]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn routing_balanced_covers_prefix() {
        let spec = AttentionSpec::routing_balanced(10, 3).unwrap();
        match &spec {
            AttentionSpec::Routing { clusters } => {
                assert_eq!(clusters.len(), 3);
                // w = 3; tail token 9 stays unrouted, as the model assumes
                assert_eq!(clusters[0], vec![0, 1, 2]);
                assert_eq!(clusters[2], vec![6, 7, 8]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn expert_choice_normalizes_and_enforces_capacity() {
        let spec = AttentionSpec::expert_choice(vec![vec![5, 2, 2, 0], vec![]], 3).unwrap();
        match &spec {
            AttentionSpec::ExpertChoice { clusters, capacity } => {
                assert_eq!(clusters[0], vec![0, 2, 5]);
                assert_eq!(clusters[1], Vec::<usize>::new());
                assert_eq!(*capacity, 3);
            }
            _ => unreachable!(),
        }
        // dedup can rescue an over-long list; a genuinely over-capacity one fails
        assert!(AttentionSpec::expert_choice(vec![vec![1, 1, 1, 1]], 1).is_ok());
        assert!(AttentionSpec::expert_choice(vec![vec![0, 1]], 1).is_err());
        assert!(AttentionSpec::expert_choice(vec![vec![7]], 0).is_err());
        assert!(AttentionSpec::expert_choice(vec![vec![], vec![]], 0).is_ok());
        // compiles routing-shaped: row of the latest member covers the cluster
        let p = spec.compile(8);
        assert_eq!(p.row(5), &[0, 2, 5]);
        assert_eq!(p.row(2), &[0, 2]);
        assert_eq!(p.row(1), &[] as &[usize]);
    }

    #[test]
    fn hand_built_expert_choice_clamps_to_capacity() {
        // direct enum construction bypasses validation; compile truncates
        let p = AttentionSpec::ExpertChoice { clusters: vec![vec![0, 1, 2, 3]], capacity: 2 }
            .compile(8);
        assert_eq!(p.row(1), &[0, 1]);
        assert_eq!(p.row(3), &[] as &[usize], "members past capacity are dropped");
    }

    #[test]
    fn threshold_from_scores_cut_floor_and_quarantine() {
        let n = 4;
        let mut scores = vec![0f32; n * n];
        // row 2: j=0 clears the cut, j=1 doesn't, j=2 (self) is NaN
        scores[2 * n] = 1.0;
        scores[2 * n + 1] = 0.2;
        scores[2 * n + 2] = f32::NAN;
        // row 3: nothing clears the cut; floor rescues the best finite keys
        scores[3 * n] = 0.3;
        scores[3 * n + 1] = f32::INFINITY;
        scores[3 * n + 2] = 0.1;
        scores[3 * n + 3] = f32::NEG_INFINITY;
        let spec = AttentionSpec::threshold_from_scores(&scores, n, 0.5, 2).unwrap();
        let p = spec.compile(n);
        assert_eq!(p.row(0), &[0], "zero score meets the floor");
        assert_eq!(p.row(2), &[0, 1], "floor tops up below-cut keys; NaN never admitted");
        assert_eq!(p.row(3), &[0, 2], "±inf quarantined even when the floor is hungry");

        assert!(AttentionSpec::threshold_from_scores(&scores, n, f32::NAN, 1).is_err());
        assert!(AttentionSpec::threshold_from_scores(&scores, 3, 0.0, 1).is_err());
        // all-non-finite rows stay empty: no finite candidate to rescue
        let bad = vec![f32::NAN; 4];
        let spec = AttentionSpec::threshold_from_scores(&bad, 2, 0.0, 5).unwrap();
        assert_eq!(spec.compile(2).nnz(), 0);
        // explicit rows reject acausal entries
        assert!(AttentionSpec::threshold(vec![vec![0], vec![2]]).is_err());
        assert!(AttentionSpec::threshold(vec![vec![0], vec![1, 0]]).is_ok());
    }

    #[test]
    fn threshold_floor_breaks_score_ties_by_index() {
        // three equal scores, floor 2: the two lowest indices win
        let scores = vec![0.5f32; 9];
        let spec = AttentionSpec::threshold_from_scores(&scores, 3, 1.0, 2).unwrap();
        assert_eq!(spec.compile(3).row(2), &[0, 1]);
    }

    #[test]
    fn json_roundtrip_nested() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(8).unwrap(),
            AttentionSpec::routing(vec![vec![0, 3, 9], vec![1, 2]]),
            AttentionSpec::expert_choice(vec![vec![4, 7], vec![5]], 2).unwrap(),
            AttentionSpec::threshold(vec![vec![0], vec![0, 1], vec![2]]).unwrap(),
            AttentionSpec::intersect(vec![
                AttentionSpec::Full,
                AttentionSpec::strided(4).unwrap(),
            ])
            .unwrap(),
        ])
        .unwrap();
        let text = spec.to_json().to_string();
        let back = AttentionSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn compile_band_slices_match_monolithic() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::block_local(3).unwrap(),
            AttentionSpec::routing(vec![vec![0, 4, 9, 13], vec![2, 6, 11]]),
            AttentionSpec::expert_choice(vec![vec![1, 8, 14], vec![3, 10]], 3).unwrap(),
            AttentionSpec::threshold(vec![vec![0], vec![], vec![0, 2], vec![1, 3], vec![0, 4]])
                .unwrap(),
        ])
        .unwrap();
        let n = 17;
        let mono = spec.compile(n);
        // 5..8 straddles a BlockLocal block boundary (blocks 1 and 2)
        for range in [0..n, 5..8, 0..0, 9..9, 16..17, 12..40] {
            let band = spec.compile_band(n, range.clone());
            assert_eq!(band.start(), range.start.min(n));
            for i in band.start()..band.end() {
                assert_eq!(band.row(i), mono.row(i), "row {i} of band {range:?}");
                assert_eq!(band.row_clusters(i), mono.row_clusters(i));
            }
        }
        assert!(spec.compile_band(0, 0..10).is_empty());
    }

    #[test]
    fn chunked_pattern_matches_monolithic_under_tiny_budget() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(5).unwrap(),
            AttentionSpec::routing_balanced(64, 8).unwrap(),
        ])
        .unwrap();
        let n = 64;
        let mono = spec.compile(n);
        // budget far below the monolithic footprint forces real churn
        let budget = MemoryBudget::bytes(mono.heap_bytes() / 4);
        let mut chunked = ChunkedPattern::new(spec, n, 7, budget.clone());
        assert_eq!(chunked.num_bands(), 10);
        assert_eq!(chunked.resident_bands(), 0, "lazy until first touch");

        assert_eq!(chunked.nnz(), mono.nnz());
        assert_eq!(chunked.cost(16), mono.cost(16));
        for i in [0, 3, 40, 63, 64, 1000] {
            assert_eq!(chunked.row(i), mono.row(i));
        }
        let got: Vec<(usize, Vec<usize>, Vec<u32>)> =
            chunked.rows(10..30).map(|(i, ks, cs)| (i, ks.to_vec(), cs.to_vec())).collect();
        let want: Vec<(usize, Vec<usize>, Vec<u32>)> =
            mono.rows(10..30).map(|(i, ks, cs)| (i, ks.to_vec(), cs.to_vec())).collect();
        assert_eq!(got, want);
        assert_eq!(chunked.assemble(), mono, "band concatenation is bit-identical");

        assert!(chunked.band_compiles() > 10, "eviction churn forces recompiles");
        assert!(chunked.band_evictions() > 0);
        assert!(chunked.bytes_evicted() > 0);
        assert_eq!(budget.resident(), chunked.resident_bytes());
        // soft cap: only protected bands ride above the budget, and the
        // widest protected window above was rows(10..30) = 4 bands
        let max_band = (0..chunked.num_bands())
            .map(|b| chunked.spec().compile_band(n, b * 7..(b + 1) * 7).heap_bytes())
            .max()
            .unwrap();
        assert!(
            budget.peak() <= budget.max_bytes().unwrap() + 4 * max_band,
            "peak {} exceeds budget {} + 4 protected bands of {}",
            budget.peak(),
            budget.max_bytes().unwrap(),
            max_band
        );

        drop(chunked);
        assert_eq!(budget.resident(), 0, "drop releases every resident charge");
    }

    #[test]
    fn chunked_attention_is_bit_identical_to_monolithic() {
        use crate::attention::backend::Reference;
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(6).unwrap(),
            AttentionSpec::routing_balanced(48, 6).unwrap(),
        ])
        .unwrap();
        let (n, d) = (48, 4);
        let mut x = 0x9E37u32;
        let mut gen = || {
            x = x.wrapping_mul(0x0101_9E3B).wrapping_add(12345);
            (x >> 8) as f32 / (1 << 24) as f32 - 0.5
        };
        let q: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let k: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let v: Vec<f32> = (0..n * d).map(|_| gen()).collect();
        let mono = spec.compile(n);
        let want = Reference.attention(&q, &k, &v, d, &mono).unwrap();
        let mut chunked =
            ChunkedPattern::new(spec, n, 5, MemoryBudget::bytes(mono.heap_bytes() / 5));
        let got = chunked.attention_backend(&q, &k, &v, d, &Reference).unwrap();
        assert_eq!(got, want, "banded evaluation must be bit-identical");
        assert_eq!(chunked.nnz(), mono.nnz(), "nnz set for free during the sweep");

        let mut empty = ChunkedPattern::new(AttentionSpec::Full, 0, 4, MemoryBudget::unbounded());
        assert_eq!(empty.num_bands(), 0);
        assert_eq!(empty.nnz(), 0);
        assert!(empty.attention_backend(&[], &[], &[], d, &Reference).unwrap().is_empty());
    }

    #[test]
    fn from_json_rejects_bad_specs() {
        for bad in [
            r#"{"kind":"warp"}"#,
            r#"{"kind":"local"}"#,
            r#"{"kind":"local","window":0}"#,
            r#"{"window":3}"#,
            // fractional / negative params used to be silently truncated
            // or saturated by the lossy `as` casts in Json::as_usize
            r#"{"kind":"local","window":2.7}"#,
            r#"{"kind":"local","window":-1}"#,
            r#"{"kind":"strided","stride":3.5}"#,
            r#"{"kind":"block_local","window":1e30}"#,
            r#"{"kind":"routing","clusters":[[0,1.5]]}"#,
            r#"{"kind":"routing","clusters":[[-2,1]]}"#,
            // expert-choice: capacity is mandatory and a hard bound
            r#"{"kind":"expert_choice","clusters":[[0,1]]}"#,
            r#"{"kind":"expert_choice","clusters":[[0,1,2]],"capacity":2}"#,
            r#"{"kind":"expert_choice","clusters":[[0,1]],"capacity":2.5}"#,
            r#"{"kind":"expert_choice","clusters":[[0,-1]],"capacity":2}"#,
            // threshold: rows must be causal integer sets
            r#"{"kind":"threshold"}"#,
            r#"{"kind":"threshold","rows":[[0],[3]]}"#,
            r#"{"kind":"threshold","rows":[[0.5]]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(AttentionSpec::from_json(&j).is_err(), "accepted {bad}");
        }
    }
}

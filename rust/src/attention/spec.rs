//! Declarative attention-sparsity specs — phase one of the spec→compile
//! pipeline.
//!
//! An [`AttentionSpec`] describes *which* scheme restricts each query's
//! key set S_i (Sec. 3 of the paper), without fixing a sequence length:
//! causal full attention, (blocked) local attention, strided attention
//! (Child et al. 2019), content-routed attention (Algorithm 1), and
//! `Union`/`Intersect` composition for the mixed head plans of Sec. 4.2
//! (the paper's best models mix local and routing heads).  Constructors
//! validate degenerate parameters (zero windows/strides used to mean
//! divide-by-zero); [`AttentionSpec::compile`] materializes the spec for a
//! sequence length into a [`CompiledPattern`] CSR index set; and
//! [`AttentionSpec::flops_estimate`] keeps the closed-form Section-4.1
//! asymptotic cost model (`O(nkd + n²d/k)`, minimized at k ≈ √n).
//!
//! Specs serialize to/from JSON (`to_json`/`from_json`) so head plans can
//! live in manifests and configs.

use anyhow::{anyhow, bail, Result};

use crate::attention::compiled::{CompiledPattern, NO_CLUSTER};
use crate::util::json::Json;

/// A declarative sparse-attention scheme.  Always causal: every variant
/// only ever admits keys j <= i.  `Hash` (with the constructor
/// normalization) makes specs directly usable as compile-cache keys —
/// structural identity coincides with canonical-JSON identity.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AttentionSpec {
    /// Causal full attention: S_i = { j | j <= i }.
    Full,
    /// Sliding-window local attention: S_i = { j | i-w < j <= i }.
    Local { window: usize },
    /// Blocked local attention (the L1 kernel's semantics): query block b
    /// attends to blocks b-1 and b, causally.
    BlockLocal { window: usize },
    /// Strided attention (Child et al.): S_i = { j <= i | (i-j) % s == 0 }.
    Strided { stride: usize },
    /// Cluster routing (Algorithm 1): token i attends to j <= i iff some
    /// cluster selected both i and j.  Member lists are sorted + deduped.
    Routing { clusters: Vec<Vec<usize>> },
    /// Mixed head plan: a key is admitted if any part admits it.
    Union(Vec<AttentionSpec>),
    /// A key is admitted only if every part admits it.
    Intersect(Vec<AttentionSpec>),
}

impl AttentionSpec {
    /// Causal full attention (constructor-style alias for
    /// [`AttentionSpec::Full`]).
    pub fn full() -> AttentionSpec {
        AttentionSpec::Full
    }

    /// Local attention; rejects `window == 0` (an empty window would make
    /// every S_i empty and used to underflow in the old pattern code).
    ///
    /// ```
    /// use routing_transformer::attention::AttentionSpec;
    /// let local = AttentionSpec::local(4).unwrap();
    /// let p = local.compile(16);
    /// assert_eq!(p.row(10), &[7, 8, 9, 10]);
    /// assert!(AttentionSpec::local(0).is_err(), "degenerate windows are rejected");
    /// ```
    pub fn local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::Local { window })
    }

    /// Blocked local attention; rejects `window == 0` (block index i/w
    /// would divide by zero).
    pub fn block_local(window: usize) -> Result<AttentionSpec> {
        if window == 0 {
            bail!("block-local attention requires window >= 1 (got 0)");
        }
        Ok(AttentionSpec::BlockLocal { window })
    }

    /// Strided attention; rejects `stride == 0` ((i-j) % 0 is UB-shaped).
    pub fn strided(stride: usize) -> Result<AttentionSpec> {
        if stride == 0 {
            bail!("strided attention requires stride >= 1 (got 0)");
        }
        Ok(AttentionSpec::Strided { stride })
    }

    /// Routing from explicit cluster membership lists.  Members are
    /// normalized (sorted ascending, deduped); membership beyond the
    /// compiled sequence length is ignored at compile time.
    pub fn routing(clusters: Vec<Vec<usize>>) -> AttentionSpec {
        let clusters = clusters
            .into_iter()
            .map(|mut m| {
                m.sort_unstable();
                m.dedup();
                m
            })
            .collect();
        AttentionSpec::Routing { clusters }
    }

    /// The balanced-cluster idealization of the Section-4.1 model: k
    /// contiguous clusters of w = max(n/k, 1) tokens each (tail tokens
    /// beyond k*w stay unrouted, exactly as the closed-form model assumes).
    pub fn routing_balanced(n: usize, k: usize) -> Result<AttentionSpec> {
        if k == 0 {
            bail!("routing requires at least one cluster (got k = 0)");
        }
        let w = (n / k).max(1);
        let clusters = (0..k)
            .map(|c| (c * w..((c + 1) * w).min(n)).collect())
            .collect();
        Ok(AttentionSpec::routing(clusters))
    }

    /// Mixed head plan: union of the parts' index sets.
    pub fn union(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("union of zero specs is undefined");
        }
        Ok(AttentionSpec::Union(parts))
    }

    /// Intersection of the parts' index sets.
    pub fn intersect(parts: Vec<AttentionSpec>) -> Result<AttentionSpec> {
        if parts.is_empty() {
            bail!("intersection of zero specs is undefined");
        }
        Ok(AttentionSpec::Intersect(parts))
    }

    /// Compile the spec for sequence length `n` into a CSR index set.
    /// Infallible: constructors validate parameters; hand-built enum
    /// values with zero windows/strides are clamped to 1 defensively.
    /// `n = 0` compiles to an empty pattern.
    pub fn compile(&self, n: usize) -> CompiledPattern {
        CompiledPattern::from_rows(n, build_rows(self, n))
    }

    /// JSON encoding of the spec (declarative, nestable).
    pub fn to_json(&self) -> Json {
        let kind = |k: &str| ("kind".to_string(), Json::Str(k.to_string()));
        match self {
            AttentionSpec::Full => Json::Obj(vec![kind("full")]),
            AttentionSpec::Local { window } => Json::Obj(vec![
                kind("local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::BlockLocal { window } => Json::Obj(vec![
                kind("block_local"),
                ("window".to_string(), Json::Num(*window as f64)),
            ]),
            AttentionSpec::Strided { stride } => Json::Obj(vec![
                kind("strided"),
                ("stride".to_string(), Json::Num(*stride as f64)),
            ]),
            AttentionSpec::Routing { clusters } => Json::Obj(vec![
                kind("routing"),
                (
                    "clusters".to_string(),
                    Json::Arr(
                        clusters
                            .iter()
                            .map(|m| {
                                Json::Arr(m.iter().map(|&i| Json::Num(i as f64)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            AttentionSpec::Union(parts) => Json::Obj(vec![
                kind("union"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
            AttentionSpec::Intersect(parts) => Json::Obj(vec![
                kind("intersect"),
                ("parts".to_string(), Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
        }
    }

    /// Decode a spec from JSON, re-running constructor validation.
    pub fn from_json(j: &Json) -> Result<AttentionSpec> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("attention spec json missing string 'kind'"))?;
        let field = |name: &str| -> Result<usize> {
            j.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("spec '{kind}' missing integer '{name}'"))
        };
        let parts = |name: &str| -> Result<Vec<AttentionSpec>> {
            j.get(name)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec '{kind}' missing array '{name}'"))?
                .iter()
                .map(AttentionSpec::from_json)
                .collect()
        };
        match kind {
            "full" => Ok(AttentionSpec::Full),
            "local" => AttentionSpec::local(field("window")?),
            "block_local" => AttentionSpec::block_local(field("window")?),
            "strided" => AttentionSpec::strided(field("stride")?),
            "routing" => {
                let arr = j
                    .get("clusters")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("routing spec missing array 'clusters'"))?;
                let clusters = arr
                    .iter()
                    .map(|m| {
                        m.as_arr()
                            .ok_or_else(|| anyhow!("routing cluster must be an array"))?
                            .iter()
                            .map(|v| {
                                v.as_usize()
                                    .ok_or_else(|| anyhow!("cluster member must be an integer"))
                            })
                            .collect::<Result<Vec<usize>>>()
                    })
                    .collect::<Result<Vec<Vec<usize>>>>()?;
                Ok(AttentionSpec::routing(clusters))
            }
            "union" => AttentionSpec::union(parts("parts")?),
            "intersect" => AttentionSpec::intersect(parts("parts")?),
            other => bail!("unknown attention spec kind '{other}'"),
        }
    }
}

/// Per-query (key, cluster-id) rows, sorted by key and deduped — the
/// intermediate representation `CompiledPattern::from_rows` packs into CSR.
fn build_rows(spec: &AttentionSpec, n: usize) -> Vec<Vec<(usize, u32)>> {
    match spec {
        AttentionSpec::Full => {
            (0..n).map(|i| (0..=i).map(|j| (j, NO_CLUSTER)).collect()).collect()
        }
        AttentionSpec::Local { window } => {
            let w = (*window).max(1);
            (0..n)
                .map(|i| {
                    (i.saturating_sub(w - 1)..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::BlockLocal { window } => {
            let w = (*window).max(1);
            (0..n)
                .map(|i| {
                    let start = (i / w).saturating_sub(1) * w;
                    (start..=i).map(|j| (j, NO_CLUSTER)).collect()
                })
                .collect()
        }
        AttentionSpec::Strided { stride } => {
            let s = (*stride).max(1);
            (0..n)
                .map(|i| (i % s..=i).step_by(s).map(|j| (j, NO_CLUSTER)).collect())
                .collect()
        }
        AttentionSpec::Routing { clusters } => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); n];
            for (c, members) in clusters.iter().enumerate() {
                // constructors normalize, but hand-built enums may not be
                // sorted/deduped/in-range — renormalize defensively
                let mut ms: Vec<usize> = members.iter().copied().filter(|&i| i < n).collect();
                ms.sort_unstable();
                ms.dedup();
                for (idx, &i) in ms.iter().enumerate() {
                    for &j in &ms[..=idx] {
                        rows[i].push((j, c as u32));
                    }
                }
            }
            for row in &mut rows {
                // sort by key then cluster; dedup keeps the lowest cluster
                // id for a key selected by several clusters (the renderer's
                // "first matching cluster" convention)
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Union(parts) => {
            let mut rows: Vec<Vec<(usize, u32)>> = vec![Vec::new(); n];
            for part in parts {
                for (i, prow) in build_rows(part, n).into_iter().enumerate() {
                    rows[i].extend(prow);
                }
            }
            for row in &mut rows {
                // NO_CLUSTER sorts last, so routed entries keep their
                // cluster id when a key is admitted by several parts
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
            }
            rows
        }
        AttentionSpec::Intersect(parts) => {
            let mut iter = parts.iter();
            let first = match iter.next() {
                // empty intersection = no constraint (matches `all()`)
                None => return build_rows(&AttentionSpec::Full, n),
                Some(p) => p,
            };
            let mut rows = build_rows(first, n);
            for part in iter {
                let prows = build_rows(part, n);
                for (row, prow) in rows.iter_mut().zip(&prows) {
                    let mut out = Vec::new();
                    let (mut a, mut b) = (0usize, 0usize);
                    while a < row.len() && b < prow.len() {
                        match row[a].0.cmp(&prow[b].0) {
                            std::cmp::Ordering::Less => a += 1,
                            std::cmp::Ordering::Greater => b += 1,
                            std::cmp::Ordering::Equal => {
                                out.push((row[a].0, row[a].1.min(prow[b].1)));
                                a += 1;
                                b += 1;
                            }
                        }
                    }
                    *row = out;
                }
            }
            rows
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degenerate_params_rejected() {
        assert!(AttentionSpec::local(0).is_err());
        assert!(AttentionSpec::block_local(0).is_err());
        assert!(AttentionSpec::strided(0).is_err());
        assert!(AttentionSpec::routing_balanced(16, 0).is_err());
        assert!(AttentionSpec::union(vec![]).is_err());
        assert!(AttentionSpec::intersect(vec![]).is_err());
        assert!(AttentionSpec::local(1).is_ok());
        assert!(AttentionSpec::strided(1).is_ok());
    }

    #[test]
    fn hand_built_zero_params_clamp_instead_of_panicking() {
        // direct enum construction bypasses validation; compile must clamp
        let p = AttentionSpec::Local { window: 0 }.compile(4);
        assert_eq!(p.row(2), &[2]);
        let p = AttentionSpec::Strided { stride: 0 }.compile(4);
        assert_eq!(p.row(3), &[0, 1, 2, 3]);
        // clamped to blocks of 1: each query sees itself and its
        // predecessor, so rows are {0}, {0,1}, {1,2}, {2,3}
        let p = AttentionSpec::BlockLocal { window: 0 }.compile(4);
        assert_eq!(p.row(3), &[2, 3]);
        assert_eq!(p.nnz(), 7);
    }

    #[test]
    fn routing_normalizes_members() {
        let spec = AttentionSpec::routing(vec![vec![5, 2, 2, 0]]);
        match &spec {
            AttentionSpec::Routing { clusters } => assert_eq!(clusters[0], vec![0, 2, 5]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn routing_balanced_covers_prefix() {
        let spec = AttentionSpec::routing_balanced(10, 3).unwrap();
        match &spec {
            AttentionSpec::Routing { clusters } => {
                assert_eq!(clusters.len(), 3);
                // w = 3; tail token 9 stays unrouted, as the model assumes
                assert_eq!(clusters[0], vec![0, 1, 2]);
                assert_eq!(clusters[2], vec![6, 7, 8]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn json_roundtrip_nested() {
        let spec = AttentionSpec::union(vec![
            AttentionSpec::local(8).unwrap(),
            AttentionSpec::routing(vec![vec![0, 3, 9], vec![1, 2]]),
            AttentionSpec::intersect(vec![
                AttentionSpec::Full,
                AttentionSpec::strided(4).unwrap(),
            ])
            .unwrap(),
        ])
        .unwrap();
        let text = spec.to_json().to_string();
        let back = AttentionSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn from_json_rejects_bad_specs() {
        for bad in [
            r#"{"kind":"warp"}"#,
            r#"{"kind":"local"}"#,
            r#"{"kind":"local","window":0}"#,
            r#"{"window":3}"#,
            // fractional / negative params used to be silently truncated
            // or saturated by the lossy `as` casts in Json::as_usize
            r#"{"kind":"local","window":2.7}"#,
            r#"{"kind":"local","window":-1}"#,
            r#"{"kind":"strided","stride":3.5}"#,
            r#"{"kind":"block_local","window":1e30}"#,
            r#"{"kind":"routing","clusters":[[0,1.5]]}"#,
            r#"{"kind":"routing","clusters":[[-2,1]]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(AttentionSpec::from_json(&j).is_err(), "accepted {bad}");
        }
    }
}

//! Shared harness for the per-table benchmark binaries (`rust/benches/`).
//!
//! Each paper table gets one binary that (1) briefly trains the relevant
//! variants on the matching synthetic workload, (2) evaluates them, and
//! (3) prints the paper's rows next to the measured ones.  Absolute
//! numbers differ by construction (synthetic data, tiny models, CPU
//! PJRT); the *shape* — who wins, roughly by how much — is asserted in
//! the integration tests and discussed in EXPERIMENTS.md.
//!
//! Environment knobs so `cargo bench` stays bounded:
//!   RTX_BENCH_STEPS   train steps per variant   (default 48)
//!   RTX_BENCH_EVAL    eval batches per variant  (default 4)

use anyhow::Result;

use crate::coordinator::{
    eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions, Trainer,
};
use crate::runtime::{Artifacts, Runtime};

/// Per-variant measurement.
#[derive(Debug, Clone)]
pub struct VariantResult {
    pub variant: String,
    pub steps: usize,
    pub final_train_loss: f64,
    pub eval_nll: f64,
    pub steps_per_sec: f64,
}

impl VariantResult {
    pub fn bits_per_dim(&self) -> f64 {
        crate::coordinator::bits_per_dim(self.eval_nll)
    }

    pub fn ppl(&self) -> f64 {
        crate::coordinator::ppl(self.eval_nll)
    }
}

pub fn bench_steps() -> usize {
    std::env::var("RTX_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(48)
}

pub fn bench_eval_batches() -> usize {
    std::env::var("RTX_BENCH_EVAL").ok().and_then(|s| s.parse().ok()).unwrap_or(4)
}

/// Train `variant` for `steps` on `data`, then eval.  One seeded run.
pub fn train_and_eval(
    rt: &Runtime,
    root: &std::path::Path,
    variant: &str,
    data: &str,
    steps: usize,
    eval_batches: usize,
) -> Result<VariantResult> {
    let art = Artifacts::load(root, variant)?;
    let manifest = art.manifest.clone();
    let mut trainer = Trainer::new(rt, &art)?;
    let mut batcher = train_batcher(&manifest, data, 0)?;
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: steps.max(4) as u32 / 4 },
        log_every: 0,
        ckpt_every: 0,
        ckpt_path: None,
        log_csv: None,
    };
    let report = trainer.train(&mut batcher, &manifest, &opts)?;

    let evaluator = Evaluator::new(rt, &art)?;
    let mut eval = eval_batcher(&manifest, data, 1)?;
    let eval_report = evaluator.eval(&trainer.state, &mut eval, eval_batches)?;
    Ok(VariantResult {
        variant: variant.to_string(),
        steps: report.steps,
        final_train_loss: report.mean_last10_loss,
        eval_nll: eval_report.mean_nll,
        steps_per_sec: report.steps_per_sec,
    })
}

/// Measure raw train-block step time (no eval) — Table 7.
pub fn measure_steps_per_sec(
    rt: &Runtime,
    root: &std::path::Path,
    variant: &str,
    data: &str,
    blocks: usize,
) -> Result<f64> {
    let art = Artifacts::load(root, variant)?;
    let manifest = art.manifest.clone();
    let mut trainer = Trainer::new(rt, &art)?;
    let mut batcher = train_batcher(&manifest, data, 0)?;
    // warmup (compile + first run)
    let block = batcher.next_block();
    trainer.step_block(&block, 1e-4)?;
    let t0 = std::time::Instant::now();
    let mut steps = 0usize;
    for _ in 0..blocks {
        let block = batcher.next_block();
        let losses = trainer.step_block(&block, 1e-4)?;
        steps += losses.len();
    }
    Ok(steps as f64 / t0.elapsed().as_secs_f64())
}

/// Default artifacts root for benches (repo root relative).
pub fn artifacts_root() -> std::path::PathBuf {
    std::env::var("RTX_ARTIFACTS").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    })
}

/// Print the standard bench header.
pub fn header(table: &str, note: &str) {
    println!("================================================================");
    println!("{table}");
    println!("{note}");
    println!("steps/variant: {}, eval batches: {}", bench_steps(), bench_eval_batches());
    println!("================================================================");
}

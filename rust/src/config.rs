//! Run-configuration system: a TOML-subset parser plus typed run configs.
//!
//! The offline environment ships no `toml` crate, so this implements the
//! subset the run configs need: `[section]` headers, `key = value` with
//! string / integer / float / boolean values, comments (`#`), and blank
//! lines.  `rtx train --config configs/<name>.toml` maps a file onto
//! [`RunConfig`]; CLI flags still override individual fields.
//!
//! ```toml
//! # configs/byte_routing.toml
//! [run]
//! variant = "byte_routing"
//! data = "bytes"
//! steps = 300
//! seed = 0
//!
//! [schedule]
//! kind = "inv_sqrt"      # constant | inv_sqrt | rsqrt
//! lr = 0.05              # scale for inv_sqrt
//! warmup = 50
//!
//! [output]
//! checkpoint = "runs/byte_routing/ck"
//! loss_csv = "runs/byte_routing/loss.csv"
//! log_every = 20
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{LrSchedule, TrainOptions};

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` map of one parsed document.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse the TOML subset (sections, scalar `key = value`, comments).
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full_key, parse_value(value.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str) -> Option<String> {
        self.get(key).and_then(|v| v.as_str()).map(str::to_string)
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(TomlValue::as_i64).map(|v| v as usize)
    }

    pub fn f32(&self, key: &str) -> Option<f32> {
        self.get(key).and_then(TomlValue::as_f64).map(|v| v as f32)
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside a quoted string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("line {lineno}: unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{s}'")
}

/// A full training-run configuration (what `rtx train --config` loads).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub variant: String,
    pub data: Option<String>,
    pub steps: usize,
    pub seed: u64,
    pub schedule: LrSchedule,
    pub checkpoint: Option<PathBuf>,
    pub loss_csv: Option<PathBuf>,
    pub log_every: usize,
    pub ckpt_every: usize,
}

impl RunConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        let variant = doc
            .str("run.variant")
            .ok_or_else(|| anyhow!("config missing run.variant"))?;
        let kind = doc.str("schedule.kind").unwrap_or_else(|| "inv_sqrt".into());
        let lr = doc.f32("schedule.lr").unwrap_or(0.05);
        let warmup = doc.usize("schedule.warmup").unwrap_or(100) as u32;
        let schedule = match kind.as_str() {
            "constant" => LrSchedule::Constant { lr },
            "inv_sqrt" => LrSchedule::InverseSqrt { scale: lr, warmup },
            "rsqrt" => LrSchedule::RsqrtDecay { lr, warmup },
            other => bail!("unknown schedule.kind '{other}'"),
        };
        Ok(RunConfig {
            variant,
            data: doc.str("run.data"),
            steps: doc.usize("run.steps").unwrap_or(100),
            seed: doc.usize("run.seed").unwrap_or(0) as u64,
            schedule,
            checkpoint: doc.str("output.checkpoint").map(PathBuf::from),
            loss_csv: doc.str("output.loss_csv").map(PathBuf::from),
            log_every: doc.usize("output.log_every").unwrap_or(20),
            ckpt_every: doc.usize("output.ckpt_every").unwrap_or(0),
        })
    }

    pub fn load(path: &Path) -> Result<RunConfig> {
        Self::from_doc(&TomlDoc::load(path)?)
    }

    pub fn train_options(&self) -> TrainOptions {
        TrainOptions {
            steps: self.steps,
            schedule: self.schedule,
            log_every: self.log_every,
            ckpt_every: self.ckpt_every,
            ckpt_path: self.checkpoint.clone(),
            log_csv: self.loss_csv.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a run config
[run]
variant = "byte_routing"
data = "bytes"
steps = 300
seed = 7

[schedule]
kind = "inv_sqrt"
lr = 0.05
warmup = 50

[output]
checkpoint = "runs/x/ck"   # with a comment
log_every = 10
"#;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.str("run.variant").unwrap(), "byte_routing");
        assert_eq!(doc.usize("run.steps").unwrap(), 300);
        assert_eq!(doc.f32("schedule.lr").unwrap(), 0.05);
        assert_eq!(doc.str("output.checkpoint").unwrap(), "runs/x/ck");
    }

    #[test]
    fn run_config_maps_to_train_options() {
        let cfg = RunConfig::from_doc(&TomlDoc::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.variant, "byte_routing");
        assert_eq!(cfg.seed, 7);
        let opts = cfg.train_options();
        assert_eq!(opts.steps, 300);
        assert_eq!(opts.log_every, 10);
        assert_eq!(
            opts.schedule,
            LrSchedule::InverseSqrt { scale: 0.05, warmup: 50 }
        );
        assert_eq!(opts.ckpt_path.unwrap(), PathBuf::from("runs/x/ck"));
    }

    #[test]
    fn value_types() {
        let doc = TomlDoc::parse("a = 1\nb = 1.5\nc = true\nd = \"x # y\"\n").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Float(1.5)));
        assert_eq!(doc.get("c"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.str("d").unwrap(), "x # y");
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = @@@").is_err());
        assert!(RunConfig::from_doc(&TomlDoc::parse("[run]\nsteps = 1").unwrap()).is_err());
    }

    #[test]
    fn schedule_kinds() {
        for (kind, expect) in [
            ("constant", LrSchedule::Constant { lr: 0.1 }),
            ("rsqrt", LrSchedule::RsqrtDecay { lr: 0.1, warmup: 5 }),
        ] {
            let text = format!(
                "[run]\nvariant = \"q\"\n[schedule]\nkind = \"{kind}\"\nlr = 0.1\nwarmup = 5\n"
            );
            let cfg = RunConfig::from_doc(&TomlDoc::parse(&text).unwrap()).unwrap();
            assert_eq!(cfg.schedule, expect);
        }
    }
}

//! Evaluation loop: held-out NLL via the `eval_loss` artifact, with
//! per-position losses for the needle-retrieval metric.

use std::sync::Arc;

use anyhow::Result;
use xla::{Literal, PjRtLoadedExecutable};

use crate::data::BlockBatcher;
use crate::runtime::{execute_tuple, i32_literal, to_f32_vec, Artifacts, ModelState, Runtime};

/// Evaluation results.
#[derive(Debug, Clone)]
pub struct EvalReport {
    /// Mean next-token NLL (nats) over all evaluated positions.
    pub mean_nll: f64,
    /// Number of [B, T] batches evaluated.
    pub batches: usize,
    /// Per-position NLLs of the last batch (for diagnostics), row-major
    /// [B, T-1].
    pub last_batch_nll: Vec<f32>,
}

impl EvalReport {
    pub fn ppl(&self) -> f64 {
        super::metrics::ppl(self.mean_nll)
    }

    pub fn bits_per_dim(&self) -> f64 {
        super::metrics::bits_per_dim(self.mean_nll)
    }
}

/// Evaluator over one variant's `eval_loss` artifact.
pub struct Evaluator {
    exe: Arc<PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq_len: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, art: &Artifacts) -> Result<Evaluator> {
        Ok(Evaluator {
            exe: art.executable(rt, "eval_loss")?,
            batch: art.manifest.batch,
            seq_len: art.manifest.config.seq_len,
        })
    }

    /// Mean NLL + per-position NLLs over one [B, T] token batch.
    pub fn eval_batch(&self, state: &ModelState, tokens: &[i32]) -> Result<(f64, Vec<f32>)> {
        let lit = i32_literal(tokens, &[self.batch, self.seq_len])?;
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.push(&lit);
        let outs = execute_tuple(&self.exe, &inputs)?;
        let mean = crate::runtime::scalar_f32_value(&outs[0])? as f64;
        let nll = to_f32_vec(&outs[1])?;
        Ok((mean, nll))
    }

    /// Evaluate `n_batches` held-out batches from a batcher.
    pub fn eval(
        &self,
        state: &ModelState,
        batcher: &mut BlockBatcher,
        n_batches: usize,
    ) -> Result<EvalReport> {
        let mut total = 0.0;
        let mut last = Vec::new();
        for _ in 0..n_batches {
            let tokens = batcher.next_eval_batch();
            let (mean, nll) = self.eval_batch(state, &tokens)?;
            total += mean;
            last = nll;
        }
        Ok(EvalReport {
            mean_nll: total / n_batches.max(1) as f64,
            batches: n_batches,
            last_batch_nll: last,
        })
    }

    /// Needle-retrieval metric: mean NLL restricted to copy-target
    /// positions (second payload occurrences) vs all positions.  The gap
    /// between the two is the long-range-retrieval signal that separates
    /// routing from local attention on the needle corpus.
    pub fn eval_retrieval(
        &self,
        state: &ModelState,
        batcher: &mut BlockBatcher,
        n_batches: usize,
        payload_len: usize,
    ) -> Result<(f64, f64)> {
        use crate::data::needle::NeedleSource;
        let mut copy_nll = 0.0;
        let mut copy_n = 0usize;
        let mut all_nll = 0.0;
        let mut all_n = 0usize;
        for _ in 0..n_batches {
            let tokens = batcher.next_eval_batch();
            let (_, nll) = self.eval_batch(state, &tokens)?;
            let t = self.seq_len;
            for b in 0..self.batch {
                let seq = &tokens[b * t..(b + 1) * t];
                let mask = NeedleSource::copy_target_mask(seq, payload_len);
                for pos in 1..t {
                    // nll[pos-1] scores the prediction of tokens[pos]
                    let x = nll[b * (t - 1) + (pos - 1)] as f64;
                    all_nll += x;
                    all_n += 1;
                    if mask[pos] {
                        copy_nll += x;
                        copy_n += 1;
                    }
                }
            }
        }
        Ok((
            copy_nll / copy_n.max(1) as f64,
            all_nll / all_n.max(1) as f64,
        ))
    }
}

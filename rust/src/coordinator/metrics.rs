//! Training/eval metrics: loss meters, unit conversions (the paper reports
//! perplexity for word/subword models and bits-per-dim / bits-per-byte for
//! image/byte models), steps/sec timing, and a CSV run logger.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Natural-log loss -> perplexity (Tables 2, 5).
pub fn ppl(nll_nats: f64) -> f64 {
    nll_nats.exp()
}

/// Natural-log loss -> bits per symbol (Tables 1, 3, 4: bits/dim, bpb).
pub fn bits_per_dim(nll_nats: f64) -> f64 {
    nll_nats / std::f64::consts::LN_2
}

/// Streaming mean.
#[derive(Debug, Default, Clone)]
pub struct Meter {
    sum: f64,
    n: usize,
}

impl Meter {
    pub fn add(&mut self, x: f64) {
        self.sum += x;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

/// Exponential moving average (for smoothed loss display).
#[derive(Debug, Clone)]
pub struct Ema {
    pub decay: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(decay: f64) -> Self {
        Ema { decay, value: None }
    }

    pub fn add(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.decay * prev + (1.0 - self.decay) * x,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Steps-per-second timer (Tables 1, 7 report steps/sec).
pub struct Throughput {
    start: Instant,
    steps: usize,
}

impl Throughput {
    pub fn start() -> Self {
        Throughput { start: Instant::now(), steps: 0 }
    }

    pub fn add_steps(&mut self, n: usize) {
        self.steps += n;
    }

    pub fn steps_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.steps as f64 / dt
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// CSV logger for loss curves (EXPERIMENTS.md plots read these files).
pub struct CsvLogger {
    file: std::fs::File,
}

impl CsvLogger {
    pub fn create(path: &Path, header: &str) -> anyhow::Result<CsvLogger> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{header}")?;
        Ok(CsvLogger { file })
    }

    pub fn log(&mut self, fields: &[String]) -> anyhow::Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        // uniform over 256 symbols: nll = ln 256, bits = 8
        let nll = (256f64).ln();
        assert!((bits_per_dim(nll) - 8.0).abs() < 1e-12);
        assert!((ppl(nll) - 256.0).abs() < 1e-9);
    }

    #[test]
    fn meter_mean() {
        let mut m = Meter::default();
        m.add(1.0);
        m.add(3.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.mean(), 0.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.add(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn csv_logger_writes() {
        let dir = std::env::temp_dir().join("rtx_metrics_test");
        let path = dir.join("loss.csv");
        let mut log = CsvLogger::create(&path, "step,loss").unwrap();
        log.log(&["1".into(), "2.5".into()]).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,2.5\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}

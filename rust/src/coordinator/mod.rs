//! Layer-3 coordinator: the training/eval/analysis driver over the AOT
//! artifacts.
//!
//! The Routing Transformer's *system* contribution lives at L1/L2 (the
//! clustering attention kernel and model); per DESIGN.md the coordinator
//! is therefore a full but conventional LM-training stack: config, data
//! pipeline, scanned train loop, evaluation, LR schedules, metrics,
//! checkpoints, plus the paper-specific analysis drivers (JSD study,
//! pattern renderer, step-time benches).

pub mod evaluator;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use evaluator::{EvalReport, Evaluator};
pub use metrics::{bits_per_dim, ppl, CsvLogger, Ema, Meter, Throughput};
pub use schedule::LrSchedule;
pub use trainer::{TrainOptions, TrainReport, Trainer};

use anyhow::Result;

use crate::data::{self, BlockBatcher};
use crate::runtime::Manifest;

/// Build a train batcher for a manifest + data source name: one forked
/// source per batch lane.
pub fn train_batcher(manifest: &Manifest, data_name: &str, seed: u64) -> Result<BlockBatcher> {
    batcher_with(manifest, data_name, seed, manifest.scan_steps)
}

/// Build an eval batcher (disjoint seeds from training).
pub fn eval_batcher(manifest: &Manifest, data_name: &str, seed: u64) -> Result<BlockBatcher> {
    batcher_with(manifest, data_name, seed ^ 0xE7A1_0000_0000_0000, 1)
}

fn batcher_with(
    manifest: &Manifest,
    data_name: &str,
    seed: u64,
    scan_steps: usize,
) -> Result<BlockBatcher> {
    let cfg = &manifest.config;
    let lanes: Result<Vec<_>> = (0..manifest.batch)
        .map(|lane| {
            data::source_by_name(
                data_name,
                cfg.vocab_size,
                cfg.seq_len,
                cfg.window,
                seed.wrapping_add(lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )
        })
        .collect();
    Ok(BlockBatcher::new(lanes?, scan_steps, cfg.seq_len))
}

/// Default data source per variant group (matches DESIGN.md's table).
pub fn default_data_for(manifest: &Manifest) -> &'static str {
    match manifest.group.as_str() {
        "table1" | "table4" => "images",
        "table3" => "bytes",
        "table5" => "bytes",
        "table2" | "table6" => "needle",
        _ => "needle",
    }
}

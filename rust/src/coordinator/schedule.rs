//! Learning-rate schedules.
//!
//! The paper uses the Transformer inverse-sqrt schedule (Vaswani et al.,
//! Section 5) for everything except PG-19, which uses a constant 0.01
//! with 10k linear warmup followed by rsqrt_normalized_decay (Section
//! 5.5).  The schedule is computed host-side and fed to the train
//! artifact as a scalar input, so switching schedules needs no
//! re-lowering.

/// A learning-rate schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant rate.
    Constant { lr: f32 },
    /// Vaswani et al.: lr = scale * min(step^-0.5, step * warmup^-1.5).
    InverseSqrt { scale: f32, warmup: u32 },
    /// PG-19 setup: linear warmup to `lr`, then lr * sqrt(warmup/step).
    RsqrtDecay { lr: f32, warmup: u32 },
}

impl LrSchedule {
    /// Learning rate at 1-based step `step`.
    pub fn lr(&self, step: u32) -> f32 {
        let s = step.max(1) as f32;
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::InverseSqrt { scale, warmup } => {
                let w = warmup.max(1) as f32;
                scale * (1.0 / s.sqrt()).min(s * w.powf(-1.5))
            }
            LrSchedule::RsqrtDecay { lr, warmup } => {
                let w = warmup.max(1) as f32;
                if s < w {
                    lr * s / w
                } else {
                    lr * (w / s).sqrt()
                }
            }
        }
    }

    /// Parse a CLI spec: `constant:LR`, `inv_sqrt:SCALE:WARMUP`,
    /// `rsqrt:LR:WARMUP`.
    pub fn parse(spec: &str) -> anyhow::Result<LrSchedule> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            ["constant", lr] => Ok(LrSchedule::Constant { lr: lr.parse()? }),
            ["inv_sqrt", scale, warmup] => Ok(LrSchedule::InverseSqrt {
                scale: scale.parse()?,
                warmup: warmup.parse()?,
            }),
            ["rsqrt", lr, warmup] => {
                Ok(LrSchedule::RsqrtDecay { lr: lr.parse()?, warmup: warmup.parse()? })
            }
            _ => anyhow::bail!(
                "bad schedule '{spec}' (constant:LR | inv_sqrt:SCALE:WARMUP | rsqrt:LR:WARMUP)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.01 };
        assert_eq!(s.lr(1), 0.01);
        assert_eq!(s.lr(100_000), 0.01);
    }

    #[test]
    fn inverse_sqrt_warms_up_then_decays() {
        let s = LrSchedule::InverseSqrt { scale: 1.0, warmup: 100 };
        assert!(s.lr(10) < s.lr(50)); // warming up
        assert!(s.lr(50) < s.lr(100));
        assert!(s.lr(400) < s.lr(100)); // decaying
        // peak at warmup: step^-0.5 branch
        assert!((s.lr(100) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn rsqrt_linear_warmup() {
        let s = LrSchedule::RsqrtDecay { lr: 0.01, warmup: 1000 };
        assert!((s.lr(500) - 0.005).abs() < 1e-7);
        assert!((s.lr(1000) - 0.01).abs() < 1e-7);
        assert!((s.lr(4000) - 0.005).abs() < 1e-7); // sqrt(1/4)
    }

    #[test]
    fn parse_specs() {
        assert_eq!(LrSchedule::parse("constant:0.5").unwrap(),
                   LrSchedule::Constant { lr: 0.5 });
        assert_eq!(LrSchedule::parse("inv_sqrt:2.0:4000").unwrap(),
                   LrSchedule::InverseSqrt { scale: 2.0, warmup: 4000 });
        assert_eq!(LrSchedule::parse("rsqrt:0.01:10000").unwrap(),
                   LrSchedule::RsqrtDecay { lr: 0.01, warmup: 10000 });
        assert!(LrSchedule::parse("nope").is_err());
    }

    #[test]
    fn never_nan_or_negative() {
        for sched in [
            LrSchedule::Constant { lr: 0.1 },
            LrSchedule::InverseSqrt { scale: 1.0, warmup: 0 },
            LrSchedule::RsqrtDecay { lr: 0.1, warmup: 0 },
        ] {
            for step in [0u32, 1, 7, 1_000_000] {
                let lr = sched.lr(step);
                assert!(lr.is_finite() && lr >= 0.0, "{sched:?} step {step} -> {lr}");
            }
        }
    }
}

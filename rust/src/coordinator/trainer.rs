//! The training loop: drives the scanned `train_block` artifact.
//!
//! Each call feeds `[S, B, T]` tokens plus the full optimizer state and
//! receives the updated state and the per-step losses.  The Adam update
//! and the centroid k-means EMA both live *inside* the artifact — this
//! loop owns only scheduling, data, metrics and checkpoints (Python never
//! runs here).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};
use xla::{Literal, PjRtLoadedExecutable};

use super::metrics::{Ema, Throughput};
use super::schedule::LrSchedule;
use crate::data::{BlockBatcher, TokenBlock};
use crate::runtime::{
    execute_tuple, i32_literal, scalar_f32, scalar_i32, to_f32_vec, Artifacts, ModelState,
    Runtime,
};

/// Training-loop options.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub schedule: LrSchedule,
    pub log_every: usize,
    /// Save a checkpoint every N steps (0 = only at the end).
    pub ckpt_every: usize,
    pub ckpt_path: Option<std::path::PathBuf>,
    /// Optional CSV loss-curve path.
    pub log_csv: Option<std::path::PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            schedule: LrSchedule::InverseSqrt { scale: 0.05, warmup: 100 },
            log_every: 20,
            ckpt_every: 0,
            ckpt_path: None,
            log_csv: None,
        }
    }
}

/// Summary of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f32,
    pub mean_last10_loss: f64,
    pub steps_per_sec: f64,
    pub losses: Vec<f32>,
}

/// Trainer over one variant's `train_block` artifact.
pub struct Trainer {
    exe: Arc<PjRtLoadedExecutable>,
    pub state: ModelState,
    pub scan_steps: usize,
    pub batch: usize,
    pub seq_len: usize,
    variant: String,
}

impl Trainer {
    /// Build from artifacts with the seeded initial state.
    pub fn new(rt: &Runtime, art: &Artifacts) -> Result<Trainer> {
        let state = art.init_state()?;
        Self::with_state(rt, art, state)
    }

    /// Build from artifacts resuming from an existing state.
    pub fn with_state(rt: &Runtime, art: &Artifacts, state: ModelState) -> Result<Trainer> {
        let m = &art.manifest;
        let exe = art.executable(rt, "train_block")?;
        Ok(Trainer {
            exe,
            state,
            scan_steps: m.scan_steps,
            batch: m.batch,
            seq_len: m.config.seq_len,
            variant: m.variant.clone(),
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    /// Execute one scanned block of `scan_steps` optimizer steps.
    pub fn step_block(&mut self, block: &TokenBlock, lr: f32) -> Result<Vec<f32>> {
        if block.dims() != [self.scan_steps, self.batch, self.seq_len] {
            return Err(anyhow!(
                "block dims {:?} != artifact dims [{}, {}, {}]",
                block.dims(), self.scan_steps, self.batch, self.seq_len
            ));
        }
        let tokens = i32_literal(&block.tokens, &block.dims())?;
        let step_lit = scalar_i32(self.state.step as i32);
        let lr_lit = scalar_f32(lr);

        let p = self.state.params.len();
        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * p + 3);
        inputs.extend(self.state.params.iter());
        inputs.extend(self.state.m.iter());
        inputs.extend(self.state.v.iter());
        inputs.push(&step_lit);
        inputs.push(&lr_lit);
        inputs.push(&tokens);

        let mut outs = execute_tuple(&self.exe, &inputs)?;
        if outs.len() != 3 * p + 1 {
            return Err(anyhow!("expected {} outputs, got {}", 3 * p + 1, outs.len()));
        }
        let losses_lit = outs.pop().unwrap();
        let v = outs.split_off(2 * p);
        let m = outs.split_off(p);
        self.state.params = outs;
        self.state.m = m;
        self.state.v = v;
        self.state.step += self.scan_steps as i64;
        Ok(to_f32_vec(&losses_lit)?)
    }

    /// Run the full training loop from a batcher.
    pub fn train(
        &mut self,
        batcher: &mut BlockBatcher,
        manifest: &crate::runtime::Manifest,
        opts: &TrainOptions,
    ) -> Result<TrainReport> {
        let mut losses: Vec<f32> = Vec::with_capacity(opts.steps);
        let mut ema = Ema::new(0.95);
        let mut throughput = Throughput::start();
        let mut csv = match &opts.log_csv {
            Some(path) => Some(super::metrics::CsvLogger::create(path, "step,loss,lr")?),
            None => None,
        };

        while losses.len() < opts.steps {
            let lr = opts.schedule.lr(self.state.step as u32 + 1);
            let block = batcher.next_block();
            let block_losses = self.step_block(&block, lr)?;
            throughput.add_steps(block_losses.len());
            for loss in block_losses {
                losses.push(loss);
                let smooth = ema.add(loss as f64);
                let step = losses.len();
                if let Some(csv) = &mut csv {
                    csv.log(&[step.to_string(), loss.to_string(), lr.to_string()])?;
                }
                if opts.log_every > 0 && step % opts.log_every == 0 {
                    println!(
                        "[{}] step {:>6}  loss {:.4}  (ema {:.4})  lr {:.2e}  {:.2} steps/s",
                        self.variant, step, loss, smooth, lr,
                        throughput.steps_per_sec()
                    );
                }
                if let (Some(path), true) = (
                    &opts.ckpt_path,
                    opts.ckpt_every > 0 && step % opts.ckpt_every == 0,
                ) {
                    self.state.save(manifest, path)?;
                }
                if step >= opts.steps {
                    break;
                }
            }
        }

        if let Some(path) = &opts.ckpt_path {
            self.state.save(manifest, path)?;
        }
        let last10 = &losses[losses.len().saturating_sub(10)..];
        Ok(TrainReport {
            steps: losses.len(),
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            mean_last10_loss: last10.iter().map(|&x| x as f64).sum::<f64>()
                / last10.len().max(1) as f64,
            steps_per_sec: throughput.steps_per_sec(),
            losses,
        })
    }

    /// Save current state to `<path>.npz/.json`.
    pub fn save(&self, manifest: &crate::runtime::Manifest, path: &Path) -> Result<()> {
        self.state.save(manifest, path)
    }
}

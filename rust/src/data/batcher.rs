//! Token batching: pack endless token streams into the `[S, B, T]` blocks
//! the scanned train artifact consumes, and `[B, T]` eval batches.
//!
//! Each batch lane (row b) is an independent contiguous stream (its own
//! forked generator seed), matching how LM training shards a corpus into
//! parallel readers: no token is lost or duplicated within a lane, and
//! lanes never interleave.

use super::TokenSource;

/// One `[S, B, T]` block of tokens, flattened row-major.
#[derive(Debug, Clone)]
pub struct TokenBlock {
    pub tokens: Vec<i32>,
    pub scan_steps: usize,
    pub batch: usize,
    pub seq_len: usize,
}

impl TokenBlock {
    pub fn dims(&self) -> [usize; 3] {
        [self.scan_steps, self.batch, self.seq_len]
    }
}

/// Packs per-lane token sources into train blocks.
pub struct BlockBatcher {
    lanes: Vec<Box<dyn TokenSource>>,
    pub scan_steps: usize,
    pub seq_len: usize,
}

impl BlockBatcher {
    pub fn new(lanes: Vec<Box<dyn TokenSource>>, scan_steps: usize, seq_len: usize) -> Self {
        assert!(!lanes.is_empty());
        BlockBatcher { lanes, scan_steps, seq_len }
    }

    pub fn batch(&self) -> usize {
        self.lanes.len()
    }

    /// Next `[S, B, T]` block: lane b contributes S consecutive sequences.
    pub fn next_block(&mut self) -> TokenBlock {
        let (s, b, t) = (self.scan_steps, self.lanes.len(), self.seq_len);
        let mut tokens = vec![0i32; s * b * t];
        for (bi, lane) in self.lanes.iter_mut().enumerate() {
            for si in 0..s {
                let off = (si * b + bi) * t;
                lane.fill(&mut tokens[off..off + t]);
            }
        }
        TokenBlock { tokens, scan_steps: s, batch: b, seq_len: t }
    }

    /// Next `[B, T]` eval batch (one sequence per lane).
    pub fn next_eval_batch(&mut self) -> Vec<i32> {
        let (b, t) = (self.lanes.len(), self.seq_len);
        let mut tokens = vec![0i32; b * t];
        for (bi, lane) in self.lanes.iter_mut().enumerate() {
            lane.fill(&mut tokens[bi * t..(bi + 1) * t]);
        }
        tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TokenSource;

    /// Counting source: emits 0,1,2,... (per-lane offset by `base`).
    struct Counter {
        next: i32,
    }

    impl TokenSource for Counter {
        fn vocab(&self) -> usize {
            1 << 30
        }
        fn fill(&mut self, out: &mut [i32]) {
            for t in out.iter_mut() {
                *t = self.next;
                self.next += 1;
            }
        }
    }

    fn batcher(b: usize, s: usize, t: usize) -> BlockBatcher {
        let lanes: Vec<Box<dyn TokenSource>> = (0..b)
            .map(|i| Box::new(Counter { next: (i as i32) * 1_000_000 }) as Box<dyn TokenSource>)
            .collect();
        BlockBatcher::new(lanes, s, t)
    }

    #[test]
    fn block_dims_and_layout() {
        let mut bt = batcher(2, 3, 4);
        let blk = bt.next_block();
        assert_eq!(blk.dims(), [3, 2, 4]);
        assert_eq!(blk.tokens.len(), 24);
        // lane 0, step 0 = [0,1,2,3]; lane 0, step 1 = [4,5,6,7]
        assert_eq!(&blk.tokens[0..4], &[0, 1, 2, 3]);
        assert_eq!(&blk.tokens[(1 * 2 + 0) * 4..(1 * 2 + 0) * 4 + 4], &[4, 5, 6, 7]);
        // lane 1, step 0 starts at its own stream
        assert_eq!(&blk.tokens[4..8], &[1_000_000, 1_000_001, 1_000_002, 1_000_003]);
    }

    #[test]
    fn lanes_are_continuous_across_blocks() {
        let mut bt = batcher(1, 2, 4);
        let a = bt.next_block();
        let b = bt.next_block();
        // last token of block a, lane 0 is 7; block b starts at 8
        assert_eq!(a.tokens[7], 7);
        assert_eq!(b.tokens[0], 8);
    }

    #[test]
    fn no_token_lost_or_duplicated() {
        let mut bt = batcher(1, 4, 8);
        let blk = bt.next_block();
        let mut toks = blk.tokens.clone();
        toks.sort();
        assert_eq!(toks, (0..32).collect::<Vec<i32>>());
    }

    #[test]
    fn eval_batch_shape() {
        let mut bt = batcher(3, 2, 5);
        let batch = bt.next_eval_batch();
        assert_eq!(batch.len(), 15);
        assert_eq!(&batch[0..5], &[0, 1, 2, 3, 4]);
        assert_eq!(batch[5], 1_000_000);
    }
}

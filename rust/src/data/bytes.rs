//! Synthetic byte-level text — the enwik-8 / PG-19 stand-in (Tables 3, 5).
//!
//! Documents are built from a seeded synthetic lexicon (Zipf-weighted
//! "words" of ASCII letters) assembled into sentences and paragraphs.
//! Each document carries a handful of *named entities* (capitalized rare
//! words) re-mentioned throughout — the long-range regularity the paper's
//! Section 6.1 argues routing attention exploits ("gender, nouns, dates
//! and names of places ... consistent throughout the entire sequence").

use super::TokenSource;
use crate::util::rng::{Rng, Zipf};

pub struct ByteTextSource {
    vocab: usize,
    lexicon: Vec<String>,
    zipf: Zipf,
    rng: Rng,
    buf: Vec<i32>,
    pos: usize,
}

impl ByteTextSource {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 256, "byte source needs vocab >= 256");
        let mut rng = Rng::new(seed);
        let lexicon = build_lexicon(&mut rng, 2000);
        ByteTextSource {
            vocab,
            lexicon,
            zipf: Zipf::new(2000, 1.05),
            rng,
            buf: Vec::new(),
            pos: 0,
        }
    }

    /// Generate one document (~2-6 KB of text).
    fn gen_document(&mut self) -> Vec<i32> {
        let mut text = String::new();
        // document-level entities: 3-6 capitalized rare words, reused often
        let n_entities = self.rng.range(3, 7);
        let entities: Vec<String> = (0..n_entities)
            .map(|_| {
                let w = &self.lexicon[self.rng.range(1000, 2000)];
                let mut c = w.clone();
                c[..1].make_ascii_uppercase();
                c
            })
            .collect();
        let n_paragraphs = self.rng.range(3, 8);
        for _ in 0..n_paragraphs {
            let n_sentences = self.rng.range(2, 6);
            for _ in 0..n_sentences {
                let n_words = self.rng.range(5, 14);
                for w in 0..n_words {
                    if w > 0 {
                        text.push(' ');
                    }
                    if self.rng.chance(0.12) {
                        // entity mention — the long-range signal
                        text.push_str(&entities[self.rng.below(entities.len())]);
                    } else {
                        text.push_str(&self.lexicon[self.zipf.sample(&mut self.rng)]);
                    }
                }
                text.push_str(". ");
            }
            text.push('\n');
        }
        text.bytes().map(|b| b as i32).collect()
    }
}

fn build_lexicon(rng: &mut Rng, n: usize) -> Vec<String> {
    const CONSONANTS: &[u8] = b"bcdfghjklmnprstvwz";
    const VOWELS: &[u8] = b"aeiou";
    let mut words = Vec::with_capacity(n);
    for _ in 0..n {
        let syllables = rng.range(1, 4);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push(CONSONANTS[rng.below(CONSONANTS.len())] as char);
            w.push(VOWELS[rng.below(VOWELS.len())] as char);
            if rng.chance(0.3) {
                w.push(CONSONANTS[rng.below(CONSONANTS.len())] as char);
            }
        }
        words.push(w);
    }
    words
}

impl TokenSource for ByteTextSource {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn fill(&mut self, out: &mut [i32]) {
        for t in out.iter_mut() {
            if self.pos >= self.buf.len() {
                self.buf = self.gen_document();
                self.pos = 0;
            }
            *t = self.buf[self.pos];
            self.pos += 1;
        }
    }
}

/// Materialize a corpus of documents as raw bytes (for the BPE tokenizer
/// training path, PG-19 style).
pub fn corpus_bytes(seed: u64, n_docs: usize) -> Vec<u8> {
    let mut src = ByteTextSource::new(256, seed);
    let mut out = Vec::new();
    for _ in 0..n_docs {
        let doc = src.gen_document();
        out.extend(doc.iter().map(|&t| t as u8));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::take;

    #[test]
    fn produces_ascii_text() {
        let mut src = ByteTextSource::new(256, 1);
        let toks = take(&mut src, 8192);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
        let text: String = toks.iter().map(|&t| t as u8 as char).collect();
        assert!(text.contains(". "));
        assert!(text.split_whitespace().count() > 100);
    }

    #[test]
    fn entities_recur() {
        let mut src = ByteTextSource::new(256, 2);
        let doc: Vec<u8> = src.gen_document().iter().map(|&t| t as u8).collect();
        let text = String::from_utf8(doc).unwrap();
        // capitalized words should appear multiple times
        let caps: Vec<&str> = text
            .split(|c: char| !c.is_ascii_alphabetic())
            .filter(|w| w.len() > 2 && w.chars().next().unwrap().is_ascii_uppercase())
            .collect();
        assert!(!caps.is_empty());
        let first = caps[0];
        let count = caps.iter().filter(|&&w| w == first).count();
        assert!(count >= 2, "entity '{first}' appears {count} time(s)");
    }

    #[test]
    fn deterministic() {
        let a = take(&mut ByteTextSource::new(256, 9), 2048);
        let b = take(&mut ByteTextSource::new(256, 9), 2048);
        assert_eq!(a, b);
    }
}

//! Synthetic raster-scan images — the CIFAR-10 / ImageNet-64 stand-in
//! (Tables 1, 4).
//!
//! Images are generated from a small set of global prototypes (smooth 2-D
//! intensity fields) plus per-image noise, then serialized in raster-scan
//! order exactly like the paper's image-generation setup (one token per
//! intensity value).  Two long-range structures reward content-based
//! attention beyond the raster-local window:
//!
//! * **horizontal mirror symmetry** — the right half of every row repeats
//!   the left half, so predicting column x >= W/2 benefits from attending
//!   W/2 tokens back (beyond a small local window);
//! * **prototype identity** — rows far apart are correlated through the
//!   global prototype, which clustering can pick up.

use super::TokenSource;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ImageConfig {
    pub width: usize,
    pub height: usize,
    pub n_prototypes: usize,
    pub noise: f64,
}

impl ImageConfig {
    /// Square grayscale image whose raster length equals `seq_len`.
    pub fn for_seq_len(seq_len: usize) -> ImageConfig {
        let side = (seq_len as f64).sqrt().round() as usize;
        assert_eq!(side * side, seq_len, "seq_len {seq_len} must be a square");
        ImageConfig { width: side, height: side, n_prototypes: 8, noise: 8.0 }
    }
}

pub struct ImageSource {
    cfg: ImageConfig,
    rng: Rng,
    buf: Vec<i32>,
    pos: usize,
}

impl ImageSource {
    pub fn new(cfg: ImageConfig, seed: u64) -> Self {
        ImageSource { cfg, rng: Rng::new(seed), buf: Vec::new(), pos: 0 }
    }

    /// Generate one image as raster-scan intensity tokens in [0, 256).
    pub fn gen_image(&mut self) -> Vec<i32> {
        let c = &self.cfg;
        let proto = self.rng.below(c.n_prototypes);
        let phase = self.rng.f64() * std::f64::consts::TAU;
        let (w, h) = (c.width, c.height);
        let mut img = vec![0i32; w * h];
        for y in 0..h {
            for x in 0..w / 2 {
                // smooth prototype field: frequency and orientation vary
                // with the prototype id -> globally distinguishable
                let fx = 1.0 + (proto % 4) as f64;
                let fy = 1.0 + (proto / 4) as f64;
                let v = 127.5
                    + 60.0
                        * ((x as f64 / w as f64 * fx * std::f64::consts::TAU + phase).sin()
                            * (y as f64 / h as f64 * fy * std::f64::consts::TAU).cos())
                    + self.rng.normal() * c.noise;
                let v = v.clamp(0.0, 255.0) as i32;
                img[y * w + x] = v;
                // mirrored right half (exact copy: the long-range signal)
                img[y * w + (w - 1 - x)] = v;
            }
        }
        img
    }
}

impl TokenSource for ImageSource {
    fn vocab(&self) -> usize {
        256
    }

    fn fill(&mut self, out: &mut [i32]) {
        for t in out.iter_mut() {
            if self.pos >= self.buf.len() {
                self.buf = self.gen_image();
                self.pos = 0;
            }
            *t = self.buf[self.pos];
            self.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::take;

    #[test]
    fn image_is_square_and_in_range() {
        let mut src = ImageSource::new(ImageConfig::for_seq_len(256), 1);
        let img = src.gen_image();
        assert_eq!(img.len(), 256);
        assert!(img.iter().all(|&v| (0..256).contains(&v)));
    }

    #[test]
    fn mirror_symmetry_holds() {
        let mut src = ImageSource::new(ImageConfig::for_seq_len(256), 2);
        let img = src.gen_image();
        let w = 16;
        for y in 0..16 {
            for x in 0..w / 2 {
                assert_eq!(img[y * w + x], img[y * w + (w - 1 - x)]);
            }
        }
    }

    #[test]
    fn prototypes_differ() {
        let mut src = ImageSource::new(ImageConfig::for_seq_len(256), 3);
        let a = src.gen_image();
        let mut b = src.gen_image();
        for _ in 0..8 {
            if b != a {
                break;
            }
            b = src.gen_image();
        }
        assert_ne!(a, b);
    }

    #[test]
    fn stream_is_deterministic() {
        let mk = || ImageSource::new(ImageConfig::for_seq_len(256), 7);
        assert_eq!(take(&mut mk(), 1000), take(&mut mk(), 1000));
    }

    #[test]
    #[should_panic]
    fn non_square_seq_rejected() {
        ImageConfig::for_seq_len(200);
    }
}

//! Workload substrates: synthetic corpora standing in for the paper's
//! datasets (DESIGN.md §3 documents each substitution).
//!
//! | paper dataset | substrate | long-range signal |
//! |---|---|---|
//! | Wikitext-103  | [`needle`] word-level corpus | payload copy beyond the local window |
//! | enwik-8       | [`bytes`] synthetic byte text | repeated named entities |
//! | CIFAR-10 / ImageNet-64 | [`images`] raster-scan images | mirrored halves + global prototypes |
//! | PG-19         | [`bytes`]+BPE long documents | entity recurrence over 1k+ tokens |
//!
//! All generators are deterministic from a `u64` seed and stream tokens;
//! [`batcher`] packs streams into the `[S, B, T]` blocks the scanned
//! train artifact consumes.

pub mod batcher;
pub mod bytes;
pub mod images;
pub mod needle;
pub mod zipf;

pub use batcher::{BlockBatcher, TokenBlock};

/// A deterministic, endless token source.
pub trait TokenSource {
    /// Vocabulary size the tokens are drawn from.
    fn vocab(&self) -> usize;
    /// Fill `out` with the next tokens of the stream.
    fn fill(&mut self, out: &mut [i32]);
}

/// Convenience: materialize `n` tokens from a source.
pub fn take(src: &mut dyn TokenSource, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; n];
    src.fill(&mut out);
    out
}

/// Build the source matching a CLI `--data` name for a given vocab/seed.
pub fn source_by_name(
    name: &str,
    vocab: usize,
    seq_len: usize,
    window: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn TokenSource>> {
    match name {
        "zipf" => Ok(Box::new(zipf::ZipfSource::new(vocab, 1.1, seed))),
        "needle" => Ok(Box::new(needle::NeedleSource::new(
            needle::NeedleConfig::for_model(vocab, seq_len, window),
            seed,
        ))),
        "bytes" => Ok(Box::new(bytes::ByteTextSource::new(vocab, seed))),
        "images" => Ok(Box::new(images::ImageSource::new(
            images::ImageConfig::for_seq_len(seq_len),
            seed,
        ))),
        other => anyhow::bail!(
            "unknown data source '{other}' (expected zipf|needle|bytes|images)"
        ),
    }
}

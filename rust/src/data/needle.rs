//! Needle/copy corpus — the long-range retrieval workload (Wikitext-103
//! stand-in for Table 2).
//!
//! Each sequence embeds `n_pairs` *needles*: a marker token followed by a
//! random payload appears once, then re-appears verbatim later at a gap
//! strictly larger than twice the local-attention window.  Predicting the
//! second payload requires content-based retrieval of the first — exactly
//! the capability routing attention adds over local attention, and the
//! reason the paper's MIPS argument (Section 6.1: "entities ... consistent
//! throughout the entire sequence") translates into lower perplexity.
//! Filler tokens are Zipf-distributed like natural text.

use super::TokenSource;
use crate::util::rng::{Rng, Zipf};

/// Reserved token ids (must stay below `filler_base`).
pub const MARKER: i32 = 1;

#[derive(Debug, Clone)]
pub struct NeedleConfig {
    pub vocab: usize,
    /// Sequence period of the generator (needle pairs are placed within
    /// each period; generators stream periods back to back).
    pub period: usize,
    /// Payload length in tokens.
    pub payload_len: usize,
    /// Needle pairs per period.
    pub n_pairs: usize,
    /// Minimum gap (tokens) between a pair's two occurrences.
    pub min_gap: usize,
    /// First token id used for filler/payload (below are reserved).
    pub filler_base: usize,
}

impl NeedleConfig {
    /// Sensible defaults for a model with the given vocab / seq_len /
    /// local window: the gap is forced beyond the reach of *blocked* local
    /// attention (2·window).
    pub fn for_model(vocab: usize, seq_len: usize, window: usize) -> NeedleConfig {
        let payload_len = 4.min(seq_len / 16).max(2);
        NeedleConfig {
            vocab,
            period: seq_len,
            payload_len,
            n_pairs: (seq_len / 64).max(1),
            min_gap: (2 * window + payload_len + 2).min(seq_len / 2),
            filler_base: 16.min(vocab / 4),
        }
    }
}

pub struct NeedleSource {
    cfg: NeedleConfig,
    rng: Rng,
    buf: Vec<i32>,
    pos: usize,
    zipf: Zipf,
}

impl NeedleSource {
    pub fn new(cfg: NeedleConfig, seed: u64) -> Self {
        assert!(cfg.filler_base < cfg.vocab);
        assert!(cfg.period > 2 * (cfg.payload_len + 1) + cfg.min_gap,
                "period too short for a needle pair: {:?}", cfg);
        let zipf = Zipf::new(cfg.vocab - cfg.filler_base, 1.1);
        NeedleSource { cfg, rng: Rng::new(seed), buf: Vec::new(), pos: 0, zipf }
    }

    /// Generate one period of tokens with embedded needle pairs.
    fn gen_period(&mut self) -> Vec<i32> {
        let c = &self.cfg;
        let n = c.period;
        let mut toks: Vec<i32> = (0..n)
            .map(|_| (c.filler_base + self.zipf.sample(&mut self.rng)) as i32)
            .collect();
        let item = c.payload_len + 1; // marker + payload
        for _ in 0..c.n_pairs {
            // choose first occurrence start and second start with min gap
            let max_first = n.saturating_sub(2 * item + c.min_gap);
            if max_first == 0 {
                break;
            }
            let p1 = self.rng.below(max_first);
            let lo = p1 + item + c.min_gap;
            let hi = n - item;
            if lo >= hi {
                continue;
            }
            let p2 = self.rng.range(lo, hi);
            let payload: Vec<i32> = (0..c.payload_len)
                .map(|_| (c.filler_base + self.zipf.sample(&mut self.rng)) as i32)
                .collect();
            toks[p1] = MARKER;
            toks[p2] = MARKER;
            for (o, &p) in payload.iter().enumerate() {
                toks[p1 + 1 + o] = p;
                toks[p2 + 1 + o] = p;
            }
        }
        toks
    }

    /// Positions within a generated period that are payload-copy targets
    /// (second occurrences) — used by evaluation to score retrieval.
    pub fn copy_target_mask(period: &[i32], payload_len: usize) -> Vec<bool> {
        // second occurrence of MARKER-initiated runs: mark positions of the
        // *second* payload of each repeated payload string.
        let n = period.len();
        let mut mask = vec![false; n];
        let mut seen: Vec<(usize, &[i32])> = Vec::new();
        let mut i = 0;
        while i < n {
            if period[i] == MARKER && i + payload_len < n {
                let payload = &period[i + 1..i + 1 + payload_len];
                if let Some(_) = seen.iter().find(|(_, p)| *p == payload) {
                    for o in 0..payload_len {
                        mask[i + 1 + o] = true;
                    }
                } else {
                    seen.push((i, payload));
                }
                i += payload_len + 1;
            } else {
                i += 1;
            }
        }
        mask
    }
}

impl TokenSource for NeedleSource {
    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn fill(&mut self, out: &mut [i32]) {
        for t in out.iter_mut() {
            if self.pos >= self.buf.len() {
                self.buf = self.gen_period();
                self.pos = 0;
            }
            *t = self.buf[self.pos];
            self.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::take;

    fn cfg() -> NeedleConfig {
        NeedleConfig::for_model(512, 256, 32)
    }

    #[test]
    fn tokens_in_vocab() {
        let mut src = NeedleSource::new(cfg(), 1);
        let toks = take(&mut src, 4096);
        assert!(toks.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn contains_repeated_payloads_beyond_window() {
        let c = cfg();
        let mut src = NeedleSource::new(c.clone(), 2);
        let period = src.gen_period();
        // find marker positions
        let marks: Vec<usize> =
            (0..period.len()).filter(|&i| period[i] == MARKER).collect();
        assert!(marks.len() >= 2, "expected at least one needle pair");
        // at least one pair repeats its payload at distance > 2*window
        let mut found = false;
        for (a_i, &a) in marks.iter().enumerate() {
            for &b in &marks[a_i + 1..] {
                if b + c.payload_len >= period.len() {
                    continue;
                }
                let pa = &period[a + 1..a + 1 + c.payload_len];
                let pb = &period[b + 1..b + 1 + c.payload_len];
                if pa == pb && b - a >= c.min_gap {
                    found = true;
                }
            }
        }
        assert!(found, "no repeated payload at long range");
    }

    #[test]
    fn copy_target_mask_marks_second_occurrence_only() {
        let payload_len = 2;
        let seq = vec![9, MARKER, 7, 8, 9, 9, MARKER, 7, 8, 9];
        let mask = NeedleSource::copy_target_mask(&seq, payload_len);
        assert_eq!(mask[2], false); // first occurrence
        assert_eq!(mask[7], true); // second occurrence payload
        assert_eq!(mask[8], true);
    }

    #[test]
    fn deterministic() {
        let a = take(&mut NeedleSource::new(cfg(), 5), 1024);
        let b = take(&mut NeedleSource::new(cfg(), 5), 1024);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = take(&mut NeedleSource::new(cfg(), 5), 1024);
        let b = take(&mut NeedleSource::new(cfg(), 6), 1024);
        assert_ne!(a, b);
    }
}

//! IID Zipf token stream — the null workload (no long-range structure).
//!
//! Natural-language unigram frequencies are approximately Zipfian; this
//! source matches that marginal while carrying *no* dependency structure,
//! so every attention variant should perform identically on it (a useful
//! control next to the needle corpus).

use super::TokenSource;
use crate::util::rng::{Rng, Zipf};

pub struct ZipfSource {
    vocab: usize,
    dist: Zipf,
    rng: Rng,
}

impl ZipfSource {
    pub fn new(vocab: usize, exponent: f64, seed: u64) -> Self {
        ZipfSource { vocab, dist: Zipf::new(vocab, exponent), rng: Rng::new(seed) }
    }
}

impl TokenSource for ZipfSource {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn fill(&mut self, out: &mut [i32]) {
        for t in out.iter_mut() {
            *t = self.dist.sample(&mut self.rng) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::take;

    #[test]
    fn tokens_in_vocab_and_zipfian() {
        let mut src = ZipfSource::new(100, 1.2, 7);
        let toks = take(&mut src, 20_000);
        assert!(toks.iter().all(|&t| (0..100).contains(&t)));
        let mut counts = vec![0usize; 100];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        assert!(counts[0] > counts[20]);
    }

    #[test]
    fn deterministic() {
        let a = take(&mut ZipfSource::new(50, 1.1, 3), 256);
        let b = take(&mut ZipfSource::new(50, 1.1, 3), 256);
        assert_eq!(a, b);
    }
}

//! Host-side online spherical k-means — a faithful mirror of the in-graph
//! centroid machinery (Algorithm 1, lines 28-31).
//!
//! Used by the Figure-1 pattern generator (cluster real vectors to draw
//! routing sparsity patterns), the complexity model, and property tests
//! that pin down the EMA/assignment semantics shared with the L2 graph.

#![warn(missing_docs)]

use anyhow::{bail, Context, Result};

use crate::attention::AttentionSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Assignment bookkeeping returned by [`SphericalKMeans::update`] — the
/// signal the incremental re-routing layer keys on.
///
/// MoSA-style expert-choice routing (Piękos et al., 2025) observes that
/// most cluster assignments are stable from step to step even though the
/// centroids keep moving; a serving loop can therefore skip re-routing
/// (and recompiling) whenever an update moved **no** token between
/// clusters.  `moved` lists exactly the tokens whose argmax centroid
/// changed across the EMA step — old assignment taken under the
/// pre-update centroids, new assignment under the post-update ones.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AssignmentDelta {
    /// Per-cluster counts of the (finite) vectors assigned under the
    /// pre-update centroids — the mini-batch that drove the EMA.
    pub counts: Vec<usize>,
    /// `(token, old_cluster, new_cluster)` for every token whose argmax
    /// assignment changed across the centroid update, ascending by token.
    pub moved: Vec<(usize, usize, usize)>,
    /// Finite vectors examined (non-finite ones are quarantined and never
    /// appear in `counts` or `moved`).
    pub assigned: usize,
}

impl AssignmentDelta {
    /// Did this update move any token between clusters (by argmax)?
    ///
    /// `false` is the signal the [`crate::attention::EpochCache`] uses to
    /// keep serving a compiled routing pattern.  Note this is a
    /// deliberate **approximation**: balanced top-w membership
    /// ([`SphericalKMeans::top_w_members`]) ranks *all* tokens per
    /// centroid, so an EMA step can reorder a centroid's top-w list
    /// without flipping any token's argmax — reuse is exact only when
    /// `w == n` (every token is always a member).  Callers that need
    /// strict per-epoch exactness should key invalidation on the cluster
    /// epoch instead (`EpochCache::get_routed`); the incremental flow
    /// trades that slack for skipping most recompiles, MoSA-style.
    pub fn changed(&self) -> bool {
        !self.moved.is_empty()
    }

    /// The tokens in `moved` (the per-update dirty set).
    pub fn moved_tokens(&self) -> impl Iterator<Item = usize> + '_ {
        self.moved.iter().map(|&(token, _, _)| token)
    }

    /// Wire form: `{"counts": [...], "moved": [[token, from, to], ...],
    /// "assigned": N}` — the payload the multi-process coordinator ships
    /// inside every delta broadcast.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "counts".to_string(),
                Json::Arr(self.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            (
                "moved".to_string(),
                Json::Arr(
                    self.moved
                        .iter()
                        .map(|&(token, from, to)| {
                            Json::Arr(vec![
                                Json::Num(token as f64),
                                Json::Num(from as f64),
                                Json::Num(to as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("assigned".to_string(), Json::Num(self.assigned as f64)),
        ])
    }

    /// Parse the [`AssignmentDelta::to_json`] wire form; round-trips to
    /// an identical value (`to_json ∘ from_json ≡ id`).
    pub fn from_json(j: &Json) -> Result<AssignmentDelta> {
        let counts = j
            .get("counts")
            .and_then(Json::as_arr)
            .context("delta missing 'counts' array")?
            .iter()
            .map(|c| c.as_usize().context("'counts' entry is not a usize"))
            .collect::<Result<Vec<usize>>>()?;
        let moved = j
            .get("moved")
            .and_then(Json::as_arr)
            .context("delta missing 'moved' array")?
            .iter()
            .map(|m| {
                let triple = m.as_arr().context("'moved' entry is not an array")?;
                if triple.len() != 3 {
                    bail!("'moved' entry must be [token, from, to]");
                }
                Ok((
                    triple[0].as_usize().context("'moved' token is not a usize")?,
                    triple[1].as_usize().context("'moved' from is not a usize")?,
                    triple[2].as_usize().context("'moved' to is not a usize")?,
                ))
            })
            .collect::<Result<Vec<(usize, usize, usize)>>>()?;
        let assigned =
            j.get("assigned").and_then(Json::as_usize).context("delta missing 'assigned'")?;
        Ok(AssignmentDelta { counts, moved, assigned })
    }
}

/// Online spherical k-means with EMA centroid updates.
#[derive(Debug, Clone)]
pub struct SphericalKMeans {
    /// Number of clusters (>= 1).
    pub k: usize,
    /// Dimensionality of the routing vectors (>= 1).
    pub dim: usize,
    /// EMA decay: `mu <- decay * mu + (1 - decay) * batch_mean`.
    pub decay: f32,
    /// Row-major [k, dim], unit-normalized.
    pub centroids: Vec<f32>,
}

impl SphericalKMeans {
    /// Random unit-vector initialization (seeded).
    ///
    /// Degenerate shapes are rejected loudly: `k == 0` would make
    /// [`SphericalKMeans::assign`] index out of bounds (there is no best
    /// cluster among zero centroids) and `dim == 0` silently produced
    /// empty centroids whose dot products are all `0.0`.
    pub fn new(k: usize, dim: usize, decay: f32, seed: u64) -> Self {
        assert!(k >= 1, "spherical k-means requires k >= 1 clusters (got k = 0)");
        assert!(dim >= 1, "spherical k-means requires dim >= 1 (got dim = 0)");
        let mut rng = Rng::new(seed);
        let mut centroids = vec![0f32; k * dim];
        for c in 0..k {
            for d in 0..dim {
                centroids[c * dim + d] = rng.normal() as f32;
            }
        }
        let mut s = SphericalKMeans { k, dim, decay, centroids };
        s.normalize_all();
        s
    }

    fn normalize_all(&mut self) {
        for c in 0..self.k {
            normalize(&mut self.centroids[c * self.dim..(c + 1) * self.dim]);
        }
    }

    /// Centroid `c` as a `[dim]` slice.
    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// Argmax-dot-product assignment (MIPS on the unit sphere ≡ NNS).
    pub fn assign(&self, x: &[f32]) -> usize {
        debug_assert_eq!(x.len(), self.dim);
        let mut best = 0;
        let mut best_dot = f32::NEG_INFINITY;
        for c in 0..self.k {
            let d = dot(self.centroid(c), x);
            if d > best_dot {
                best_dot = d;
                best = c;
            }
        }
        best
    }

    /// Routing scores of one vector against every centroid.
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        (0..self.k).map(|c| dot(self.centroid(c), x)).collect()
    }

    /// Balanced top-w membership (Algorithm 1 lines 12-15): for every
    /// centroid, the `w` highest-scoring vectors, indices sorted ascending
    /// to preserve temporal order.  `xs` is row-major [n, dim].
    ///
    /// NaN routing scores (a poisoned routing vector upstream) sort
    /// *last*, ties broken by index — the old `partial_cmp(..).unwrap()`
    /// aborted the entire routing pass on the first NaN, taking the
    /// serving loop down with it.  A NaN-scored token is only selected
    /// once every finite-scoring token already is (i.e. when `w == n`).
    pub fn top_w_members(&self, xs: &[f32], n: usize, w: usize) -> Vec<Vec<usize>> {
        (0..self.k).map(|c| self.top_w_of(c, xs, n, w)).collect()
    }

    /// One centroid's balanced top-w membership list — the single-cluster
    /// unit of [`SphericalKMeans::top_w_members`] (identical ordering and
    /// NaN semantics), exposed so an incremental re-router can regenerate
    /// only the clusters an update actually touched (see
    /// `attention::decode::MemberCache`).
    pub fn top_w_of(&self, c: usize, xs: &[f32], n: usize, w: usize) -> Vec<usize> {
        assert_eq!(xs.len(), n * self.dim);
        assert!(c < self.k, "cluster {c} out of bounds for k = {}", self.k);
        let w = w.min(n);
        let mu = self.centroid(c);
        let mut scored: Vec<(f32, usize)> = (0..n)
            .map(|i| (dot(mu, &xs[i * self.dim..(i + 1) * self.dim]), i))
            .collect();
        scored.sort_by(|a, b| match (a.0.is_nan(), b.0.is_nan()) {
            (false, false) => b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)),
            (true, true) => a.1.cmp(&b.1),
            // NaN scores sort last, after every finite score
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
        });
        let mut idx: Vec<usize> = scored[..w].iter().map(|&(_, i)| i).collect();
        idx.sort_unstable();
        idx
    }

    /// One EMA update from a mini-batch of vectors (xs row-major [n, dim]):
    /// hard-assign each vector, average per cluster, EMA, re-project to the
    /// unit sphere.  Empty clusters keep their centroid.  Returns the
    /// [`AssignmentDelta`] (per-cluster counts plus the old→new cluster of
    /// every token the update moved).
    ///
    /// An **empty batch (`n == 0`) is a strict no-op**: the centroids are
    /// untouched and the returned delta reports nothing moved, so callers
    /// (e.g. [`crate::attention::RoutingSession`]) must not bump epochs
    /// or dirty any routing slot for it.
    ///
    /// Non-finite vectors are skipped entirely (and not counted): one NaN
    /// folded into a cluster mean would stick forever — `decay · NaN` is
    /// NaN, and `normalize` cannot rescue it — silently corrupting every
    /// future routing assignment against that centroid.  Skipping mirrors
    /// [`SphericalKMeans::top_w_members`], which sorts NaN scores last.
    pub fn update(&mut self, xs: &[f32], n: usize) -> AssignmentDelta {
        assert_eq!(xs.len(), n * self.dim);
        let mut counts = vec![0usize; self.k];
        if n == 0 {
            return AssignmentDelta { counts, ..AssignmentDelta::default() };
        }
        let mut sums = vec![0f32; self.k * self.dim];
        // assignments under the pre-update centroids; None = quarantined
        let mut old_assign: Vec<Option<usize>> = vec![None; n];
        for i in 0..n {
            let x = &xs[i * self.dim..(i + 1) * self.dim];
            if x.iter().any(|v| !v.is_finite()) {
                continue;
            }
            let c = self.assign(x);
            old_assign[i] = Some(c);
            counts[c] += 1;
            for d in 0..self.dim {
                sums[c * self.dim + d] += x[d];
            }
        }
        for c in 0..self.k {
            if counts[c] == 0 {
                continue;
            }
            let inv = 1.0 / counts[c] as f32;
            for d in 0..self.dim {
                let mean = sums[c * self.dim + d] * inv;
                let mu = &mut self.centroids[c * self.dim + d];
                *mu = self.decay * *mu + (1.0 - self.decay) * mean;
            }
            normalize(&mut self.centroids[c * self.dim..(c + 1) * self.dim]);
        }
        // re-assign under the moved centroids: the incremental-routing
        // delta (costs one extra assignment pass, same order as the one
        // above; buys every skipped recompile downstream)
        let mut moved = Vec::new();
        let mut assigned = 0usize;
        for (i, old) in old_assign.iter().enumerate() {
            let Some(old) = *old else { continue };
            assigned += 1;
            let new = self.assign(&xs[i * self.dim..(i + 1) * self.dim]);
            if new != old {
                moved.push((i, old, new));
            }
        }
        AssignmentDelta { counts, moved, assigned }
    }

    /// Package balanced top-w membership over the given routing vectors
    /// (row-major [n, dim]) as a routing [`AttentionSpec`] — Algorithm 1's
    /// content-based index sets, ready to `compile(n)` into CSR.
    pub fn routing_spec(&self, xs: &[f32], n: usize, w: usize) -> AttentionSpec {
        AttentionSpec::routing(self.top_w_members(xs, n, w))
    }

    /// Hard argmax assignment buckets: for each cluster, the sorted
    /// indices of the tokens whose best centroid it is (first-index-wins
    /// on score ties, matching [`SphericalKMeans::assign`]).  Unlike the
    /// overlapping top-w memberships of [`SphericalKMeans::top_w_members`],
    /// buckets are **disjoint**.  Non-finite vectors are quarantined
    /// (assigned to no bucket), mirroring [`SphericalKMeans::update`].
    pub fn assigned_buckets(&self, xs: &[f32], n: usize) -> Vec<Vec<usize>> {
        assert_eq!(xs.len(), n * self.dim);
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for i in 0..n {
            let x = &xs[i * self.dim..(i + 1) * self.dim];
            if x.iter().any(|v| !v.is_finite()) {
                continue;
            }
            buckets[self.assign(x)].push(i);
        }
        buckets
    }

    /// One cluster's expert-choice selection: rank the tokens of its
    /// `bucket` by routing score against centroid `c` (NaN-last
    /// total-order sort, ties by ascending index — the
    /// [`SphericalKMeans::top_w_of`] comparator) and keep the first
    /// `capacity`, indices sorted ascending.  The single-cluster unit of
    /// [`SphericalKMeans::top_capacity_tokens`], exposed so an
    /// incremental re-router can re-rank only the clusters an update
    /// actually touched (see `attention::decode::MemberCache`).
    pub fn top_capacity_of(
        &self,
        c: usize,
        bucket: &[usize],
        xs: &[f32],
        n: usize,
        capacity: usize,
    ) -> Vec<usize> {
        assert_eq!(xs.len(), n * self.dim);
        assert!(c < self.k, "cluster {c} out of bounds for k = {}", self.k);
        let mu = self.centroid(c);
        let mut scored: Vec<(f32, usize)> = bucket
            .iter()
            .map(|&i| (dot(mu, &xs[i * self.dim..(i + 1) * self.dim]), i))
            .collect();
        scored.sort_by(|a, b| match (a.0.is_nan(), b.0.is_nan()) {
            (false, false) => b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)),
            (true, true) => a.1.cmp(&b.1),
            // NaN scores sort last, after every finite score
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
        });
        scored.truncate(capacity);
        let mut idx: Vec<usize> = scored.into_iter().map(|(_, i)| i).collect();
        idx.sort_unstable();
        idx
    }

    /// Expert-choice membership (MoSA-style): hard-assign every finite
    /// token to its argmax centroid, then each cluster keeps only its
    /// top-`capacity` assigned tokens by routing score.  The dual of
    /// [`SphericalKMeans::top_w_members`] — *clusters pick tokens* from
    /// disjoint buckets instead of every cluster ranking all tokens — so
    /// per-cluster membership (and hence per-cluster compiled nnz) is
    /// bounded by `capacity` by construction.
    pub fn top_capacity_tokens(&self, xs: &[f32], n: usize, capacity: usize) -> Vec<Vec<usize>> {
        let buckets = self.assigned_buckets(xs, n);
        (0..self.k).map(|c| self.top_capacity_of(c, &buckets[c], xs, n, capacity)).collect()
    }

    /// Package expert-choice membership as an
    /// [`AttentionSpec::ExpertChoice`] — the capacity-bounded counterpart
    /// of [`SphericalKMeans::routing_spec`].
    pub fn expert_choice_spec(&self, xs: &[f32], n: usize, capacity: usize) -> AttentionSpec {
        AttentionSpec::expert_choice(self.top_capacity_tokens(xs, n, capacity), capacity)
            .expect("top_capacity_tokens bounds every cluster by capacity")
    }

    /// Mean within-cluster dot product (clustering quality metric).
    pub fn cohesion(&self, xs: &[f32], n: usize) -> f32 {
        let mut total = 0.0;
        for i in 0..n {
            let x = &xs[i * self.dim..(i + 1) * self.dim];
            let c = self.assign(x);
            total += dot(self.centroid(c), x);
        }
        total / n.max(1) as f32
    }
}

/// Plain dot product over two equal-length slices.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Scale `a` to unit norm in place (norm clamped away from zero).
pub fn normalize(a: &mut [f32]) {
    let n = norm(a).max(1e-6);
    for x in a.iter_mut() {
        *x /= n;
    }
}

/// LayerNorm without scale/bias — the paper's unit-ball projection,
/// mirrored for host-side analysis (norm of the output ≈ sqrt(dim)).
pub fn layernorm_nsb(x: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-6).sqrt();
    x.iter().map(|v| (v - mean) * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn clustered_data(n: usize, dim: usize, k: usize, seed: u64) -> Vec<f32> {
        // k well-separated directions + small noise, unit-normalized
        let mut rng = Rng::new(seed);
        let mut xs = vec![0f32; n * dim];
        for i in 0..n {
            let c = i % k;
            for d in 0..dim {
                let base = if d == c { 4.0 } else { 0.0 };
                xs[i * dim + d] = base + rng.normal() as f32 * 0.2;
            }
            normalize(&mut xs[i * dim..(i + 1) * dim]);
        }
        xs
    }

    #[test]
    fn centroids_stay_unit_norm() {
        let mut km = SphericalKMeans::new(4, 8, 0.5, 1);
        let xs = clustered_data(64, 8, 4, 2);
        for _ in 0..10 {
            km.update(&xs, 64);
        }
        for c in 0..4 {
            assert!((norm(km.centroid(c)) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn recovers_separated_clusters() {
        let mut km = SphericalKMeans::new(4, 8, 0.2, 3);
        let xs = clustered_data(256, 8, 4, 4);
        for _ in 0..50 {
            km.update(&xs, 256);
        }
        assert!(km.cohesion(&xs, 256) > 0.8, "cohesion {}", km.cohesion(&xs, 256));
    }

    #[test]
    fn update_improves_cohesion() {
        let mut km = SphericalKMeans::new(4, 8, 0.2, 5);
        let xs = clustered_data(256, 8, 4, 6);
        let before = km.cohesion(&xs, 256);
        for _ in 0..30 {
            km.update(&xs, 256);
        }
        assert!(km.cohesion(&xs, 256) > before);
    }

    #[test]
    fn top_w_balanced_and_sorted() {
        let km = SphericalKMeans::new(3, 8, 0.5, 7);
        let xs = clustered_data(30, 8, 3, 8);
        let members = km.top_w_members(&xs, 30, 10);
        assert_eq!(members.len(), 3);
        for m in &members {
            assert_eq!(m.len(), 10);
            assert!(m.windows(2).all(|p| p[0] < p[1]), "sorted unique");
        }
    }

    #[test]
    fn top_w_of_matches_full_membership_per_cluster() {
        let km = SphericalKMeans::new(4, 8, 0.5, 17);
        let mut xs = clustered_data(24, 8, 4, 18);
        xs[5 * 8] = f32::NAN; // NaN ordering must match too
        for w in [1usize, 3, 24, 40] {
            let all = km.top_w_members(&xs, 24, w);
            for c in 0..4 {
                assert_eq!(all[c], km.top_w_of(c, &xs, 24, w), "cluster {c}, w {w}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn top_w_of_rejects_bad_cluster() {
        let km = SphericalKMeans::new(2, 4, 0.5, 1);
        km.top_w_of(2, &[0.0; 8], 2, 1);
    }

    #[test]
    fn routing_spec_compiles_to_member_sets() {
        let km = SphericalKMeans::new(3, 8, 0.5, 7);
        let xs = clustered_data(30, 8, 3, 8);
        let spec = km.routing_spec(&xs, 30, 10);
        let members = km.top_w_members(&xs, 30, 10);
        let p = spec.compile(30);
        assert!(p.is_causal());
        for m in &members {
            for (idx, &i) in m.iter().enumerate() {
                for &j in &m[..=idx] {
                    assert!(p.allowed(i, j), "member pair ({i},{j}) must be admitted");
                }
            }
        }
    }

    #[test]
    fn top_capacity_buckets_are_disjoint_and_capacity_bounded() {
        let km = SphericalKMeans::new(3, 8, 0.5, 7);
        let xs = clustered_data(30, 8, 3, 8);
        let buckets = km.assigned_buckets(&xs, 30);
        assert_eq!(buckets.iter().map(Vec::len).sum::<usize>(), 30, "buckets partition tokens");
        for cap in [0usize, 1, 4, 100] {
            let members = km.top_capacity_tokens(&xs, 30, cap);
            assert_eq!(members.len(), 3);
            let mut seen = std::collections::HashSet::new();
            for (c, m) in members.iter().enumerate() {
                assert!(m.len() <= cap, "cluster {c} over capacity {cap}");
                assert!(m.len() <= buckets[c].len(), "selection stays inside the bucket");
                assert!(m.windows(2).all(|p| p[0] < p[1]), "sorted unique");
                for &i in m {
                    assert!(seen.insert(i), "token {i} selected by two clusters");
                    assert_eq!(km.assign(&xs[i * 8..(i + 1) * 8]), c, "selection ⊆ bucket");
                }
            }
        }
        // capacity >= every bucket: selection IS the bucket
        assert_eq!(km.top_capacity_tokens(&xs, 30, 30), buckets);
        // the spec wrapper upholds the constructor's capacity invariant
        let spec = km.expert_choice_spec(&xs, 30, 4);
        match &spec {
            AttentionSpec::ExpertChoice { clusters, capacity } => {
                assert_eq!(*capacity, 4);
                assert!(clusters.iter().all(|m| m.len() <= 4));
            }
            _ => unreachable!(),
        }
        assert!(spec.compile(30).is_causal());
    }

    #[test]
    fn top_capacity_quarantines_non_finite_and_breaks_ties_by_index() {
        let km = SphericalKMeans::new(2, 4, 0.5, 11);
        let mut xs = clustered_data(8, 4, 2, 12);
        xs[3 * 4] = f32::NAN;
        let buckets = km.assigned_buckets(&xs, 8);
        assert!(buckets.iter().all(|b| !b.contains(&3)), "poisoned token assigned nowhere");
        assert_eq!(buckets.iter().map(Vec::len).sum::<usize>(), 7);
        let members = km.top_capacity_tokens(&xs, 8, 8);
        assert!(members.iter().all(|m| !m.contains(&3)));
        // duplicate-score ranking is deterministic: equal scores keep the
        // lowest indices (same comparator as top_w_of)
        let mut dup = SphericalKMeans::new(1, 2, 0.5, 1);
        dup.centroids = vec![1.0, 0.0];
        let xs = vec![0.5, 0.5, 0.5, -0.5, 0.5, 0.0];
        assert_eq!(dup.top_capacity_of(0, &[0, 1, 2], &xs, 3, 2), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn top_capacity_of_rejects_bad_cluster() {
        let km = SphericalKMeans::new(2, 4, 0.5, 1);
        km.top_capacity_of(2, &[], &[0.0; 8], 2, 1);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let mut km = SphericalKMeans::new(2, 4, 0.5, 9);
        // all mass on direction 0 -> one cluster may starve
        let mut xs = vec![0f32; 16 * 4];
        for i in 0..16 {
            xs[i * 4] = 1.0;
        }
        let before: Vec<f32> = km.centroids.clone();
        let delta = km.update(&xs, 16);
        for c in 0..2 {
            if delta.counts[c] == 0 {
                assert_eq!(km.centroid(c), &before[c * 4..(c + 1) * 4]);
            }
        }
    }

    #[test]
    fn update_on_empty_batch_is_noop() {
        // regression: an n = 0 update must not touch the centroids or
        // report movement — callers key epoch bumps and dirty sets on
        // this delta, and an empty batch must not force a recompile
        let mut km = SphericalKMeans::new(3, 4, 0.5, 13);
        let before = km.centroids.clone();
        let delta = km.update(&[], 0);
        assert_eq!(km.centroids, before, "centroids must be untouched");
        assert_eq!(delta.counts, vec![0; 3]);
        assert!(!delta.changed());
        assert_eq!(delta.assigned, 0);
        assert_eq!(delta.moved_tokens().count(), 0);
    }

    #[test]
    fn update_delta_matches_before_after_assign_oracle() {
        // the reported moved set must be exactly { i | assign_before(x_i)
        // != assign_after(x_i) }, computed here with the public assign()
        // on a cloned pre-update state
        let mut rng = Rng::new(31);
        for case in 0..50 {
            let mut km = SphericalKMeans::new(3, 4, 0.3, 100 + case);
            let n = 24;
            let xs: Vec<f32> = (0..n * 4).map(|_| rng.normal() as f32).collect();
            let before = km.clone();
            let delta = km.update(&xs, n);
            let mut expect = Vec::new();
            for i in 0..n {
                let x = &xs[i * 4..(i + 1) * 4];
                let (old, new) = (before.assign(x), km.assign(x));
                if old != new {
                    expect.push((i, old, new));
                }
            }
            assert_eq!(delta.moved, expect, "case {case}");
            assert_eq!(delta.assigned, n);
            assert_eq!(delta.counts.iter().sum::<usize>(), n);
        }
    }

    #[test]
    fn update_delta_detects_a_boundary_flip() {
        // hand-built flip: centroid 1 starts between the two tokens,
        // captures both, then (decay = 0 -> centroid := cluster mean)
        // gets pulled toward the y-axis token, releasing token 0 to
        // centroid 0.  Every comparison below has a wide float margin.
        let mut km = SphericalKMeans::new(2, 2, 0.0, 1);
        km.centroids = vec![1.0, 0.0, 0.9397, 0.342];
        let xs = vec![0.98, 0.2, 0.0, 1.0];
        let delta = km.update(&xs, 2);
        assert_eq!(delta.counts, vec![0, 2], "both tokens start on centroid 1");
        assert_eq!(delta.moved, vec![(0, 1, 0)], "token 0 must flip to centroid 0");
        assert!(delta.changed());
    }

    #[test]
    fn top_w_nan_scores_sort_last_instead_of_panicking() {
        // token 3's routing vector is poisoned: its dot against every
        // centroid is NaN, which used to abort the pass via
        // partial_cmp(..).unwrap()
        let km = SphericalKMeans::new(2, 4, 0.5, 11);
        let mut xs = clustered_data(8, 4, 2, 12);
        xs[3 * 4] = f32::NAN;
        let members = km.top_w_members(&xs, 8, 3);
        assert_eq!(members.len(), 2);
        for m in &members {
            assert_eq!(m.len(), 3, "balanced membership survives NaN scores");
            assert!(m.windows(2).all(|p| p[0] < p[1]));
            assert!(!m.contains(&3), "NaN-scored token must sort after every finite one");
        }
        // w = n still admits every token, NaN-scored ones last
        for m in &km.top_w_members(&xs, 8, 8) {
            assert_eq!(m.len(), 8);
        }
        // the spec -> compile path stays NaN-safe end to end
        let p = km.routing_spec(&xs, 8, 3).compile(8);
        assert!(p.is_causal());
    }

    #[test]
    fn update_skips_non_finite_vectors() {
        // a poisoned vector folded into the EMA would make the centroid
        // NaN forever (decay * NaN = NaN); update must quarantine it
        let mut km = SphericalKMeans::new(2, 4, 0.5, 21);
        let mut xs = clustered_data(8, 4, 2, 22);
        xs[0] = f32::NAN;
        xs[4 + 2] = f32::INFINITY;
        let delta = km.update(&xs, 8);
        assert_eq!(delta.counts.iter().sum::<usize>(), 6, "the two poisoned vectors are skipped");
        assert_eq!(delta.assigned, 6, "quarantined vectors never enter the delta");
        assert!(delta.moved_tokens().all(|t| t != 0 && t != 1), "poisoned tokens cannot move");
        assert!(km.centroids.iter().all(|c| c.is_finite()), "centroids must stay finite");
        for _ in 0..5 {
            km.update(&xs, 8);
        }
        assert!(km.centroids.iter().all(|c| c.is_finite()), "finiteness must persist");
        // routing over the same poisoned batch still works end to end
        let p = km.routing_spec(&xs, 8, 4).compile(8);
        assert!(p.is_causal());
    }

    #[test]
    #[should_panic(expected = "k >= 1")]
    fn zero_clusters_rejected() {
        SphericalKMeans::new(0, 4, 0.5, 1);
    }

    #[test]
    #[should_panic(expected = "dim >= 1")]
    fn zero_dim_rejected() {
        SphericalKMeans::new(4, 0, 0.5, 1);
    }

    #[test]
    #[should_panic(expected = "assertion")]
    fn update_shape_mismatch_rejected() {
        let mut km = SphericalKMeans::new(2, 4, 0.5, 1);
        km.update(&[0.0; 7], 2); // 7 != 2 * 4
    }

    #[test]
    fn layernorm_nsb_norm() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.3 - 7.0).collect();
        let y = layernorm_nsb(&x);
        assert!((norm(&y) - (64f32).sqrt()).abs() < 1e-2);
    }
}

//! # Routing Transformer — Rust + JAX + Pallas reproduction
//!
//! Reproduction of *"Efficient Content-Based Sparse Attention with Routing
//! Transformers"* (Roy, Saffar, Vaswani, Grangier — TACL 2020) as a
//! three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the
//!   within-cluster attention hot-spot of Algorithm 1, blocked local
//!   attention, dense causal attention.
//! * **L2** — JAX model (`python/compile/model.py`): the decoder-only LM
//!   with mixed local/routing/full/random/strided head plans, online
//!   spherical k-means routing, Adam + centroid-EMA train step; AOT-lowered
//!   to HLO text by `python/compile/aot.py`.
//! * **L3** — this crate: the coordinator that loads the HLO artifacts via
//!   PJRT (`runtime`), generates workloads ([`data`], [`tokenizer`]),
//!   drives training/eval (`coordinator`), samples ([`sampler`]),
//!   and reproduces every table and figure of the paper ([`analysis`],
//!   [`attention`], `rust/benches/`).  Sparsity semantics flow through one
//!   spec→compile pipeline: a declarative
//!   [`attention::AttentionSpec`] (full / local / block-local / strided /
//!   routing, composable into mixed head plans with `Union`/`Intersect`)
//!   compiles once per sequence length into a CSR-indexed
//!   [`attention::CompiledPattern`] that feeds the Figure-1 renderers, the
//!   exact and asymptotic cost models, and the JSD analysis from a single
//!   source of truth.
//!
//! Python runs once at build time (`make artifacts`); the `rtx` binary is
//! self-contained afterwards.
//!
//! The PJRT-backed layers (`runtime`, `coordinator`, `bench`,
//! `config`, and the sampler's `Generator`) sit behind the default-on
//! `xla` cargo feature; `--no-default-features` builds the host-only
//! crate (attention + engine, kmeans, analysis, data, tokenizer, util)
//! without the XLA native toolchain, which is what CI's tier-1 job runs.

pub mod analysis;
pub mod attention;
#[cfg(feature = "xla")]
pub mod bench;
#[cfg(feature = "xla")]
pub mod config;
#[cfg(feature = "xla")]
pub mod coordinator;
pub mod data;
pub mod kmeans;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sampler;
pub mod tokenizer;
pub mod util;

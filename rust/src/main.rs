//! `rtx` — the Routing Transformer coordinator CLI.
//!
//! Self-contained after `make artifacts`; Python never runs here.
//!
//! ```text
//! rtx info     [--artifacts DIR] [--variant NAME]     artifact inventory
//! rtx train    --variant NAME [--steps N] [--data D] [--out CKPT] ...
//! rtx eval     --variant NAME [--ckpt CKPT] [--data D] [--batches N]
//! rtx sample   --variant NAME [--ckpt CKPT] [--tokens N] [--top-p P]
//! rtx analyze  [--variant analysis] [--ckpt CKPT] [--runs N]   Table 6 JSD
//! rtx figure1  [--n 64] [--window 8] [--stride 8] [--clusters 8] [--stats]
//! rtx serve-bench [--n 256] [--heads 8] [--layers 4] [--steps 8] [--shards 4]
//!                 [--sequences 1] [--route-every 2] [--drift-every 4]
//!                 [--backend reference,blocked] [--pool] [--json]
//! rtx serve    [--n 256] [--heads 8] [--layers 4] [--capacity 8] [--requests 64]
//!              [--rate 1.0] [--zipf 1.1] [--backend blocked] [--json] [--append]
//! ```
//!
//! The PJRT-backed commands (`info`/`train`/`eval`/`sample`/`analyze`) need
//! the default `xla` feature; the pattern-engine commands (`figure1`,
//! `serve-bench`, `serve`) run in the `--no-default-features` host build
//! too — that is the binary CI's `rust-host` job smokes.

#[cfg(feature = "xla")]
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use routing_transformer::analysis;
use routing_transformer::attention::{
    assert_outputs_match, backend, optimal_clusters, run_serve, run_worker, sparse_attention,
    threshold_content_spec, ArrivalConfig, AttentionSpec, Backend, BatchedAttention,
    CompiledPattern, EpochCache, Exactness, Execution, MemberCache, RegenStats, RouteSlot,
    RoutingSession, ServeOptions, ServeSummary, SpecFamily, WorkerPool, JSON_SCHEMA_VERSION,
};
#[cfg(feature = "xla")]
use routing_transformer::coordinator::{
    default_data_for, eval_batcher, train_batcher, Evaluator, LrSchedule, TrainOptions,
    Trainer,
};
#[cfg(feature = "xla")]
use routing_transformer::data;
use routing_transformer::kmeans::SphericalKMeans;
#[cfg(feature = "xla")]
use routing_transformer::runtime::{Artifacts, ModelState, Runtime};
#[cfg(feature = "xla")]
use routing_transformer::sampler::{Generator, SamplerConfig};
#[cfg(feature = "xla")]
use routing_transformer::tokenizer::{ByteTokenizer, Tokenizer};
use routing_transformer::util::cli::Args;
use routing_transformer::util::json::Json;
use routing_transformer::util::rng::Rng;
use routing_transformer::util::timing::{StreamingHistogram, Table};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => cmd_info(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "sample" => cmd_sample(args),
        "analyze" => cmd_analyze(args),
        "figure1" => cmd_figure1(args),
        "serve-bench" => cmd_serve_bench(args),
        "serve" => cmd_serve(args),
        "worker" => cmd_worker(args),
        "help" | _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
rtx — Routing Transformer coordinator (paper: Roy et al., TACL 2020)

commands:
  info      list artifact variants (--artifacts DIR, --variant NAME for detail)
  train     train a variant: --variant NAME | --config configs/FILE.toml
            [--steps N] [--data zipf|needle|bytes|images]
            [--schedule constant:LR|inv_sqrt:SCALE:WARMUP|rsqrt:LR:WARMUP]
            [--out CKPT] [--log-csv FILE] [--seed S] [--log-every N]
  eval      evaluate: --variant NAME [--ckpt CKPT] [--data D] [--batches N] [--unit ppl|bits]
  sample    generate: --variant NAME [--ckpt CKPT] [--tokens N] [--top-p P] [--temp T] [--seed S]
  analyze   Table-6 JSD study: [--variant analysis] [--ckpt CKPT] [--runs 10] [--data needle]
  figure1   render Figure-1 attention patterns (local, strided, routing, mixed,
            expert-choice, score-threshold): [--n 64] [--window 8] [--stride 8] [--clusters 8]
            [--stats] (nnz/density/row-size table per scheme) [--csv FILE] [--seed S]
            [--render-rows 128] (clip ASCII/CSV renders to the first R rows so
             large --n stays printable; a truncation marker notes clipped rows)
  serve-bench  heads x layers x steps decode sweep over the pattern engine:
            [--n 256] [--d 64] [--heads 8] [--layers 4] [--steps 8] [--shards 4]
            [--window W] [--clusters K] [--sequences B] [--route-every R]
            [--drift-every D] [--backend NAMES] [--seed S] [--pool] [--json]
            (B requests batched per worker sweep, k-means re-fit every R steps,
             content drift every D steps, incremental assignment-delta
             invalidation and dirty-cluster-only membership regeneration; prints
             epoch hit rate, unchanged-epoch hits, evictions, dirty tokens,
             membership rows regenerated vs reused, rows/sec per backend
             (--backend, comma-separated registry names; default
             reference,blocked; e.g. simd for the fast-math tier — every
             backend is checked per step against the first under its
             declared exactness contract: bitwise, or ulps(k) for
             fast-math), and batched vs sequential rows/sec (the
             sequential Reference oracle only runs when more than one
             backend is requested); retires every sequence's routed slots
             on completion (stream-close GC); --pool adds resident-pool vs
             scoped-spawn comparison rows; --json appends one machine-readable
             summary line, schema documented in ARCHITECTURE.md)
  serve     continuous-batching server front-end over the same engine:
            requests arrive over virtual time (seeded exponential
            interarrivals, Zipf content popularity), are admitted against
            per-request deadlines, join/leave the decode batch mid-flight,
            and retire through per-slot epoch-cache GC — the asynchronous
            counterpart to serve-bench's lock-step sweep:
            [--n 256] [--d 64] [--heads 8] [--layers 4] [--window W]
            [--clusters K] [--capacity 8] [--shards 4] [--workers 0]
            [--route-every 4]
            [--requests 64] [--rate 1.0] [--contents 64] [--zipf 1.1]
            [--work-min 4] [--work-max 16] [--slack-min 8] [--slack-max 64]
            [--backend blocked] [--seed S] [--json] [--append [FILE]]
            [--max-pattern-bytes B] [--band-rows R]
            [--spec routing|expert-choice|threshold]
            (--spec picks the content-based family the odd heads route
             through: classic overlapping top-w routing (default),
             capacity-bounded expert-choice routing — disjoint argmax
             buckets, each cluster keeps its top-capacity members, so
             per-cluster nnz is bounded by construction — or the
             calibrated score-threshold attend set; the family name and
             the max_cluster_nnz / max_shard_nnz / min_shard_nnz
             load-balance observables land in the schema-6 --json line;
             --backend picks any registered kernel by name — blocked stays
             bitwise, simd trades bitwise for >= 3x throughput within its
             declared ulps budget; the backend name and exactness land in
             the --json line; --shards sets intra-process chunk parallelism
             per batched sweep; --workers N > 0 instead splits every sweep
             across N spawned `rtx worker` OS subprocesses via the
             multi-process coordinator — bit-identical output_digest to
             --workers 0, monolithic mode only; --band-rows R > 0 switches
             to memory-bounded
             banded compilation: patterns are compiled on demand in R-row
             bands against a shared byte budget of B (--max-pattern-bytes,
             0 = unbounded) with LRU spill, bit-identical outputs, and
             peak/resident/evicted pattern bytes reported in the summary
             and the schema-5 --json line; prints
             admitted/completed/rejected/shed counts, p50/p99 step
             latency from a streaming histogram, rows/sec, and the
             cache/epoch/regen counters; --json prints one machine-readable
             line, --append appends it to BENCH_serve.json (or FILE) so the
             perf trajectory persists across runs; schema in ARCHITECTURE.md)
  worker    multi-process serve worker (spawned by `rtx serve --workers N`
            over stdin/stdout length-prefixed JSON frames; not for
            interactive use): [--id N]

info/train/eval/sample/analyze need the default `xla` build; figure1,
serve-bench, serve, and worker also work with --no-default-features
(host-only).
";

#[cfg(not(feature = "xla"))]
fn xla_required(cmd: &str) -> Result<()> {
    bail!(
        "`rtx {cmd}` needs the PJRT runtime, but this binary was built without the \
         `xla` feature (host-only build); rebuild with default features to enable it"
    )
}

#[cfg(not(feature = "xla"))]
fn cmd_info(_args: &Args) -> Result<()> {
    xla_required("info")
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_args: &Args) -> Result<()> {
    xla_required("train")
}

#[cfg(not(feature = "xla"))]
fn cmd_eval(_args: &Args) -> Result<()> {
    xla_required("eval")
}

#[cfg(not(feature = "xla"))]
fn cmd_sample(_args: &Args) -> Result<()> {
    xla_required("sample")
}

#[cfg(not(feature = "xla"))]
fn cmd_analyze(_args: &Args) -> Result<()> {
    xla_required("analyze")
}

#[cfg(feature = "xla")]
fn artifacts_root(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

#[cfg(feature = "xla")]
fn load_artifacts(args: &Args) -> Result<(Runtime, Artifacts)> {
    let rt = Runtime::cpu()?;
    let variant = args.str_req("variant")?;
    let art = Artifacts::load(&artifacts_root(args), &variant)?;
    Ok((rt, art))
}

#[cfg(feature = "xla")]
fn load_state(art: &Artifacts, args: &Args) -> Result<ModelState> {
    match args.flags.get("ckpt") {
        Some(path) => ModelState::load(&art.manifest, Path::new(path)),
        None => art.init_state(),
    }
}

#[cfg(feature = "xla")]
fn cmd_info(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    if let Some(variant) = args.flags.get("variant") {
        let art = Artifacts::load(&root, variant)?;
        let m = &art.manifest;
        println!("variant:     {}", m.variant);
        println!("group:       {}", m.group);
        println!("params:      {} arrays, {} scalars", m.params.len(), m.n_params_total);
        let c = &m.config;
        println!(
            "model:       d={} L={} H={} T={} V={}",
            c.d_model, c.n_layers, c.n_heads, c.seq_len, c.vocab_size
        );
        println!(
            "routing:     k={} w={} local window={} decay={}",
            c.n_clusters, c.routing_window, c.window, c.centroid_decay
        );
        for (l, plan) in c.plan.iter().enumerate() {
            println!(
                "layer {l:>2}:    local={} routing={} full={} random={} strided={}",
                plan.local, plan.routing, plan.full, plan.random, plan.strided
            );
        }
        println!("batch:       {} (scan_steps {})", m.batch, m.scan_steps);
        for (name, a) in &m.artifacts {
            println!("artifact:    {name:<12} {} -> {}", a.inputs, a.outputs);
        }
    } else {
        println!("artifact variants under {}:", root.display());
        for name in Artifacts::list(&root)? {
            let art = Artifacts::load(&root, &name)?;
            let m = &art.manifest;
            println!(
                "  {:<18} group={:<8} T={:<5} params={:<9} artifacts={}",
                m.variant,
                m.group,
                m.config.seq_len,
                m.n_params_total,
                m.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
            );
        }
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_train(args: &Args) -> Result<()> {
    // --config FILE loads a RunConfig; individual CLI flags override it.
    let file_cfg = match args.flags.get("config") {
        Some(path) => Some(routing_transformer::config::RunConfig::load(Path::new(path))?),
        None => None,
    };
    let rt = Runtime::cpu()?;
    let variant = match (&file_cfg, args.flags.get("variant")) {
        (_, Some(v)) => v.clone(),
        (Some(c), None) => c.variant.clone(),
        (None, None) => anyhow::bail!("missing --variant (or --config)"),
    };
    let art = Artifacts::load(&artifacts_root(args), &variant)?;
    let manifest = art.manifest.clone();
    let default_data = file_cfg
        .as_ref()
        .and_then(|c| c.data.clone())
        .unwrap_or_else(|| default_data_for(&manifest).to_string());
    let data_name = args.str("data", &default_data);
    let seed = args.u64("seed", file_cfg.as_ref().map(|c| c.seed).unwrap_or(0))?;
    let state = load_state(&art, args)?;

    let mut trainer = Trainer::with_state(&rt, &art, state)?;
    let mut batcher = train_batcher(&manifest, &data_name, seed)?;
    let base = file_cfg.as_ref().map(|c| c.train_options()).unwrap_or_default();
    let opts = TrainOptions {
        steps: args.usize("steps", base.steps)?,
        schedule: match args.flags.get("schedule") {
            Some(spec) => LrSchedule::parse(spec)?,
            None => base.schedule,
        },
        log_every: args.usize("log-every", base.log_every)?,
        ckpt_every: args.usize("ckpt-every", base.ckpt_every)?,
        ckpt_path: args.flags.get("out").map(PathBuf::from).or(base.ckpt_path),
        log_csv: args.flags.get("log-csv").map(PathBuf::from).or(base.log_csv),
    };
    println!(
        "training variant '{}' on '{}' data for {} steps (platform: {})",
        manifest.variant, data_name, opts.steps, rt.platform()
    );
    let report = trainer.train(&mut batcher, &manifest, &opts)?;
    println!(
        "done: {} steps, final loss {:.4}, mean(last 10) {:.4}, {:.2} steps/s",
        report.steps, report.final_loss, report.mean_last10_loss, report.steps_per_sec
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_eval(args: &Args) -> Result<()> {
    let (rt, art) = load_artifacts(args)?;
    let manifest = &art.manifest;
    let data_name = args.str("data", default_data_for(manifest));
    let state = load_state(&art, args)?;
    let evaluator = Evaluator::new(&rt, &art)?;
    let mut batcher = eval_batcher(manifest, &data_name, args.u64("seed", 1)?)?;
    let n = args.usize("batches", 8)?;
    let report = evaluator.eval(&state, &mut batcher, n)?;
    println!(
        "eval[{}] on '{}': nll {:.4} nats | ppl {:.2} | bits/dim {:.4}  ({} batches)",
        manifest.variant, data_name, report.mean_nll, report.ppl(), report.bits_per_dim(), n
    );
    if data_name == "needle" {
        let mut batcher = eval_batcher(manifest, &data_name, args.u64("seed", 1)? + 7)?;
        let payload = 4.min(manifest.config.seq_len / 16).max(2);
        let (copy, all) = evaluator.eval_retrieval(&state, &mut batcher, n, payload)?;
        println!(
            "retrieval: copy-target nll {:.4} vs overall {:.4} (gap {:+.4})",
            copy, all, copy - all
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_sample(args: &Args) -> Result<()> {
    let (rt, art) = load_artifacts(args)?;
    let manifest = &art.manifest;
    let state = load_state(&art, args)?;
    let exe = art.executable(&rt, "logits")?;
    let cfg = SamplerConfig {
        temperature: args.f32("temp", 1.0)?,
        top_p: args.f32("top-p", 0.8)?,
    };
    let mut generator = Generator::new(
        &exe,
        &state,
        manifest.config.seq_len,
        manifest.config.vocab_size,
        cfg,
        args.u64("seed", 0)?,
    );
    let n = args.usize("tokens", 64)?;
    let prompt_text = args.str("prompt", "");
    let tok = ByteTokenizer;
    let prompt: Vec<i32> = if prompt_text.is_empty() {
        vec![0]
    } else {
        tok.encode(&prompt_text)
            .into_iter()
            .map(|t| t.min(manifest.config.vocab_size as i32 - 1))
            .collect()
    };
    let out = generator.generate(&prompt, n)?;
    println!("token ids: {:?}", &out);
    if manifest.config.vocab_size == 256 {
        println!("as bytes:  {:?}", tok.decode(&out));
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_analyze(args: &Args) -> Result<()> {
    let rt = Runtime::cpu()?;
    let variant = args.str("variant", "analysis");
    let art = Artifacts::load(&artifacts_root(args), &variant)?;
    let manifest = &art.manifest;
    let cfg = &manifest.config;
    if !manifest.artifacts.contains_key("attn_probs") {
        bail!("variant '{}' has no attn_probs artifact (use --variant analysis)", variant);
    }
    let state = load_state(&art, args)?;
    let exe = art.executable(&rt, "attn_probs")?;
    let data_name = args.str("data", default_data_for(manifest));
    let runs = args.usize("runs", 10)?;
    let t = cfg.seq_len;

    // per layer, collect JSD samples across runs
    let mut rng = Rng::new(args.u64("seed", 0)?);
    let mut rows = Vec::new();
    let mut jsd_ll: Vec<Vec<f64>> = vec![Vec::new(); cfg.n_layers];
    let mut jsd_lr: Vec<Vec<f64>> = vec![Vec::new(); cfg.n_layers];
    let mut jsd_rr: Vec<Vec<f64>> = vec![Vec::new(); cfg.n_layers];

    for run in 0..runs {
        // a fresh eval sequence per run
        let mut src = data::source_by_name(
            &data_name, cfg.vocab_size, cfg.seq_len, cfg.window, 1000 + run as u64,
        )?;
        let tokens = data::take(src.as_mut(), t);
        let lit = routing_transformer::runtime::i32_literal(&tokens, &[1, t])?;
        let mut inputs: Vec<&xla::Literal> = state.params.iter().collect();
        inputs.push(&lit);
        let outs = routing_transformer::runtime::execute_tuple(&exe, &inputs)?;
        let probs = routing_transformer::runtime::to_f32_vec(&outs[0])?;

        for layer in 0..cfg.n_layers {
            let plan = &cfg.plan[layer];
            let local = plan.heads_of("local");
            let routing = plan.heads_of("routing");
            if let Some(d) = analysis::sample_pair_jsd(
                &probs, cfg.n_heads, t, layer, &local, &local, &mut rng) {
                jsd_ll[layer].push(d);
            }
            if let Some(d) = analysis::sample_pair_jsd(
                &probs, cfg.n_heads, t, layer, &local, &routing, &mut rng) {
                jsd_lr[layer].push(d);
            }
            if let Some(d) = analysis::sample_pair_jsd(
                &probs, cfg.n_heads, t, layer, &routing, &routing, &mut rng) {
                jsd_rr[layer].push(d);
            }
        }
    }

    println!("Table 6 — Jensen-Shannon divergence between attention heads");
    println!("(natural log; upper bound {:.4}; {} runs)", analysis::JSD_MAX, runs);
    let mut table = Table::new(&[
        "layer", "JSD(local‖local)", "JSD(local‖routing)", "JSD(routing‖routing)",
    ]);
    for layer in 0..cfg.n_layers {
        let cell = |xs: &[f64]| -> String {
            if xs.is_empty() {
                "-".to_string()
            } else {
                let (m, s) = analysis::mean_std(xs);
                format!("{m:.4} ± {s:.4}")
            }
        };
        table.row(&[
            format!("layer {layer}"),
            cell(&jsd_ll[layer]),
            cell(&jsd_lr[layer]),
            cell(&jsd_rr[layer]),
        ]);
        rows.push(layer);
    }
    table.print();

    // spec-level counterpart: analytic JSD between uniform attention over
    // the config's local window and a balanced routing plan, straight from
    // the compiled CSR patterns (no model forward pass)
    let local = AttentionSpec::local(cfg.window.max(1))?.compile(t);
    let routing = AttentionSpec::routing_balanced(t, cfg.n_clusters.max(1))?.compile(t);
    println!(
        "\nanalytic uniform-pattern JSD: local‖routing {:.4} (bound {:.4})",
        analysis::mean_pattern_jsd(&local, &routing),
        analysis::JSD_MAX
    );
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let n = args.usize("n", 256)?.max(1);
    let d = args.usize("d", 64)?.max(1);
    let heads = args.usize("heads", 8)?.max(1);
    let layers = args.usize("layers", 4)?.max(1);
    let steps = args.usize("steps", 8)?.max(1);
    let shards = args.usize("shards", 4)?.max(1);
    let window = args.usize("window", (n / 8).max(1))?.max(1);
    let k = args.usize("clusters", optimal_clusters(n))?.max(1);
    let b = args.usize("sequences", 1)?.max(1);
    let route_every = args.usize("route-every", 2)?.max(1);
    let drift_every = args.usize("drift-every", route_every * 2)?.max(1);
    let seed = args.u64("seed", 0)?;
    let pool_cmp = args.bool("pool", false)?;
    let json_out = args.bool("json", false)?;
    let w_top = (n / k).max(1);

    // kernel backends to sweep: each run's output is compared against the
    // first (canonical) backend under the joined exactness declarations —
    // bitwise backends stay pinned bit-for-bit, fast-math backends are
    // held to their declared ulps budget
    let mut backends: Vec<std::sync::Arc<dyn Backend>> = Vec::new();
    for name in args.str("backend", "reference,blocked").split(',') {
        let name = name.trim();
        if name.is_empty() {
            continue;
        }
        match backend::lookup(name) {
            Some(be) => backends.push(be),
            None => bail!(
                "unknown attention backend '{name}' (registered: {})",
                backend::names().join(", ")
            ),
        }
    }
    if backends.is_empty() {
        bail!("--backend needs at least one registered backend name");
    }

    // Sec. 4.2 head plan: even heads are static local (pinned compiles),
    // odd heads mix local with content-routed attention whose memberships
    // come from the session's online k-means — re-fit (epoch bump) every
    // `route_every` steps as the per-sequence content drifts.
    let local = AttentionSpec::local(window)?;
    let mut session = RoutingSession::new(layers, heads, k, d, 0.5, seed)?;
    let mut cache = EpochCache::new();

    let mut rng = Rng::new(seed);
    let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    };
    // B independent requests: [B, n, d] q/k/v plus per-sequence routing
    // vectors that drift between re-fits
    let q = mk(&mut rng, b * n * d);
    let kk = mk(&mut rng, b * n * d);
    let v = mk(&mut rng, b * n * d);
    let mut xs: Vec<Vec<f32>> = (0..b).map(|_| mk(&mut rng, n * d)).collect();

    println!(
        "serve-bench: n={n} d={d} heads={heads} layers={layers} steps={steps} \
         shards={shards} window={window} clusters={k} sequences={b} route-every={route_every} \
         drift-every={drift_every} backends={} pool-compare={pool_cmp}",
        backends.iter().map(|be| be.name()).collect::<Vec<_>>().join(",")
    );

    // The static even-head batch never changes: plan it once.  Routed
    // batches are re-planned only when their slot's *assignment* epoch
    // moves (a re-fit that moved no token keeps both the compiles and
    // the plan); the per-step cache consultation (the lookup a decode
    // server performs) still happens every step so the epoch hit-rate is
    // honest.
    let static_batch = BatchedAttention::shared(cache.get_static(&local, n), b, shards)?;
    let mut routed_batches: Vec<Option<(u64, BatchedAttention)>> = vec![None; layers * heads];
    // one membership cache per routed stream (slot x sequence): spec
    // regeneration re-ranks only the clusters each re-fit touched
    let mut member_caches: Vec<MemberCache> =
        (0..layers * heads * b).map(|_| MemberCache::new()).collect();
    let pool = WorkerPool::global();

    let mut batched_rows = 0u64;
    let mut macs = 0u64;
    let mut backend_dt = vec![0f64; backends.len()];
    let mut sequential_dt = 0f64;
    let mut scoped_dt = 0f64;
    let mut moved_tokens = 0u64;
    // run-wide per-worker nnz extremes: how (im)balanced the nnz-balanced
    // row packer actually left the shards
    let mut max_shard_nnz = 0usize;
    let mut min_shard_nnz = usize::MAX;
    // per-step latency of the canonical (first) backend's batched sweeps —
    // the same histogram the `serve` loop uses, so p50/p99 come from one
    // shared implementation
    let mut step_hist = StreamingHistogram::new();
    for step in 0..steps {
        let mut step_sec = 0f64;
        if step % drift_every == 0 {
            // the per-request content moves (new tokens, shifting topics)
            for x in xs.iter_mut().flat_map(|s| s.iter_mut()) {
                *x = 0.9 * *x + 0.43 * rng.normal() as f32;
            }
        }
        if step % route_every == 0 {
            // one online k-means step per routed slot over the whole
            // batch's content; the cluster epoch bumps, but only a
            // non-empty assignment delta dirties the slot and forces
            // recompiles — and a re-fit between content drifts re-ranks
            // only the clusters its delta touched
            let all: Vec<f32> = xs.concat();
            for layer in 0..layers {
                for head in (1..heads).step_by(2) {
                    let upd = session.update(layer, head, &all, b * n);
                    moved_tokens += upd.delta.moved.len() as u64;
                }
            }
        }
        for layer in 0..layers {
            for head in 0..heads {
                let batch: &BatchedAttention = if head % 2 == 0 {
                    &static_batch
                } else {
                    let epoch = session.epoch(layer, head);
                    let ae = session.assignment_epoch(layer, head);
                    let patterns: Vec<Arc<CompiledPattern>> = (0..b)
                        .map(|s| {
                            let slot = RouteSlot { layer, head, seq: s };
                            let mc = &mut member_caches[(layer * heads + head) * b + s];
                            cache.get_routed_at(slot, epoch, ae, n, || {
                                AttentionSpec::union(vec![
                                    local.clone(),
                                    session.routing_spec_cached(layer, head, mc, &xs[s], n, w_top),
                                ])
                                .expect("two-part union is non-empty")
                            })
                        })
                        .collect();
                    let si = layer * heads + head;
                    if !matches!(&routed_batches[si], Some((e, _)) if *e == ae) {
                        routed_batches[si] = Some((ae, BatchedAttention::new(patterns, shards)?));
                    }
                    &routed_batches[si].as_ref().expect("planned above").1
                };
                for nnz in batch.worker_nnz() {
                    max_shard_nnz = max_shard_nnz.max(nnz);
                    min_shard_nnz = min_shard_nnz.min(nnz);
                }
                let mut canonical: Option<Vec<f32>> = None;
                for (bi, be) in backends.iter().enumerate() {
                    let t0 = std::time::Instant::now();
                    let out =
                        batch.attention_backend(&q, &kk, &v, d, Execution::default(), be.as_ref())?;
                    let dt = t0.elapsed().as_secs_f64();
                    backend_dt[bi] += dt;
                    if bi == 0 {
                        step_sec += dt;
                    }
                    match &canonical {
                        None => canonical = Some(out),
                        Some(first) => {
                            // both backends sit within their declared
                            // budget of Reference, so they sit within the
                            // joined budget of each other
                            let tolerance = backends[0].exactness().join(be.exactness());
                            assert_outputs_match(
                                first,
                                &out,
                                tolerance,
                                &format!(
                                    "backend '{}' vs '{}' at step {step}",
                                    be.name(),
                                    backends[0].name()
                                ),
                            )?;
                        }
                    }
                }
                let batched = canonical.expect("at least one backend ran");
                batched_rows += (b * n) as u64;
                macs += batch.cost(d);

                if pool_cmp {
                    // the path the resident pool replaces: a scoped
                    // thread spawn per worker per call, on the SAME
                    // kernel as the pool-side timing so the comparison
                    // isolates scheduling cost, not backend choice
                    let t = std::time::Instant::now();
                    let scoped = batch.attention_backend(
                        &q,
                        &kk,
                        &v,
                        d,
                        Execution::Scoped,
                        backends[0].as_ref(),
                    )?;
                    scoped_dt += t.elapsed().as_secs_f64();
                    // same backend, different execution strategy: always
                    // bitwise, whatever the backend declares vs Reference
                    assert_outputs_match(
                        &batched,
                        &scoped,
                        Exactness::Bitwise,
                        &format!("pool vs scoped-spawn at step {step}"),
                    )?;
                }

                // the path batching replaces: B independent Reference
                // kernel calls.  Only worth re-deriving when several
                // backends are being cross-checked — a single-backend
                // sweep skips this redundant per-step oracle entirely
                // (the baseline numbers are then omitted from the table
                // and the --json line, see ARCHITECTURE.md schema 4).
                if backends.len() > 1 {
                    let t1 = std::time::Instant::now();
                    let mut sequential = Vec::with_capacity(b * n * d);
                    for (s, pattern) in batch.patterns().iter().enumerate() {
                        let lo = s * n * d;
                        let hi = lo + n * d;
                        sequential.extend(sparse_attention(
                            &q[lo..hi],
                            &kk[lo..hi],
                            &v[lo..hi],
                            d,
                            pattern,
                        )?);
                    }
                    sequential_dt += t1.elapsed().as_secs_f64();
                    // the oracle is Reference itself, so the canonical
                    // backend's own declaration is the right tolerance
                    assert_outputs_match(
                        &sequential,
                        &batched,
                        backends[0].exactness(),
                        &format!("batched vs sequential at step {step}"),
                    )?;
                }
                std::hint::black_box(&batched);
            }
        }
        step_hist.record(step_sec * 1e6);
    }
    // the first requested backend is the canonical timing baseline
    let batched_dt = backend_dt[0].max(1e-9);
    let sequential_dt = sequential_dt.max(1e-9);
    let min_shard_nnz = if min_shard_nnz == usize::MAX { 0 } else { min_shard_nnz };

    let cs = cache.stats();
    let es = cache.epoch_stats();
    let dirty_pending: usize = (0..layers)
        .flat_map(|l| (0..heads).map(move |h| (l, h)))
        .map(|(l, h)| session.dirty_len(l, h))
        .sum();
    // drain the cluster-granular worklists the way a re-router would:
    // everything the member caches already consumed shows up here as
    // the clusters a version-blind consumer would still have re-ranked
    let dirty_clusters_drained: usize = (0..layers)
        .flat_map(|l| (0..heads).map(move |h| (l, h)))
        .map(|(l, h)| session.take_dirty_clusters(l, h).len())
        .sum();
    let mut regen = RegenStats::default();
    for mc in &member_caches {
        let st = mc.stats();
        regen.regenerated += st.regenerated;
        regen.reused += st.reused;
        regen.full_rebuilds += st.full_rebuilds;
        regen.calls += st.calls;
    }
    let live_before_gc = cache.len();
    // stream close: every sequence completes here, so its routed slots
    // retire through the per-request GC path (counted as evictions but
    // reported separately; static compiles deliberately survive)
    let mut retired = 0usize;
    let mut gc_bytes = 0usize;
    for layer in 0..layers {
        for head in (1..heads).step_by(2) {
            for s in 0..b {
                if let Some(bytes) = cache.evict_slot(RouteSlot { layer, head, seq: s }) {
                    retired += 1;
                    gc_bytes += bytes;
                }
            }
        }
    }
    let live_after_gc = cache.len();
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["routed lookups".to_string(), es.lookups().to_string()]);
    table.row(&["epoch hits".to_string(), es.epoch_hits.to_string()]);
    table.row(&["epoch hit rate".to_string(), format!("{:.1}%", es.hit_rate() * 100.0)]);
    table.row(&[
        "unchanged-epoch hits (recompiles skipped)".to_string(),
        es.unchanged_epochs.to_string(),
    ]);
    table.row(&["tokens moved by re-fits".to_string(), moved_tokens.to_string()]);
    table.row(&["dirty tokens pending".to_string(), dirty_pending.to_string()]);
    table.row(&[
        "dirty clusters drained".to_string(),
        dirty_clusters_drained.to_string(),
    ]);
    table.row(&["evictions (stale assignments)".to_string(), cs.evictions.to_string()]);
    table.row(&["compiles".to_string(), cs.misses.to_string()]);
    table.row(&["compile-cache hits".to_string(), cs.hits.to_string()]);
    table.row(&["compile-cache hit rate".to_string(), format!("{:.1}%", cs.hit_rate() * 100.0)]);
    table.row(&[
        "membership rows regenerated".to_string(),
        format!("{} of {}", regen.regenerated, regen.rows_total()),
    ]);
    table.row(&[
        "membership rows reused".to_string(),
        format!("{} ({:.1}%)", regen.reused, regen.reuse_rate() * 100.0),
    ]);
    table.row(&["membership full rebuilds".to_string(), regen.full_rebuilds.to_string()]);
    table.row(&["patterns cached (live)".to_string(), live_before_gc.to_string()]);
    table.row(&["slots retired (stream-close GC)".to_string(), retired.to_string()]);
    table.row(&["GC bytes reclaimed".to_string(), gc_bytes.to_string()]);
    table.row(&["pattern bytes resident".to_string(), cache.stats().bytes_resident.to_string()]);
    table.row(&["patterns cached after GC".to_string(), live_after_gc.to_string()]);
    table.row(&["batched elapsed".to_string(), format!("{:.3} s", batched_dt)]);
    table.row(&[
        "step latency p50/p99".to_string(),
        format!("{:.0} / {:.0} µs", step_hist.p50(), step_hist.p99()),
    ]);
    table.row(&[
        "batched rows/sec".to_string(),
        format!("{:.3e}", batched_rows as f64 / batched_dt),
    ]);
    for (bi, be) in backends.iter().enumerate() {
        table.row(&[
            format!("{} backend rows/sec", be.name()),
            format!("{:.3e}", batched_rows as f64 / backend_dt[bi].max(1e-9)),
        ]);
    }
    if backends.len() > 1 {
        table.row(&["sequential elapsed".to_string(), format!("{:.3} s", sequential_dt)]);
        table.row(&[
            "sequential rows/sec".to_string(),
            format!("{:.3e}", batched_rows as f64 / sequential_dt),
        ]);
        table.row(&[
            "batched speedup".to_string(),
            format!("{:.2}x", sequential_dt / batched_dt),
        ]);
    }
    table.row(&["attention MACs/sec (batched)".to_string(), format!("{:.3e}", macs as f64 / batched_dt)]);
    table.row(&[
        "max/min shard nnz (all sweeps)".to_string(),
        format!("{max_shard_nnz}/{min_shard_nnz}"),
    ]);
    if pool_cmp {
        // the batched path above ran on the resident pool (the default
        // execution); these rows compare it against per-call scoped
        // spawns over the identical batches (outputs checked row-for-row
        // every step)
        let scoped_dt = scoped_dt.max(1e-9);
        table.row(&[
            "pool rows/sec".to_string(),
            format!("{:.3e}", batched_rows as f64 / batched_dt),
        ]);
        table.row(&["scoped-spawn elapsed".to_string(), format!("{:.3} s", scoped_dt)]);
        table.row(&[
            "scoped-spawn rows/sec".to_string(),
            format!("{:.3e}", batched_rows as f64 / scoped_dt),
        ]);
        table.row(&[
            "pool vs scoped speedup".to_string(),
            format!("{:.2}x", scoped_dt / batched_dt),
        ]);
        table.row(&[
            "pool workers (spawned/config)".to_string(),
            format!("{}/{}", pool.spawned_workers(), pool.workers()),
        ]);
        table.row(&["pool jobs run".to_string(), pool.jobs_run().to_string()]);
    }
    table.print();

    // the last head of the last layer: routed when heads is even (head
    // heads-1 is odd), the shared static batch otherwise
    let last_batch: Option<&BatchedAttention> = if (heads - 1) % 2 == 0 {
        Some(&static_batch)
    } else {
        routed_batches[(layers - 1) * heads + (heads - 1)].as_ref().map(|(_, batch)| batch)
    };
    if let Some(batch) = last_batch {
        println!(
            "\nrow split of the last head's batch ({} sequences x {n} rows) across {} workers:",
            batch.batch(),
            batch.num_workers()
        );
        let mut table = Table::new(&["worker", "rows", "row share", "nnz", "nnz share"]);
        let total_rows = (batch.batch() * n).max(1);
        let worker_nnz = batch.worker_nnz();
        let total_nnz: usize = worker_nnz.iter().sum::<usize>().max(1);
        for (w, (rows, nnz)) in batch.worker_rows().iter().zip(&worker_nnz).enumerate() {
            table.row(&[
                w.to_string(),
                rows.to_string(),
                format!("{:.1}%", 100.0 * *rows as f64 / total_rows as f64),
                nnz.to_string(),
                format!("{:.1}%", 100.0 * *nnz as f64 / total_nnz as f64),
            ]);
        }
        table.print();
    }

    if json_out {
        // one greppable line per run; schema documented in ARCHITECTURE.md
        let f = |key: &str, v: f64| (key.to_string(), Json::Num(v));
        let mut fields = vec![
            ("bench".to_string(), Json::Str("serve-bench".to_string())),
            f("schema", JSON_SCHEMA_VERSION as f64),
            f("n", n as f64),
            f("d", d as f64),
            f("heads", heads as f64),
            f("layers", layers as f64),
            f("steps", steps as f64),
            f("shards", shards as f64),
            f("sequences", b as f64),
            f("route_every", route_every as f64),
            f("drift_every", drift_every as f64),
            (
                "backends".to_string(),
                Json::Arr(
                    backends
                        .iter()
                        .enumerate()
                        .map(|(bi, be)| {
                            Json::Obj(vec![
                                ("name".to_string(), Json::Str(be.name().to_string())),
                                (
                                    "exactness".to_string(),
                                    Json::Str(be.exactness().to_string()),
                                ),
                                f("elapsed_sec", backend_dt[bi]),
                                f("rows_per_sec", batched_rows as f64 / backend_dt[bi].max(1e-9)),
                            ])
                        })
                        .collect(),
                ),
            ),
            f("batched_rows", batched_rows as f64),
            f("macs_per_sec", macs as f64 / batched_dt),
            f("p50_step_us", step_hist.p50()),
            f("p99_step_us", step_hist.p99()),
            f("mean_step_us", step_hist.mean()),
            (
                "cache".to_string(),
                Json::Obj(vec![
                    f("hits", cs.hits as f64),
                    f("misses", cs.misses as f64),
                    f("evictions", cs.evictions as f64),
                    f("bytes_resident", cs.bytes_resident as f64),
                    f("bytes_evicted", cs.bytes_evicted as f64),
                ]),
            ),
            (
                "epoch".to_string(),
                Json::Obj(vec![
                    f("hits", es.epoch_hits as f64),
                    f("misses", es.epoch_misses as f64),
                    f("unchanged", es.unchanged_epochs as f64),
                    f("hit_rate", es.hit_rate()),
                ]),
            ),
            (
                "regen".to_string(),
                Json::Obj(vec![
                    f("regenerated", regen.regenerated as f64),
                    f("reused", regen.reused as f64),
                    f("full_rebuilds", regen.full_rebuilds as f64),
                    f("reuse_rate", regen.reuse_rate()),
                ]),
            ),
            f("moved_tokens", moved_tokens as f64),
            f("max_shard_nnz", max_shard_nnz as f64),
            f("min_shard_nnz", min_shard_nnz as f64),
            f("dirty_tokens_pending", dirty_pending as f64),
            f("dirty_clusters_drained", dirty_clusters_drained as f64),
            f("retired_slots", retired as f64),
            f("gc_bytes_reclaimed", gc_bytes as f64),
            f("live_patterns_after_gc", live_after_gc as f64),
        ];
        if backends.len() > 1 {
            // single-backend sweeps skip the per-step sequential oracle,
            // so the baseline only exists in multi-backend runs
            fields.push(f("sequential_rows_per_sec", batched_rows as f64 / sequential_dt));
        }
        if pool_cmp {
            fields.push((
                "pool".to_string(),
                Json::Obj(vec![
                    f("scoped_rows_per_sec", batched_rows as f64 / scoped_dt.max(1e-9)),
                    f("pool_rows_per_sec", batched_rows as f64 / batched_dt),
                    f("workers", pool.workers() as f64),
                ]),
            ));
        }
        println!("{}", Json::Obj(fields));
    }
    Ok(())
}

/// Default perf-trajectory file `--append` writes to (JSONL: one summary
/// line per run, appended, never rewritten).
const BENCH_SERVE_PATH: &str = "BENCH_serve.json";

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.usize("n", 256)?.max(1);
    let d = args.usize("d", 64)?.max(1);
    let heads = args.usize("heads", 8)?.max(1);
    let layers = args.usize("layers", 4)?.max(1);
    let window = args.usize("window", (n / 8).max(1))?.max(1);
    let k = args.usize("clusters", optimal_clusters(n))?.max(1);
    let capacity = args.usize("capacity", 8)?.max(1);
    let shards = args.usize("shards", 4)?.max(1);
    let worker_procs = args.usize("workers", 0)?;
    let route_every = args.u64("route-every", 4)?.max(1);
    let requests = args.usize("requests", 64)?;
    let rate = args.f64("rate", 1.0)?;
    let contents = args.usize("contents", 64)?.max(1);
    let zipf_s = args.f64("zipf", 1.1)?;
    let work_min = args.u64("work-min", 4)?.max(1);
    let work_max = args.u64("work-max", 16)?.max(work_min);
    let slack_min = args.u64("slack-min", 8)?;
    let slack_max = args.u64("slack-max", 64)?.max(slack_min);
    let max_pattern_bytes = args.usize("max-pattern-bytes", 0)?;
    let band_rows = args.usize("band-rows", 0)?;
    let seed = args.u64("seed", 0)?;
    let json_out = args.bool("json", false)?;
    let spec_family = SpecFamily::parse(&args.str("spec", "routing"))?;
    let backend_name = args.str("backend", "blocked");
    let be = match backend::lookup(&backend_name) {
        Some(be) => be,
        None => bail!(
            "unknown attention backend '{backend_name}' (registered: {})",
            backend::names().join(", ")
        ),
    };
    // bare `--append` (parsed as "true") means the default trajectory file
    let append_path: Option<String> = args.flags.get("append").map(|v| {
        if v == "true" {
            BENCH_SERVE_PATH.to_string()
        } else {
            v.clone()
        }
    });

    let opts = ServeOptions {
        n,
        d,
        layers,
        heads,
        window,
        clusters: k,
        top_w: (n / k).max(1),
        spec_family,
        workers: shards,
        capacity,
        route_every,
        max_pattern_bytes,
        band_rows,
        arrivals: ArrivalConfig {
            requests,
            rate,
            contents,
            zipf_s,
            work: (work_min, work_max),
            slack: (slack_min, slack_max),
            seed,
        },
        seed,
        worker_procs,
    };
    println!(
        "serve: n={n} d={d} heads={heads} layers={layers} window={window} clusters={k} \
         capacity={capacity} shards={shards} workers={worker_procs} route-every={route_every} \
         requests={requests} rate={rate} contents={contents} zipf={zipf_s} \
         work=[{work_min},{work_max}] slack=[{slack_min},{slack_max}] \
         max-pattern-bytes={max_pattern_bytes} band-rows={band_rows} spec={} backend={} \
         seed={seed}",
        spec_family.name(),
        be.name()
    );
    let summary = run_serve(&opts, be.as_ref())?;

    let s = summary.stats;
    let hist = &summary.step_us;
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["requests submitted".to_string(), s.submitted.to_string()]);
    table.row(&["admitted".to_string(), s.admitted.to_string()]);
    table.row(&["completed".to_string(), s.completed.to_string()]);
    table.row(&["rejected at submit".to_string(), s.rejected.to_string()]);
    table.row(&["shed from queue".to_string(), s.shed.to_string()]);
    table.row(&[
        "completion rate".to_string(),
        format!("{:.1}%", s.completion_rate() * 100.0),
    ]);
    table.row(&["virtual steps".to_string(), summary.virtual_steps.to_string()]);
    table.row(&[
        "decode steps (executed/idle/skipped)".to_string(),
        format!("{}/{}/{}", s.steps, s.idle_steps, s.fast_forwarded),
    ]);
    table.row(&["peak batch".to_string(), s.peak_active.to_string()]);
    table.row(&[
        "step latency p50/p99".to_string(),
        format!("{:.0} / {:.0} µs", hist.p50(), hist.p99()),
    ]);
    table.row(&[
        "step latency mean/max".to_string(),
        format!("{:.0} / {:.0} µs", hist.mean(), hist.max()),
    ]);
    table.row(&["attention elapsed".to_string(), format!("{:.3} s", summary.elapsed_sec)]);
    table.row(&["rows/sec".to_string(), format!("{:.3e}", summary.rows_per_sec())]);
    table.row(&["MACs/sec".to_string(), format!("{:.3e}", summary.macs_per_sec())]);
    let es = summary.epoch;
    table.row(&["routed epoch hit rate".to_string(), format!("{:.1}%", es.hit_rate() * 100.0)]);
    table.row(&[
        "unchanged-epoch hits (recompiles skipped)".to_string(),
        es.unchanged_epochs.to_string(),
    ]);
    let cs = summary.cache;
    table.row(&["compiles".to_string(), cs.misses.to_string()]);
    table.row(&[
        "evictions (stale + retirement GC)".to_string(),
        format!("{} ({} from GC)", cs.evictions, s.gc_evictions),
    ]);
    let rg = summary.regen;
    table.row(&[
        "membership rows regenerated/reused".to_string(),
        format!("{}/{} ({:.1}% reused)", rg.regenerated, rg.reused, rg.reuse_rate() * 100.0),
    ]);
    table.row(&[
        "patterns live after GC".to_string(),
        summary.live_patterns_after_gc.to_string(),
    ]);
    table.row(&[
        "peak pattern bytes".to_string(),
        summary.peak_pattern_bytes.to_string(),
    ]);
    table.row(&[
        "pattern bytes resident/evicted".to_string(),
        format!("{}/{}", summary.pattern_bytes_resident, summary.pattern_bytes_evicted),
    ]);
    table.row(&["band compiles".to_string(), summary.band_compiles.to_string()]);
    table.row(&[
        "GC bytes reclaimed".to_string(),
        summary.gc_bytes_reclaimed.to_string(),
    ]);
    table.row(&["spec family".to_string(), summary.spec_family.name().to_string()]);
    table.row(&["max cluster nnz".to_string(), summary.max_cluster_nnz.to_string()]);
    table.row(&[
        "max/min shard nnz".to_string(),
        format!("{}/{}", summary.max_shard_nnz, summary.min_shard_nnz),
    ]);
    table.row(&[
        "output digest".to_string(),
        format!("{:016x}", summary.output_digest),
    ]);
    table.row(&["worker subprocesses".to_string(), summary.worker_procs.to_string()]);
    if let Some(co) = summary.coord {
        table.row(&[
            "coord grants (accepted/superseded/voided)".to_string(),
            format!("{} ({}/{}/{})", co.grants, co.accepted, co.superseded, co.voided),
        ]);
        table.row(&[
            "coord rejected (stale/duplicate)".to_string(),
            format!("{}/{}", co.rejected_stale_epoch, co.rejected_duplicate),
        ]);
        table.row(&[
            "coord rows (worker/inline)".to_string(),
            format!("{}/{}", co.worker_rows, co.inline_rows),
        ]);
    }
    table.print();

    let line = serve_json_line(&opts, be.as_ref(), &summary);
    if json_out {
        println!("{line}");
    }
    if let Some(path) = append_path {
        use std::io::Write;
        let mut file =
            std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        writeln!(file, "{line}")?;
        println!("appended summary line to {path}");
    }
    Ok(())
}

/// The `serve` perf-trajectory line: the PR 5 `serve-bench` schema's
/// cache/epoch/regen sub-objects plus the request-lifecycle and step-
/// latency fields, stamped with `"schema"`; schema 4 records the
/// executing backend's name and declared exactness contract; schema 5
/// adds `worker_procs`, `output_digest` (a 16-hex-digit string — a u64
/// does not survive the f64 number type past 2^53), and the `coord`
/// ledger object for multi-process runs; schema 6 adds `spec_family`
/// (`"routing"` | `"expert-choice"` | `"threshold"`) and the
/// `max_cluster_nnz` / `max_shard_nnz` / `min_shard_nnz` load-balance
/// observables (0 in banded/coordinated modes).  Documented in
/// ARCHITECTURE.md; appended (JSONL) to `BENCH_serve.json` by `--append`.
fn serve_json_line(opts: &ServeOptions, be: &dyn Backend, summary: &ServeSummary) -> Json {
    let f = |key: &str, v: f64| (key.to_string(), Json::Num(v));
    let s = summary.stats;
    let hist = &summary.step_us;
    let cs = summary.cache;
    let es = summary.epoch;
    let rg = summary.regen;
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve".to_string())),
        f("schema", JSON_SCHEMA_VERSION as f64),
        f("n", opts.n as f64),
        f("d", opts.d as f64),
        f("heads", opts.heads as f64),
        f("layers", opts.layers as f64),
        f("window", opts.window as f64),
        f("clusters", opts.clusters as f64),
        f("capacity", opts.capacity as f64),
        f("workers", opts.workers as f64),
        f("worker_procs", summary.worker_procs as f64),
        f("route_every", opts.route_every as f64),
        f("requests", opts.arrivals.requests as f64),
        f("rate", opts.arrivals.rate),
        f("contents", opts.arrivals.contents as f64),
        f("zipf_s", opts.arrivals.zipf_s),
        (
            "work".to_string(),
            Json::Arr(vec![
                Json::Num(opts.arrivals.work.0 as f64),
                Json::Num(opts.arrivals.work.1 as f64),
            ]),
        ),
        (
            "slack".to_string(),
            Json::Arr(vec![
                Json::Num(opts.arrivals.slack.0 as f64),
                Json::Num(opts.arrivals.slack.1 as f64),
            ]),
        ),
        f("seed", opts.seed as f64),
        f("max_pattern_bytes", opts.max_pattern_bytes as f64),
        f("band_rows", opts.band_rows as f64),
        ("spec_family".to_string(), Json::Str(summary.spec_family.name().to_string())),
        f("max_cluster_nnz", summary.max_cluster_nnz as f64),
        f("max_shard_nnz", summary.max_shard_nnz as f64),
        f("min_shard_nnz", summary.min_shard_nnz as f64),
        ("backend".to_string(), Json::Str(be.name().to_string())),
        ("exactness".to_string(), Json::Str(be.exactness().to_string())),
        f("submitted", s.submitted as f64),
        f("admitted", s.admitted as f64),
        f("completed", s.completed as f64),
        f("rejected", s.rejected as f64),
        f("shed", s.shed as f64),
        f("completion_rate", s.completion_rate()),
        f("peak_active", s.peak_active as f64),
        f("virtual_steps", summary.virtual_steps as f64),
        f("steps", s.steps as f64),
        f("idle_steps", s.idle_steps as f64),
        f("fast_forwarded", s.fast_forwarded as f64),
        f("p50_step_us", hist.p50()),
        f("p99_step_us", hist.p99()),
        f("mean_step_us", hist.mean()),
        f("batched_rows", summary.batched_rows as f64),
        f("rows_per_sec", summary.rows_per_sec()),
        f("macs_per_sec", summary.macs_per_sec()),
        f("elapsed_sec", summary.elapsed_sec),
        (
            "cache".to_string(),
            Json::Obj(vec![
                f("hits", cs.hits as f64),
                f("misses", cs.misses as f64),
                f("evictions", cs.evictions as f64),
                f("bytes_resident", cs.bytes_resident as f64),
                f("bytes_evicted", cs.bytes_evicted as f64),
            ]),
        ),
        (
            "epoch".to_string(),
            Json::Obj(vec![
                f("hits", es.epoch_hits as f64),
                f("misses", es.epoch_misses as f64),
                f("unchanged", es.unchanged_epochs as f64),
                f("hit_rate", es.hit_rate()),
            ]),
        ),
        (
            "regen".to_string(),
            Json::Obj(vec![
                f("regenerated", rg.regenerated as f64),
                f("reused", rg.reused as f64),
                f("full_rebuilds", rg.full_rebuilds as f64),
                f("reuse_rate", rg.reuse_rate()),
            ]),
        ),
        f("gc_evictions", s.gc_evictions as f64),
        f("live_patterns_after_gc", summary.live_patterns_after_gc as f64),
        f("peak_pattern_bytes", summary.peak_pattern_bytes as f64),
        f("pattern_bytes_resident", summary.pattern_bytes_resident as f64),
        f("pattern_bytes_evicted", summary.pattern_bytes_evicted as f64),
        f("band_compiles", summary.band_compiles as f64),
        f("gc_bytes_reclaimed", summary.gc_bytes_reclaimed as f64),
        (
            "output_digest".to_string(),
            Json::Str(format!("{:016x}", summary.output_digest)),
        ),
    ]
    .into_iter()
    .chain(summary.coord.map(|co| {
        (
            "coord".to_string(),
            Json::Obj(vec![
                f("joins", co.joins as f64),
                f("rejoins", co.rejoins as f64),
                f("crashes", co.crashes as f64),
                f("grants", co.grants as f64),
                f("accepted", co.accepted as f64),
                f("superseded", co.superseded as f64),
                f("voided", co.voided as f64),
                f("regrants", co.regrants as f64),
                f("rejected_stale_epoch", co.rejected_stale_epoch as f64),
                f("rejected_duplicate", co.rejected_duplicate as f64),
                f("nacks", co.nacks as f64),
                f("spec_installs", co.spec_installs as f64),
                f("delta_broadcasts", co.delta_broadcasts as f64),
                f("evict_broadcasts", co.evict_broadcasts as f64),
                f("worker_rows", co.worker_rows as f64),
                f("inline_rows", co.inline_rows as f64),
            ]),
        )
    }))
    .collect())
}

/// `rtx worker`: the multi-process serve worker loop.  Spawned by the
/// coordinator with piped stdin/stdout; speaks the length-prefixed JSON
/// frame protocol documented in ARCHITECTURE.md and exits on `shutdown`
/// or EOF.
fn cmd_worker(args: &Args) -> Result<()> {
    let id = args.usize("id", 0)?;
    run_worker(id)
}

fn cmd_figure1(args: &Args) -> Result<()> {
    let n = args.usize("n", 64)?;
    let window = args.usize("window", 8)?;
    let stride = args.usize("stride", 8)?;
    let k = args.usize("clusters", 8)?.max(1);
    let seed = args.u64("seed", 0)?;
    let render_rows = args.usize("render-rows", 128)?;

    // routing spec from clustered synthetic routing vectors
    let dim = 16;
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n * dim];
    for i in 0..n {
        let c = i % k;
        for d in 0..dim {
            let base = if d == c % dim { 3.0 } else { 0.0 };
            xs[i * dim + d] = base + rng.normal() as f32 * 0.5;
        }
    }
    let mut km = SphericalKMeans::new(k, dim, 0.5, seed);
    for _ in 0..30 {
        km.update(&xs, n);
    }

    let local = AttentionSpec::local(window)?;
    let strided = AttentionSpec::strided(stride)?;
    let routing = km.routing_spec(&xs, n, n / k);
    let mixed = AttentionSpec::union(vec![local.clone(), routing.clone()])?;
    let expert = km.expert_choice_spec(&xs, n, (n / k).max(1));
    let threshold = threshold_content_spec(&xs, n);
    let schemes = [
        (format!("local attention (window {window})"), local.compile(n)),
        (format!("strided attention (stride {stride})"), strided.compile(n)),
        (format!("routing attention (k = {k} clusters, letters = clusters)"), routing.compile(n)),
        ("mixed local+routing head plan (union)".to_string(), mixed.compile(n)),
        (
            format!("expert-choice routing (k = {k} clusters, capacity {})", (n / k).max(1)),
            expert.compile(n),
        ),
        ("score-threshold attend set (cut 0, floor 1)".to_string(), threshold.compile(n)),
    ];

    println!("Figure 1 — 2-D attention schemes (rows = outputs, cols = inputs)\n");
    for (name, pattern) in &schemes {
        println!("{name}:");
        println!("{}", pattern.render_ascii_clipped(render_rows));
    }
    println!(
        "densities: local {:.3}, strided {:.3}, routing {:.3}, mixed {:.3}, \
         expert-choice {:.3}, threshold {:.3} (full = 1.0)",
        schemes[0].1.density(),
        schemes[1].1.density(),
        schemes[2].1.density(),
        schemes[3].1.density(),
        schemes[4].1.density(),
        schemes[5].1.density()
    );
    if args.bool("stats", false)? {
        println!("\npattern statistics (compiled CSR index sets, d = 64 for MACs):");
        let mut table = Table::new(&[
            "scheme", "nnz", "density", "row min", "row mean", "row max", "exact MACs",
        ]);
        for (name, pattern) in &schemes {
            let s = pattern.row_stats();
            table.row(&[
                name.split(" (").next().unwrap_or(name.as_str()).to_string(),
                pattern.nnz().to_string(),
                format!("{:.4}", pattern.density()),
                s.min.to_string(),
                format!("{:.1}", s.mean),
                s.max.to_string(),
                format!("{:.3e}", pattern.cost(64) as f64),
            ]);
        }
        table.print();
    }
    if let Some(path) = args.flags.get("csv") {
        std::fs::write(path, schemes[2].1.render_csv_clipped(render_rows))?;
        println!("routing pattern CSV written to {path}");
    }
    Ok(())
}

//! Helpers around `xla::Literal`: typed host<->literal conversion, zeros,
//! scalars, and tuple splitting for the train-state round-trip.

use anyhow::{anyhow, Result};
use xla::{ArrayShape, ElementType, Literal, PrimitiveType};

/// Create an f32 literal of the given shape from a flat host vector.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {} elements, got {}", dims, n, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// Create an i32 literal of the given shape from a flat host vector.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {} elements, got {}", dims, n, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// Zero-filled f32 literal (optimizer-state init).
pub fn zeros_f32(dims: &[usize]) -> Literal {
    Literal::create_from_shape(PrimitiveType::F32, dims)
}

/// Scalar literals for the step counter / learning rate inputs.
pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Extract a literal's dims.
pub fn dims_of(lit: &Literal) -> Result<Vec<usize>> {
    let shape: ArrayShape = lit.array_shape()?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

/// Host copy as f32 vec.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    match lit.ty()? {
        ElementType::F32 => Ok(lit.to_vec::<f32>()?),
        other => Err(anyhow!("expected f32 literal, got {:?}", other)),
    }
}

/// Host copy as i32 vec.
pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    match lit.ty()? {
        ElementType::S32 => Ok(lit.to_vec::<i32>()?),
        other => Err(anyhow!("expected s32 literal, got {:?}", other)),
    }
}

/// Scalar f32 from a rank-0 literal.
pub fn scalar_f32_value(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let lit = f32_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(dims_of(&lit).unwrap(), vec![2, 3]);
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn i32_roundtrip() {
        let lit = i32_literal(&[7, -3], &[2]).unwrap();
        assert_eq!(to_i32_vec(&lit).unwrap(), vec![7, -3]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn zeros_are_zero() {
        let z = zeros_f32(&[4, 4]);
        assert_eq!(to_f32_vec(&z).unwrap(), vec![0.0; 16]);
        assert_eq!(dims_of(&z).unwrap(), vec![4, 4]);
    }

    #[test]
    fn scalars() {
        assert_eq!(scalar_f32_value(&scalar_f32(2.5)).unwrap(), 2.5);
        let s = scalar_i32(42);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 42);
    }
}

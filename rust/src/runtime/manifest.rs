//! Manifest: the contract between `python/compile/aot.py` and this runtime.
//!
//! Each artifact directory contains a `manifest.json` describing the model
//! configuration, the parameter list in flatten order (the order the
//! lowered HLO takes its arguments in), and the artifact files.  Parsed
//! with the in-repo JSON substrate (`util::json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One parameter array: name + shape + dtype, in flatten order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-layer head allocation (mirrors python `HeadPlan`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HeadPlan {
    pub local: usize,
    pub routing: usize,
    pub full: usize,
    pub random: usize,
    pub strided: usize,
}

impl HeadPlan {
    pub fn total(&self) -> usize {
        self.local + self.routing + self.full + self.random + self.strided
    }

    /// Head-kind of head index `h` under the fixed kind ordering.
    pub fn kind_of(&self, h: usize) -> &'static str {
        let bounds = [
            ("local", self.local),
            ("routing", self.routing),
            ("full", self.full),
            ("random", self.random),
            ("strided", self.strided),
        ];
        let mut acc = 0;
        for (kind, cnt) in bounds {
            acc += cnt;
            if h < acc {
                return kind;
            }
        }
        "none"
    }

    /// Head indices of a given kind.
    pub fn heads_of(&self, kind: &str) -> Vec<usize> {
        (0..self.total()).filter(|&h| self.kind_of(h) == kind).collect()
    }
}

/// Echo of the python `ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub window: usize,
    pub n_clusters: usize,
    pub routing_window: usize,
    pub strided_stride: usize,
    pub centroid_decay: f64,
    pub plan: Vec<HeadPlan>,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Description of one lowered artifact (an HLO text file).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub scan_steps: Option<usize>,
    pub batch: Option<usize>,
    pub inputs: String,
    pub outputs: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub group: String,
    pub batch: usize,
    pub scan_steps: usize,
    pub n_params_total: usize,
    pub params: Vec<ParamSpec>,
    pub config: ModelConfig,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&root, dir)
    }

    pub fn from_json(root: &Json, dir: &Path) -> Result<Manifest> {
        let s = |j: Option<&Json>, what: &str| -> Result<String> {
            Ok(j.and_then(Json::as_str).ok_or_else(|| anyhow!("missing {what}"))?.to_string())
        };
        let u = |j: Option<&Json>, what: &str| -> Result<usize> {
            j.and_then(Json::as_usize).ok_or_else(|| anyhow!("missing {what}"))
        };

        let mut params = Vec::new();
        for p in root
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
        {
            params.push(ParamSpec {
                name: s(p.get("name"), "param name")?,
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: s(p.get("dtype"), "param dtype")?,
            });
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }

        let cj = root.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let mut plan = Vec::new();
        for pj in cj.get("plan").and_then(Json::as_arr).ok_or_else(|| anyhow!("plan"))? {
            let g = |k: &str| pj.get(k).and_then(Json::as_usize).unwrap_or(0);
            plan.push(HeadPlan {
                local: g("local"),
                routing: g("routing"),
                full: g("full"),
                random: g("random"),
                strided: g("strided"),
            });
        }
        let config = ModelConfig {
            vocab_size: u(cj.get("vocab_size"), "vocab_size")?,
            d_model: u(cj.get("d_model"), "d_model")?,
            n_layers: u(cj.get("n_layers"), "n_layers")?,
            n_heads: u(cj.get("n_heads"), "n_heads")?,
            seq_len: u(cj.get("seq_len"), "seq_len")?,
            window: u(cj.get("window"), "window")?,
            n_clusters: u(cj.get("n_clusters"), "n_clusters")?,
            routing_window: u(cj.get("routing_window"), "routing_window")?,
            strided_stride: cj.get("strided_stride").and_then(Json::as_usize).unwrap_or(1),
            centroid_decay: cj.get("centroid_decay").and_then(Json::as_f64).unwrap_or(0.999),
            plan,
        };

        let mut artifacts = BTreeMap::new();
        if let Some(fields) = root.get("artifacts").and_then(Json::fields) {
            for (name, a) in fields {
                artifacts.insert(
                    name.clone(),
                    ArtifactInfo {
                        file: s(a.get("file"), "artifact file")?,
                        scan_steps: a.get("scan_steps").and_then(Json::as_usize),
                        batch: a.get("batch").and_then(Json::as_usize),
                        inputs: a.get("inputs").and_then(Json::as_str).unwrap_or("").to_string(),
                        outputs: a.get("outputs").and_then(Json::as_str).unwrap_or("").to_string(),
                    },
                );
            }
        }

        Ok(Manifest {
            variant: s(root.get("variant"), "variant")?,
            group: root.get("group").and_then(Json::as_str).unwrap_or("core").to_string(),
            batch: u(root.get("batch"), "batch")?,
            scan_steps: u(root.get("scan_steps"), "scan_steps")?,
            n_params_total: u(root.get("n_params"), "n_params")?,
            params,
            config,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Number of parameter arrays (P): the lowered train artifacts take
    /// 3P + 3 inputs (params, m, v, step, lr, tokens).
    pub fn n_param_arrays(&self) -> usize {
        self.params.len()
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("variant {} has no artifact '{name}'", self.variant))?;
        Ok(self.dir.join(&info.file))
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Layers that have routing heads, with their centroid param index.
    pub fn routing_layers(&self) -> Vec<(usize, usize)> {
        (0..self.config.n_layers)
            .filter_map(|l| {
                let name = format!("layer{l:02}.attn.centroids");
                self.param_index(&name).map(|i| (l, i))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
          "variant": "t", "group": "core", "batch": 4, "scan_steps": 2,
          "n_params": 100,
          "config": {"vocab_size": 256, "d_model": 64, "n_layers": 2,
                     "n_heads": 4, "seq_len": 128, "window": 32,
                     "n_clusters": 4, "routing_window": 32,
                     "strided_stride": 16, "centroid_decay": 0.999,
                     "plan": [{"local": 4}, {"local": 2, "routing": 2}]},
          "params": [{"name": "layer01.attn.centroids", "shape": [2,4,16], "dtype": "f32"},
                     {"name": "tok_emb", "shape": [256,64], "dtype": "f32"}],
          "artifacts": {"train_block": {"file": "train_block.hlo.txt",
                                        "scan_steps": 2,
                                        "inputs": "x", "outputs": "y"}}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&sample_json(), Path::new("/tmp/x")).unwrap();
        assert_eq!(m.variant, "t");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.config.plan[1].routing, 2);
        assert_eq!(m.routing_layers(), vec![(1, 0)]);
        assert_eq!(m.artifact_path("train_block").unwrap(),
                   Path::new("/tmp/x/train_block.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn head_plan_kinds() {
        let p = HeadPlan { local: 2, routing: 1, full: 0, random: 1, strided: 0 };
        assert_eq!(p.kind_of(0), "local");
        assert_eq!(p.kind_of(1), "local");
        assert_eq!(p.kind_of(2), "routing");
        assert_eq!(p.kind_of(3), "random");
        assert_eq!(p.heads_of("routing"), vec![2]);
        assert_eq!(p.total(), 4);
    }
}

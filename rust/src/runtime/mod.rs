//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the Rust hot path.  Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format because xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id serialized protos.

pub mod literal_util;
pub mod manifest;
pub mod state;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

pub use literal_util::*;
pub use manifest::{ArtifactInfo, HeadPlan, Manifest, ModelConfig, ParamSpec};
pub use state::{load_params_npz, ModelState};

/// A PJRT client plus a compile cache keyed by HLO file path: each artifact
/// is compiled exactly once per process, then reused by trainers, eval
/// loops, samplers and benches.
pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<BTreeMap<PathBuf, std::sync::Arc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text file (cached).
    pub fn compile(&self, path: &Path) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }
}

/// One variant's artifact directory + manifest.
pub struct Artifacts {
    pub manifest: Manifest,
}

impl Artifacts {
    /// Load `<root>/<variant>/manifest.json`.
    pub fn load(root: &Path, variant: &str) -> Result<Artifacts> {
        let dir = root.join(variant);
        if !dir.join("manifest.json").exists() {
            return Err(anyhow!(
                "no artifacts for variant '{variant}' under {} — run `make artifacts`",
                root.display()
            ));
        }
        Ok(Artifacts { manifest: Manifest::load(&dir)? })
    }

    /// All variants available under an artifact root.
    pub fn list(root: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(root).with_context(|| format!("{}", root.display()))? {
            let entry = entry?;
            if entry.path().join("manifest.json").exists() {
                names.push(entry.file_name().to_string_lossy().to_string());
            }
        }
        names.sort();
        Ok(names)
    }

    /// Compile one of this variant's artifacts.
    pub fn executable(
        &self,
        rt: &Runtime,
        name: &str,
    ) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        rt.compile(&self.manifest.artifact_path(name)?)
    }

    /// Seeded initial state.
    pub fn init_state(&self) -> Result<ModelState> {
        ModelState::init(&self.manifest)
    }
}

/// Execute an executable whose result is a tuple, returning the tuple
/// elements as host literals.  (PJRT under this crate returns one
/// tuple-shaped buffer; we untuple on the host.)
pub fn execute_tuple(exe: &PjRtLoadedExecutable, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let outs = exe.execute::<&Literal>(inputs).context("executing artifact")?;
    let lit = outs
        .first()
        .and_then(|replica| replica.first())
        .ok_or_else(|| anyhow!("execution produced no outputs"))?
        .to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

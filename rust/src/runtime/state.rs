//! Model training state: parameters + Adam moments + step counter.
//!
//! Parameters live as host literals between executions (the published
//! `xla` crate returns multi-result executions as one tuple buffer, so
//! on-device chaining is impossible; the scanned train-block artifact
//! amortizes the host round-trip — see DESIGN.md).  Checkpoints are npz
//! (numpy-compatible) plus a JSON sidecar with the step counter, readable
//! by both numpy and this runtime.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal};

use super::literal_util::{dims_of, zeros_f32};
use super::manifest::Manifest;
use crate::util::json::Json;

/// Full optimizer state for one model variant.
pub struct ModelState {
    pub params: Vec<Literal>,
    pub m: Vec<Literal>,
    pub v: Vec<Literal>,
    pub step: i64,
}

impl ModelState {
    /// Load the seeded initial parameters written by `aot.py` and zero
    /// Adam moments.
    pub fn init(manifest: &Manifest) -> Result<ModelState> {
        let npz = manifest.dir.join("init_params.npz");
        let params = load_params_npz(manifest, &npz)?;
        let (m, v) = zero_moments(manifest);
        Ok(ModelState { params, m, v, step: 0 })
    }

    /// Fresh zero moments matching the manifest's parameter shapes.
    pub fn with_params(manifest: &Manifest, params: Vec<Literal>) -> Result<ModelState> {
        validate_params(manifest, &params)?;
        let (m, v) = zero_moments(manifest);
        Ok(ModelState { params, m, v, step: 0 })
    }

    /// Save a checkpoint: `<path>.npz` (params + moments) and
    /// `<path>.json` (step counter, variant echo).
    pub fn save(&self, manifest: &Manifest, path: &Path) -> Result<()> {
        let mut entries: Vec<(String, &Literal)> = Vec::new();
        for (spec, lit) in manifest.params.iter().zip(&self.params) {
            entries.push((format!("p/{}", spec.name), lit));
        }
        for (spec, lit) in manifest.params.iter().zip(&self.m) {
            entries.push((format!("m/{}", spec.name), lit));
        }
        for (spec, lit) in manifest.params.iter().zip(&self.v) {
            entries.push((format!("v/{}", spec.name), lit));
        }
        // NOTE: the crate's Literal::write_npz is broken for f32 (type
        // check in its u8 copy path) — use the in-repo npz substrate.
        crate::util::npz::write_npz(&entries, path.with_extension("npz"))?;
        let side = Json::Obj(vec![
            ("variant".into(), Json::Str(manifest.variant.clone())),
            ("step".into(), Json::Num(self.step as f64)),
        ]);
        std::fs::write(path.with_extension("json"), side.to_string())?;
        Ok(())
    }

    /// Load a checkpoint written by `save`.
    pub fn load(manifest: &Manifest, path: &Path) -> Result<ModelState> {
        let npz = path.with_extension("npz");
        let all = Literal::read_npz(&npz, &())
            .with_context(|| format!("reading {}", npz.display()))?;
        let mut by_name: std::collections::HashMap<String, Literal> =
            all.into_iter().collect();
        let mut take = |prefix: &str| -> Result<Vec<Literal>> {
            manifest
                .params
                .iter()
                .map(|spec| {
                    by_name
                        .remove(&format!("{prefix}/{}", spec.name))
                        .ok_or_else(|| anyhow!("checkpoint missing {prefix}/{}", spec.name))
                })
                .collect()
        };
        let params = take("p")?;
        let m = take("m")?;
        let v = take("v")?;
        validate_params(manifest, &params)?;

        let side_path = path.with_extension("json");
        let step = match std::fs::read_to_string(&side_path) {
            Ok(text) => Json::parse(&text)?
                .get("step")
                .and_then(Json::as_i64)
                .unwrap_or(0),
            Err(_) => 0,
        };
        Ok(ModelState { params, m, v, step })
    }

    /// Parameters only (for eval / sampling executables).
    pub fn param_refs(&self) -> Vec<&Literal> {
        self.params.iter().collect()
    }

    /// Total number of scalar parameters.
    pub fn numel(&self) -> usize {
        self.params
            .iter()
            .map(|l| l.element_count())
            .sum()
    }
}

fn zero_moments(manifest: &Manifest) -> (Vec<Literal>, Vec<Literal>) {
    let zeros = |m: &Manifest| -> Vec<Literal> {
        m.params.iter().map(|spec| zeros_f32(&spec.shape)).collect()
    };
    (zeros(manifest), zeros(manifest))
}

fn validate_params(manifest: &Manifest, params: &[Literal]) -> Result<()> {
    if params.len() != manifest.params.len() {
        bail!("expected {} param arrays, got {}", manifest.params.len(), params.len());
    }
    for (spec, lit) in manifest.params.iter().zip(params) {
        let dims = dims_of(lit)?;
        if dims != spec.shape {
            bail!("param {}: manifest shape {:?} != literal shape {:?}",
                  spec.name, spec.shape, dims);
        }
    }
    Ok(())
}

/// Read `init_params.npz` (or any flat npz of `name -> array`) in manifest
/// order.
pub fn load_params_npz(manifest: &Manifest, path: &Path) -> Result<Vec<Literal>> {
    let all = Literal::read_npz(path, &())
        .with_context(|| format!("reading {}", path.display()))?;
    let mut by_name: std::collections::HashMap<String, Literal> = all.into_iter().collect();
    let params: Vec<Literal> = manifest
        .params
        .iter()
        .map(|spec| {
            by_name
                .remove(&spec.name)
                .ok_or_else(|| anyhow!("npz missing param {}", spec.name))
        })
        .collect::<Result<_>>()?;
    validate_params(manifest, &params)?;
    Ok(params)
}

//! Autoregressive sampling: temperature + nucleus (top-p) — the decoding
//! setup of Appendix A (nucleus sampling with p = 0.8, temperature 1.0).
//!
//! The model forward runs through the AOT `logits` artifact; this module
//! owns the host-side categorical sampling and the generation loop
//! plumbing (prompt, max tokens, stop condition).  The host-side math
//! ([`nucleus_probs`], [`sample_logits`]) builds without the `xla`
//! feature; only the artifact-driven `Generator` (xla-gated) needs the
//! runtime.

#[cfg(feature = "xla")]
use anyhow::{anyhow, Result};
#[cfg(feature = "xla")]
use xla::PjRtLoadedExecutable;

#[cfg(feature = "xla")]
use crate::runtime::{execute_tuple, i32_literal, to_f32_vec, ModelState};
use crate::util::rng::Rng;

/// Sampler hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    pub temperature: f32,
    /// Nucleus mass; 1.0 disables the top-p filter.
    pub top_p: f32,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        // Appendix A: nucleus sampling with p=0.8, temperature 1.0
        SamplerConfig { temperature: 1.0, top_p: 0.8 }
    }
}

/// Sample one token id from raw logits.
pub fn sample_logits(logits: &[f32], cfg: SamplerConfig, rng: &mut Rng) -> usize {
    let probs = nucleus_probs(logits, cfg);
    rng.weighted(&probs)
}

/// Temperature + top-p filtered probability vector (f64 for the sampler),
/// normalized to sum to 1 over the kept support.
///
/// Non-finite logits (`-inf` masks, `NaN`, stray `+inf`) carry zero
/// probability.  A fully-masked row — every logit non-finite — used to
/// poison the whole vector: `max` became `-inf`, every `exp` returned
/// `NaN`, and `Rng::weighted` silently picked the last index.  That row
/// now degrades to a uniform distribution over all indices (there is no
/// finite evidence to prefer any token), and the top-p cut renormalizes
/// explicitly so the sampler always sees a proper distribution.
pub fn nucleus_probs(logits: &[f32], cfg: SamplerConfig) -> Vec<f64> {
    let t = cfg.temperature.max(1e-4) as f64;
    let max = logits
        .iter()
        .cloned()
        .filter(|l| l.is_finite())
        .fold(f32::NEG_INFINITY, f32::max) as f64;
    if !max.is_finite() {
        // fully-masked row: no finite logit survives; fall back to uniform
        return vec![1.0 / logits.len().max(1) as f64; logits.len()];
    }
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&l| if l.is_finite() { ((l as f64 - max) / t).exp() } else { 0.0 })
        .collect();
    // z >= exp(0) = 1: at least one logit equals max, so no 0/0 here
    let z: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= z;
    }
    if cfg.top_p < 1.0 {
        // keep the smallest prefix of sorted probs with mass >= top_p;
        // total_cmp keeps the descending sort total even if a prob were
        // NaN (the masking above makes probs finite today, but the old
        // partial_cmp(..).unwrap() aborted sampling the moment that
        // invariant slipped — same panic class as the routing top-w sort)
        let mut order: Vec<usize> = (0..probs.len()).collect();
        order.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]));
        let mut mass = 0.0;
        let mut keep = vec![false; probs.len()];
        for &i in &order {
            keep[i] = true;
            mass += probs[i];
            if mass >= cfg.top_p as f64 {
                break;
            }
        }
        for (i, p) in probs.iter_mut().enumerate() {
            if !keep[i] {
                *p = 0.0;
            }
        }
        // renormalize over the kept support instead of leaving the cut
        // mass for the sampler to absorb
        let kept: f64 = probs.iter().sum();
        if kept > 0.0 {
            for p in &mut probs {
                *p /= kept;
            }
        }
    }
    probs
}

/// Autoregressive generator over a fixed-context `logits` artifact.
///
/// The artifact's signature is `(params, tokens i32[1, T]) -> logits
/// f32[1, T, V]`.  The context is a sliding window of the last T tokens;
/// generation re-runs the forward per token (O(T²) per token — the
/// honest cost of sampling without a KV-cache artifact; see DESIGN.md
/// §Perf for the planned incremental-decode artifact).
#[cfg(feature = "xla")]
pub struct Generator<'a> {
    exe: &'a PjRtLoadedExecutable,
    state: &'a ModelState,
    pub seq_len: usize,
    pub vocab: usize,
    pub cfg: SamplerConfig,
    rng: Rng,
}

#[cfg(feature = "xla")]
impl<'a> Generator<'a> {
    pub fn new(
        exe: &'a PjRtLoadedExecutable,
        state: &'a ModelState,
        seq_len: usize,
        vocab: usize,
        cfg: SamplerConfig,
        seed: u64,
    ) -> Self {
        Generator { exe, state, seq_len, vocab, cfg, rng: Rng::new(seed) }
    }

    /// Logits for the next token after `context` (last position that holds
    /// a real token).  Context is right-padded with zeros to T.
    pub fn next_logits(&self, context: &[i32]) -> Result<Vec<f32>> {
        if context.is_empty() || context.len() > self.seq_len {
            return Err(anyhow!("context length {} not in [1, {}]", context.len(), self.seq_len));
        }
        let mut padded = vec![0i32; self.seq_len];
        padded[..context.len()].copy_from_slice(context);
        let tokens = i32_literal(&padded, &[1, self.seq_len])?;
        let mut inputs: Vec<&xla::Literal> = self.state.params.iter().collect();
        inputs.push(&tokens);
        let outs = execute_tuple(self.exe, &inputs)?;
        let logits = to_f32_vec(&outs[0])?;
        let pos = context.len() - 1;
        Ok(logits[pos * self.vocab..(pos + 1) * self.vocab].to_vec())
    }

    /// Generate `n` tokens continuing `prompt`.
    pub fn generate(&mut self, prompt: &[i32], n: usize) -> Result<Vec<i32>> {
        let mut out: Vec<i32> = prompt.to_vec();
        if out.is_empty() {
            out.push(0);
        }
        for _ in 0..n {
            let start = out.len().saturating_sub(self.seq_len);
            let logits = self.next_logits(&out[start..])?;
            let tok = sample_logits(&logits, self.cfg, &mut self.rng);
            out.push(tok as i32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nucleus_keeps_top_mass() {
        // peaked distribution: top-p=0.5 keeps only the argmax
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let probs = nucleus_probs(&logits, SamplerConfig { temperature: 1.0, top_p: 0.5 });
        assert!(probs[0] > 0.99);
        assert!(probs[1] == 0.0 && probs[2] == 0.0 && probs[3] == 0.0);
    }

    #[test]
    fn fully_masked_row_is_uniform_not_nan() {
        // all -inf (and NaN) used to make max = -inf and every prob NaN,
        // so weighted() silently returned the last index
        let logits = vec![f32::NEG_INFINITY; 4];
        let probs = nucleus_probs(&logits, SamplerConfig::default());
        assert!(probs.iter().all(|p| p.is_finite()));
        assert!(probs.iter().all(|&p| (p - 0.25).abs() < 1e-12));
        let mut rng = Rng::new(5);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample_logits(&logits, SamplerConfig::default(), &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "degenerate row must sample uniformly");
        // partially-masked rows (NaN + -inf alongside one finite logit)
        // drive the top-p sort over zero-probability entries; the sort
        // must stay total and the finite logit must keep the whole mass
        let mixed = vec![f32::NAN, f32::NEG_INFINITY, 1.0, f32::NAN];
        let probs = nucleus_probs(&mixed, SamplerConfig { temperature: 1.0, top_p: 0.5 });
        assert!(probs.iter().all(|p| p.is_finite()));
        assert!((probs[2] - 1.0).abs() < 1e-12, "finite logit keeps all mass");
        assert!(probs[0] == 0.0 && probs[1] == 0.0 && probs[3] == 0.0);
        for _ in 0..50 {
            assert_eq!(sample_logits(&mixed, SamplerConfig::default(), &mut rng), 2);
        }
    }

    #[test]
    fn non_finite_logits_are_masked_out() {
        let logits = vec![1.0, f32::NAN, f32::NEG_INFINITY, 0.0];
        let probs = nucleus_probs(&logits, SamplerConfig { temperature: 1.0, top_p: 1.0 });
        assert_eq!(probs[1], 0.0);
        assert_eq!(probs[2], 0.0);
        assert!(probs[0] > probs[3] && probs[3] > 0.0);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn top_p_cut_renormalizes() {
        let logits = vec![2.0, 1.0, 0.0, -1.0];
        let probs = nucleus_probs(&logits, SamplerConfig { temperature: 1.0, top_p: 0.6 });
        let mass: f64 = probs.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "kept mass must renormalize to 1 (got {mass})");
        assert!(probs.iter().filter(|&&p| p > 0.0).count() < 4, "cut must drop the tail");
    }

    #[test]
    fn top_p_one_keeps_everything() {
        let logits = vec![1.0, 0.5, 0.0];
        let probs = nucleus_probs(&logits, SamplerConfig { temperature: 1.0, top_p: 1.0 });
        assert!(probs.iter().all(|&p| p > 0.0));
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn low_temperature_sharpens() {
        let logits = vec![1.0, 0.9];
        let hot = nucleus_probs(&logits, SamplerConfig { temperature: 2.0, top_p: 1.0 });
        let cold = nucleus_probs(&logits, SamplerConfig { temperature: 0.1, top_p: 1.0 });
        assert!(cold[0] > hot[0]);
    }

    #[test]
    fn sampling_respects_support() {
        let mut rng = Rng::new(3);
        let logits = vec![5.0, f32::NEG_INFINITY + 1e30, 5.0, -100.0];
        let cfg = SamplerConfig { temperature: 1.0, top_p: 0.95 };
        for _ in 0..100 {
            let t = sample_logits(&logits, cfg, &mut rng);
            assert!(t == 0 || t == 2, "sampled {t}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerConfig::default();
        let a: Vec<usize> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| sample_logits(&logits, cfg, &mut rng)).collect()
        };
        let b: Vec<usize> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| sample_logits(&logits, cfg, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}

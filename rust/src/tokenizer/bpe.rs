//! Greedy byte-pair encoding (PG-19 subword setting).
//!
//! Trained on corpus bytes: iteratively merge the most frequent adjacent
//! token pair until the target vocabulary size is reached (ties broken by
//! pair id for determinism).  Encoding applies merges in training order —
//! the standard BPE inference rule.  Stands in for PG-19's ~98k
//! sentencepiece vocabulary at reproduction scale.

use std::collections::HashMap;

use super::Tokenizer;

#[derive(Debug, Clone)]
pub struct Bpe {
    /// Merge rules in training order: (left, right) -> new id.
    merges: Vec<(i32, i32)>,
    /// id -> byte string.
    vocab: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train on raw bytes up to `vocab_size` tokens (>= 256).
    pub fn train(corpus: &[u8], vocab_size: usize) -> Bpe {
        assert!(vocab_size >= 256);
        let mut vocab: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        let mut merges: Vec<(i32, i32)> = Vec::new();
        let mut seq: Vec<i32> = corpus.iter().map(|&b| b as i32).collect();

        while vocab.len() < vocab_size {
            // count adjacent pairs
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
            else {
                break;
            };
            if cnt < 2 {
                break; // nothing worth merging
            }
            let new_id = vocab.len() as i32;
            let mut merged = vocab[pair.0 as usize].clone();
            merged.extend_from_slice(&vocab[pair.1 as usize]);
            vocab.push(merged);
            merges.push(pair);
            seq = merge_once(&seq, pair, new_id);
        }
        Bpe { merges, vocab }
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    pub fn encode_bytes(&self, bytes: &[u8]) -> Vec<i32> {
        let mut seq: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
        for (rule_idx, &pair) in self.merges.iter().enumerate() {
            let new_id = 256 + rule_idx as i32;
            if seq.len() < 2 {
                break;
            }
            seq = merge_once(&seq, pair, new_id);
        }
        seq
    }

    pub fn decode_bytes(&self, tokens: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            if let Some(bytes) = self.vocab.get(t as usize) {
                out.extend_from_slice(bytes);
            }
        }
        out
    }

    /// Mean bytes per token on a sample (compression ratio; > 1 once
    /// merges exist).
    pub fn bytes_per_token(&self, sample: &[u8]) -> f64 {
        let toks = self.encode_bytes(sample);
        if toks.is_empty() {
            return 0.0;
        }
        sample.len() as f64 / toks.len() as f64
    }
}

fn merge_once(seq: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(seq.len());
    let mut i = 0;
    while i < seq.len() {
        if i + 1 < seq.len() && seq[i] == pair.0 && seq[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(seq[i]);
            i += 1;
        }
    }
    out
}

impl Tokenizer for Bpe {
    fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        self.encode_bytes(text.as_bytes())
    }

    fn decode(&self, tokens: &[i32]) -> String {
        String::from_utf8_lossy(&self.decode_bytes(tokens)).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let corpus = b"abab ababab abab cdcd cdcdcd".repeat(10);
        let bpe = Bpe::train(&corpus, 270);
        let text = "abab cdcd abab";
        assert_eq!(bpe.decode(&bpe.encode(text)), text);
    }

    #[test]
    fn merges_compress() {
        let corpus = b"the quick the quick the quick brown fox ".repeat(20);
        let bpe = Bpe::train(&corpus, 300);
        assert!(bpe.n_merges() > 0);
        assert!(bpe.bytes_per_token(&corpus) > 1.5, "bpt {}", bpe.bytes_per_token(&corpus));
    }

    #[test]
    fn vocab_capped() {
        let corpus = b"aaaabbbbccccdddd".repeat(50);
        let bpe = Bpe::train(&corpus, 260);
        assert!(bpe.vocab_size() <= 260);
    }

    #[test]
    fn deterministic_training() {
        let corpus = b"hello world hello world hello".repeat(8);
        let a = Bpe::train(&corpus, 280);
        let b = Bpe::train(&corpus, 280);
        assert_eq!(a.encode("hello world"), b.encode("hello world"));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let corpus = b"xyzxyzxyz".repeat(30);
        let bpe = Bpe::train(&corpus, 280);
        let toks = bpe.encode("xyzxyz");
        assert!(toks.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    }
}

//! Byte-level tokenizer (enwik-8 setting): token id == byte value.

use super::Tokenizer;

#[derive(Debug, Default, Clone)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "The Council of Basle, 1487.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "naïve — ✓";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.encode(s).len(), s.len()); // bytes, not chars
    }

    #[test]
    fn vocab_is_256() {
        assert_eq!(ByteTokenizer.vocab_size(), 256);
    }
}

//! Tokenization substrates.
//!
//! The paper's benchmarks span three granularities — bytes (enwik-8),
//! words (Wikitext-103) and subwords (PG-19's ~98k sentencepiece vocab).
//! This module provides all three: a byte tokenizer, a frequency-capped
//! word vocabulary, and a greedy-merge BPE trained on corpus bytes.

pub mod bpe;
pub mod byte;
pub mod words;

pub use bpe::Bpe;
pub use byte::ByteTokenizer;
pub use words::WordVocab;

/// Common tokenizer interface.
pub trait Tokenizer {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    fn decode(&self, tokens: &[i32]) -> String;
}

//! Word-level vocabulary (Wikitext-103 setting): whitespace/punctuation
//! word split, frequency-ranked vocab with `<unk>`, exact round-trip for
//! in-vocabulary text via space joining.

use std::collections::HashMap;

use super::Tokenizer;

pub const UNK: i32 = 0;

#[derive(Debug, Clone)]
pub struct WordVocab {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl WordVocab {
    /// Build from a corpus: the `max_vocab - 1` most frequent words (id 0
    /// is `<unk>`), ties broken lexicographically for determinism.
    pub fn build(corpus: &str, max_vocab: usize) -> WordVocab {
        let mut freq: HashMap<&str, usize> = HashMap::new();
        for w in corpus.split_whitespace() {
            *freq.entry(w).or_insert(0) += 1;
        }
        let mut by_freq: Vec<(&str, usize)> = freq.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let mut id_to_word = vec!["<unk>".to_string()];
        for (w, _) in by_freq.into_iter().take(max_vocab.saturating_sub(1)) {
            id_to_word.push(w.to_string());
        }
        let word_to_id =
            id_to_word.iter().enumerate().map(|(i, w)| (w.clone(), i as i32)).collect();
        WordVocab { word_to_id, id_to_word }
    }

    pub fn id(&self, word: &str) -> i32 {
        self.word_to_id.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.id_to_word.get(id as usize).map(String::as_str).unwrap_or("<unk>")
    }

    /// Fraction of corpus tokens covered (non-unk).
    pub fn coverage(&self, corpus: &str) -> f64 {
        let mut total = 0usize;
        let mut known = 0usize;
        for w in corpus.split_whitespace() {
            total += 1;
            if self.word_to_id.contains_key(w) {
                known += 1;
            }
        }
        if total == 0 {
            1.0
        } else {
            known as f64 / total as f64
        }
    }
}

impl Tokenizer for WordVocab {
    fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    fn decode(&self, tokens: &[i32]) -> String {
        tokens.iter().map(|&t| self.word(t)).collect::<Vec<_>>().join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequent_words_in_vocab() {
        let corpus = "the cat sat on the mat the cat";
        let v = WordVocab::build(corpus, 100);
        assert_ne!(v.id("the"), UNK);
        assert_ne!(v.id("cat"), UNK);
        assert_eq!(v.id("zebra"), UNK);
    }

    #[test]
    fn capped_vocab_keeps_most_frequent() {
        let corpus = "a a a a b b b c c d";
        let v = WordVocab::build(corpus, 3); // <unk> + 2 words
        assert_eq!(v.vocab_size(), 3);
        assert_ne!(v.id("a"), UNK);
        assert_ne!(v.id("b"), UNK);
        assert_eq!(v.id("c"), UNK);
    }

    #[test]
    fn roundtrip_known_text() {
        let corpus = "alpha beta gamma alpha beta";
        let v = WordVocab::build(corpus, 100);
        let text = "alpha gamma beta";
        assert_eq!(v.decode(&v.encode(text)), text);
    }

    #[test]
    fn coverage_metric() {
        let corpus = "x x y";
        let v = WordVocab::build(corpus, 2); // only <unk> + "x"
        assert!((v.coverage(corpus) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_ids() {
        let corpus = "b a b a c";
        let v1 = WordVocab::build(corpus, 10);
        let v2 = WordVocab::build(corpus, 10);
        assert_eq!(v1.id("a"), v2.id("a"));
        assert_eq!(v1.id("c"), v2.id("c"));
    }
}

//! Minimal CLI argument parser (no clap in the offline environment).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults and error messages.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn str_req(&self, key: &str) -> Result<String> {
        self.flags.get(key).cloned().ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a float, got '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a float, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow!("--{key} expects a bool, got '{v}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_kinds() {
        let a = parse("train --steps 100 --lr=0.001 --verbose --out x.npz");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert_eq!(a.f32("lr", 0.0).unwrap(), 0.001);
        assert!(a.bool("verbose", false).unwrap());
        assert_eq!(a.str("out", ""), "x.npz");
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("cmd --n abc");
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!(a.usize("n", 0).is_err());
        assert!(a.str_req("nope").is_err());
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("cmd --force");
        assert!(a.bool("force", false).unwrap());
    }
}

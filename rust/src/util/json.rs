//! Minimal JSON parser/serializer.
//!
//! The offline build environment ships no `serde_json`, so the manifest
//! contract between `python/compile/aot.py` and the runtime is parsed with
//! this hand-rolled, dependency-free implementation.  It supports the full
//! JSON grammar (objects, arrays, strings with escapes incl. `\uXXXX`,
//! numbers, booleans, null); object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value — only if the number is integral and in i64 range.
    /// The old lossy `as` casts truncated `2.7` to `2` and saturated
    /// out-of-range values, silently accepting bad config/spec fields;
    /// non-integral, non-finite, or out-of-range numbers are now `None`.
    pub fn as_i64(&self) -> Option<i64> {
        let f = self.as_f64()?;
        // upper bound is exclusive: 2^63 rounds out of i64 range
        if !f.is_finite() || f.fract() != 0.0 || f < -(2f64.powi(63)) || f >= 2f64.powi(63) {
            return None;
        }
        Some(f as i64)
    }

    /// Non-negative integer value — integral and in range, like
    /// [`Json::as_i64`] (so `-1.0` is `None`, not a saturated `0`).
    pub fn as_usize(&self) -> Option<usize> {
        let f = self.as_f64()?;
        if !f.is_finite() || f.fract() != 0.0 || f < 0.0 || f >= usize::MAX as f64 {
            return None;
        }
        Some(f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// All object fields, in document order.
    pub fn fields(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(f) => Some(f),
            _ => None,
        }
    }

    /// Convenience: object as a map (loses duplicate keys).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(f) => f.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact JSON serialization (`value.to_string()` via the blanket
/// `ToString`; an inherent `to_string` would shadow this impl).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c);
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn roundtrips() {
        let text = r#"{"name":"x","shape":[2,4,16],"n":140672,"f":1.5,"b":true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_accessors_reject_lossy_values() {
        assert_eq!(Json::Num(2.0).as_usize(), Some(2));
        assert_eq!(Json::Num(2.0).as_i64(), Some(2));
        assert_eq!(Json::Num(-3.0).as_i64(), Some(-3));
        // fractional values must not truncate
        assert_eq!(Json::Num(2.7).as_usize(), None);
        assert_eq!(Json::Num(2.7).as_i64(), None);
        assert_eq!(Json::Num(-0.5).as_i64(), None);
        // negatives must not saturate to 0
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        // out-of-range and non-finite must not saturate
        assert_eq!(Json::Num(1e30).as_usize(), None);
        assert_eq!(Json::Num(1e30).as_i64(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_i64(), None);
        // integral in-range values parsed from text still work
        assert_eq!(Json::parse("140672").unwrap().as_usize(), Some(140672));
        assert_eq!(Json::parse("1e3").unwrap().as_usize(), Some(1000));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"params":[{"name":"tok_emb","shape":[256,64],"dtype":"f32"}]}"#;
        let v = Json::parse(text).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("tok_emb"));
        let shape: Vec<usize> =
            p.get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![256, 64]);
    }
}

//! Dependency-free substrates: JSON, PRNG, CLI parsing, bench timing.
//! The npz writer serializes `xla::Literal`s, so it rides the `xla`
//! feature.

pub mod cli;
pub mod json;
#[cfg(feature = "xla")]
pub mod npz;
pub mod rng;
pub mod timing;

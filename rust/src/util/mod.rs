//! Dependency-free substrates: JSON, PRNG, CLI parsing, bench timing.

pub mod cli;
pub mod json;
pub mod npz;
pub mod rng;
pub mod timing;

//! Minimal numpy-compatible `.npz` writer.
//!
//! The published `xla` crate's `Literal::write_npz` is unusable for f32
//! tensors (its `write()` copies through a `Vec<u8>` and trips its own
//! element-type check), so checkpoints are written with this hand-rolled
//! implementation: a STORED (uncompressed) ZIP of npy-v1.0 members, the
//! exact layout `numpy.savez` produces.  Readable by `numpy.load` and by
//! the crate's (working) `read_npz`.

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// CRC-32 (IEEE 802.3), table-driven.
fn crc32(data: &[u8]) -> u32 {
    static mut TABLE: [u32; 256] = [0; 256];
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            unsafe { TABLE[i as usize] = c };
        }
    });
    let table = unsafe { &*std::ptr::addr_of!(TABLE) };
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Serialize one literal as npy v1.0 bytes.
fn npy_bytes(lit: &Literal) -> Result<Vec<u8>> {
    let shape = lit.array_shape()?;
    let descr = match shape.ty() {
        ElementType::F32 => "<f4",
        ElementType::F64 => "<f8",
        ElementType::S32 => "<i4",
        ElementType::S64 => "<i8",
        ElementType::U8 => "|u1",
        other => return Err(anyhow!("npz writer: unsupported element type {other:?}")),
    };
    let dims: Vec<String> = shape.dims().iter().map(|d| d.to_string()).collect();
    let shape_str = match dims.len() {
        0 => "()".to_string(),
        1 => format!("({},)", dims[0]),
        _ => format!("({})", dims.join(", ")),
    };
    let mut header =
        format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_str}, }}");
    // pad so that magic(6)+version(2)+len(2)+header is a multiple of 64
    let unpadded = 6 + 2 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut out = Vec::new();
    out.extend_from_slice(b"\x93NUMPY");
    out.extend_from_slice(&[1u8, 0u8]);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());

    // payload: raw little-endian element bytes via the typed copy path
    let n = lit.element_count();
    match shape.ty() {
        ElementType::F32 => {
            let v = lit.to_vec::<f32>()?;
            out.extend(v.iter().flat_map(|x| x.to_le_bytes()));
        }
        ElementType::F64 => {
            let v = lit.to_vec::<f64>()?;
            out.extend(v.iter().flat_map(|x| x.to_le_bytes()));
        }
        ElementType::S32 => {
            let v = lit.to_vec::<i32>()?;
            out.extend(v.iter().flat_map(|x| x.to_le_bytes()));
        }
        ElementType::S64 => {
            let v = lit.to_vec::<i64>()?;
            out.extend(v.iter().flat_map(|x| x.to_le_bytes()));
        }
        ElementType::U8 => {
            let v = lit.to_vec::<u8>()?;
            out.extend_from_slice(&v);
        }
        _ => unreachable!(),
    }
    debug_assert!(out.len() > n);
    Ok(out)
}

/// Write `name -> literal` entries as an uncompressed npz.
pub fn write_npz<P: AsRef<Path>>(entries: &[(String, &Literal)], path: P) -> Result<()> {
    let mut file = std::fs::File::create(path.as_ref())?;
    let mut central: Vec<u8> = Vec::new();
    let mut offset = 0u32;
    let mut n_entries = 0u16;

    for (name, lit) in entries {
        let fname = format!("{name}.npy");
        let data = npy_bytes(lit)?;
        let crc = crc32(&data);
        let (flen, dlen) = (fname.len() as u16, data.len() as u32);

        // local file header
        let mut local: Vec<u8> = Vec::with_capacity(30 + fname.len());
        local.extend_from_slice(&0x04034b50u32.to_le_bytes());
        local.extend_from_slice(&20u16.to_le_bytes()); // version needed
        local.extend_from_slice(&0u16.to_le_bytes()); // flags
        local.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        local.extend_from_slice(&0u16.to_le_bytes()); // mod time
        local.extend_from_slice(&0u16.to_le_bytes()); // mod date
        local.extend_from_slice(&crc.to_le_bytes());
        local.extend_from_slice(&dlen.to_le_bytes()); // compressed
        local.extend_from_slice(&dlen.to_le_bytes()); // uncompressed
        local.extend_from_slice(&flen.to_le_bytes());
        local.extend_from_slice(&0u16.to_le_bytes()); // extra len
        local.extend_from_slice(fname.as_bytes());
        file.write_all(&local)?;
        file.write_all(&data)?;

        // central directory record
        central.extend_from_slice(&0x02014b50u32.to_le_bytes());
        central.extend_from_slice(&20u16.to_le_bytes()); // version made by
        central.extend_from_slice(&20u16.to_le_bytes()); // version needed
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&crc.to_le_bytes());
        central.extend_from_slice(&dlen.to_le_bytes());
        central.extend_from_slice(&dlen.to_le_bytes());
        central.extend_from_slice(&flen.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes()); // extra
        central.extend_from_slice(&0u16.to_le_bytes()); // comment
        central.extend_from_slice(&0u16.to_le_bytes()); // disk
        central.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        central.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        central.extend_from_slice(&offset.to_le_bytes());
        central.extend_from_slice(fname.as_bytes());

        offset = offset
            .checked_add(local.len() as u32)
            .and_then(|o| o.checked_add(dlen))
            .ok_or_else(|| anyhow!("npz too large for zip32"))?;
        n_entries += 1;
    }

    // end of central directory
    file.write_all(&central)?;
    let mut eocd: Vec<u8> = Vec::with_capacity(22);
    eocd.extend_from_slice(&0x06054b50u32.to_le_bytes());
    eocd.extend_from_slice(&0u16.to_le_bytes()); // disk
    eocd.extend_from_slice(&0u16.to_le_bytes()); // cd disk
    eocd.extend_from_slice(&n_entries.to_le_bytes());
    eocd.extend_from_slice(&n_entries.to_le_bytes());
    eocd.extend_from_slice(&(central.len() as u32).to_le_bytes());
    eocd.extend_from_slice(&offset.to_le_bytes());
    eocd.extend_from_slice(&0u16.to_le_bytes()); // comment len
    file.write_all(&eocd)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal_util::{f32_literal, i32_literal, to_f32_vec, to_i32_vec};
    use xla::FromRawBytes;

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"hello"), 0x3610_A686);
    }

    #[test]
    fn roundtrip_via_crate_reader() {
        let dir = std::env::temp_dir().join("rtx_npz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.npz");
        let a = f32_literal(&[1.5, -2.0, 3.25, 0.0, 7.0, -1.0], &[2, 3]).unwrap();
        let b = i32_literal(&[7, -3, 0], &[3]).unwrap();
        write_npz(&[("x/a".to_string(), &a), ("b".to_string(), &b)], &path).unwrap();

        let back = Literal::read_npz(&path, &()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "x/a");
        assert_eq!(to_f32_vec(&back[0].1).unwrap(), vec![1.5, -2.0, 3.25, 0.0, 7.0, -1.0]);
        assert_eq!(to_i32_vec(&back[1].1).unwrap(), vec![7, -3, 0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scalar_shape_header() {
        let dir = std::env::temp_dir().join("rtx_npz_scalar");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.npz");
        let s = xla::Literal::scalar(2.5f32);
        write_npz(&[("s".to_string(), &s)], &path).unwrap();
        let back = Literal::read_npz(&path, &()).unwrap();
        assert_eq!(back[0].1.get_first_element::<f32>().unwrap(), 2.5);
        std::fs::remove_dir_all(&dir).ok();
    }
}

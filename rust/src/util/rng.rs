//! Deterministic, dependency-free PRNG (SplitMix64 + xoshiro256**).
//!
//! Used by every data generator, the sampler, and the property tests so
//! that all workloads are exactly reproducible from a single `u64` seed
//! across runs and machines.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (for per-document / per-shard seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Exponential variate with the given rate (mean `1 / rate`) via
    /// inverse-CDF sampling: `-ln(1 - u) / rate` with `u ∈ [0, 1)`.
    ///
    /// This is the interarrival-time distribution of a Poisson arrival
    /// process, so the serve layer's request generator draws gaps between
    /// request arrivals from it. `1 - u ∈ (0, 1]` never hits zero (so the
    /// log is always finite) and `u = 0` yields exactly `0.0`.
    ///
    /// Panics if `rate` is not strictly positive and finite.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(
            rate > 0.0 && rate.is_finite(),
            "Rng::exponential requires a positive finite rate, got {rate}"
        );
        -(1.0 - self.f64()).ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    ///
    /// Degenerate inputs are handled explicitly instead of silently
    /// biasing: an empty slice panics (it used to underflow
    /// `weights.len() - 1`), and a zero, negative, NaN, or infinite total
    /// falls back to a uniform pick over all indices (a NaN total used to
    /// make every comparison false and always return the last index; a
    /// zero total always returned index 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        assert!(!weights.is_empty(), "Rng::weighted requires at least one weight");
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over [0, n) — the unigram statistics of the
/// synthetic corpora (natural text is approximately Zipfian).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the CDF; `n = 0` yields an empty (unsampleable) distribution
    /// instead of panicking on `cdf.last().unwrap()`.
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        if let Some(&total) = cdf.last() {
            for c in &mut cdf {
                *c /= total;
            }
        }
        Zipf { cdf }
    }

    /// Number of outcomes ([0, n) from construction).
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        assert!(!self.cdf.is_empty(), "Zipf::sample over an empty range");
        let x = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Draw `n` samples in one call — exactly `n` successive [`Zipf::sample`]
    /// calls against the same `rng`, so interleaving a manual loop with this
    /// convenience produces identical streams. The serve layer uses it to
    /// draw all request content ids up front.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(100, 1.2);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn zipf_empty_range_constructs_without_panic() {
        let z = Zipf::new(0, 1.2);
        assert!(z.is_empty());
        assert_eq!(z.len(), 0);
        let z1 = Zipf::new(1, 1.2);
        let mut rng = Rng::new(2);
        assert_eq!(z1.sample(&mut rng), 0);
    }

    #[test]
    fn weighted_degenerate_totals_fall_back_to_uniform() {
        let mut rng = Rng::new(17);
        for w in [
            vec![0.0, 0.0, 0.0],
            vec![f64::NAN, 1.0, 1.0],
            vec![f64::INFINITY, 1.0, 1.0],
            vec![-1.0, -2.0, -3.0],
        ] {
            let mut seen = [false; 3];
            for _ in 0..200 {
                let i = rng.weighted(&w);
                assert!(i < 3);
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s), "fallback must be uniform, not biased: {w:?}");
        }
    }

    #[test]
    #[should_panic(expected = "at least one weight")]
    fn weighted_empty_panics_with_message() {
        Rng::new(0).weighted(&[]);
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = Rng::new(19);
        for rate in [0.5, 2.0, 8.0] {
            let n = 40_000;
            let mean = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
            let expect = 1.0 / rate;
            assert!(
                (mean - expect).abs() < 0.05 * expect,
                "rate {rate}: mean {mean} vs expected {expect}"
            );
        }
    }

    #[test]
    fn exponential_deterministic_and_nonnegative() {
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        for _ in 0..1000 {
            let x = a.exponential(1.5);
            assert_eq!(x, b.exponential(1.5));
            assert!(x >= 0.0 && x.is_finite());
        }
    }

    #[test]
    #[should_panic(expected = "positive finite rate")]
    fn exponential_rejects_zero_rate() {
        Rng::new(0).exponential(0.0);
    }

    #[test]
    fn zipf_sample_n_matches_repeated_sample() {
        let z = Zipf::new(50, 1.1);
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        let batch = z.sample_n(&mut a, 200);
        let manual: Vec<usize> = (0..200).map(|_| z.sample(&mut b)).collect();
        assert_eq!(batch, manual, "sample_n must be exactly n successive sample() calls");
        assert!(batch.iter().all(|&c| c < 50));
        // the two rngs must also be left in identical states
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zipf_sample_n_zero_is_empty() {
        let z = Zipf::new(10, 1.0);
        assert!(z.sample_n(&mut Rng::new(1), 0).is_empty());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..5000 {
            c[rng.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }
}

//! Tiny benchmarking substrate (no criterion in the offline environment).
//!
//! Provides warmup + repeated timing with mean/stddev/min, throughput
//! helpers, the table printer the per-paper-table bench harnesses use to
//! emit "paper vs measured" rows, and [`StreamingHistogram`] — the shared
//! constant-memory p50/p99 estimator behind both `rtx serve` and
//! `rtx serve-bench --json` (one percentile implementation, two callers).

use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            mean,
            std: var.sqrt(),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max: samples.iter().cloned().fold(0.0, f64::max),
            n,
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(&samples)
}

/// Log-bucketed bins per power of two: ratio 2^(1/8) ≈ 1.09, so quantile
/// estimates carry at most ~4.5% relative error (half a bucket width).
const HIST_BINS_PER_OCTAVE: f64 = 8.0;
/// 8 bins/octave × 40 octaves covers [1, 2^40] — for microsecond samples
/// that is one µs up to ~12.7 days per step, far beyond any real step.
const HIST_BUCKETS: usize = 320;

/// Constant-memory streaming quantile estimator over non-negative samples.
///
/// Samples land in geometric buckets (ratio `2^(1/8)`), so `quantile`
/// answers within ~4.5% relative error using a fixed 320-slot table — no
/// per-sample storage, which is what a serve loop recording every decode
/// step needs. `min`, `max`, and `mean` are tracked exactly; quantiles are
/// clamped into `[min, max]` so the edges never drift outside the observed
/// range. Units are whatever the caller records (the serve layer records
/// microseconds).
///
/// **Empty-histogram convention:** every getter (`mean`, `min`, `max`,
/// `quantile`/`p50`/`p99`) returns exactly `0.0` when no sample has been
/// recorded — never NaN and never a division by zero.  Consumers render
/// the numbers straight into `--json` lines
/// ([`ServeSummary`](crate::attention::ServeSummary) p50/p99 among
/// them), so a run that retires zero steps must still serialize as valid
/// finite JSON.  Pinned by `histogram_empty_reports_zero` here and the
/// zero-step serve regression test in `attention::serve`.
#[derive(Debug, Clone)]
pub struct StreamingHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for StreamingHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingHistogram {
    /// An empty histogram ([`StreamingHistogram::quantile`] returns 0.0).
    pub fn new() -> Self {
        StreamingHistogram {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_of(v: f64) -> usize {
        // values in [0, 1] share bucket 0; the clamp also absorbs any
        // sample beyond the 2^40 top edge instead of indexing out of range
        let idx = (v.max(1.0).log2() * HIST_BINS_PER_OCTAVE).floor();
        (idx as usize).min(HIST_BUCKETS - 1)
    }

    /// Record one sample. Negative or NaN inputs are clamped to 0.0 so a
    /// jittery clock can never corrupt the table.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of all recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum recorded sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate `q`-quantile (`q` clamped into [0, 1]); 0.0 when empty.
    ///
    /// Walks the cumulative bucket counts to the target rank and returns
    /// the geometric midpoint of the landing bucket, clamped into the
    /// exact `[min, max]` envelope.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let mid = 2f64.powf((i as f64 + 0.5) / HIST_BINS_PER_OCTAVE);
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median estimate (`quantile(0.5)`).
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// Tail-latency estimate (`quantile(0.99)`).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold another histogram into this one (same bucket layout by
    /// construction); counts, sum, and the min/max envelope all merge.
    pub fn merge(&mut self, other: &StreamingHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Simple column-aligned table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

/// Column-aligned rendering (`table.to_string()` via the blanket
/// `ToString`; an inherent `to_string` would shadow this impl).
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        writeln!(f, "{}", fmt_row(&self.headers))?;
        writeln!(f, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"))?;
        for row in &self.rows {
            writeln!(f, "{}", fmt_row(row))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn timing_runs() {
        let mut count = 0;
        let s = time_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn histogram_empty_reports_zero() {
        // the documented empty-histogram convention: every getter is
        // exactly 0.0 (finite, JSON-serializable), never NaN
        let h = StreamingHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        for i in 0..=10 {
            let q = h.quantile(i as f64 / 10.0);
            assert!(q.is_finite());
            assert_eq!(q, 0.0);
        }
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
    }

    #[test]
    fn histogram_quantiles_within_bucket_error() {
        let mut h = StreamingHistogram::new();
        for v in 1..=1000 {
            h.record(v as f64);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 1000.0);
        assert!((h.mean() - 500.5).abs() < 1e-9, "mean is exact: {}", h.mean());
        let p50 = h.p50();
        let p99 = h.p99();
        assert!((p50 - 500.0).abs() / 500.0 < 0.10, "p50 {p50} not within 10% of 500");
        assert!((p99 - 990.0).abs() / 990.0 < 0.10, "p99 {p99} not within 10% of 990");
        // quantiles are monotone and clamped into [min, max]
        let mut last = 0.0;
        for i in 0..=20 {
            let q = h.quantile(i as f64 / 20.0);
            assert!(q >= last, "quantile must be monotone in q");
            assert!((1.0..=1000.0).contains(&q));
            last = q;
        }
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let mut h = StreamingHistogram::new();
        h.record(123.4);
        // one sample: the [min, max] clamp pins every quantile to it
        assert_eq!(h.quantile(0.0), 123.4);
        assert_eq!(h.p50(), 123.4);
        assert_eq!(h.p99(), 123.4);
        assert_eq!(h.mean(), 123.4);
    }

    #[test]
    fn histogram_clamps_bad_samples() {
        let mut h = StreamingHistogram::new();
        h.record(-5.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(1e30); // beyond the 2^40 top edge: absorbed, not a panic
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        assert!(h.quantile(0.5).is_finite());
    }

    #[test]
    fn histogram_merge_equals_combined_stream() {
        let mut a = StreamingHistogram::new();
        let mut b = StreamingHistogram::new();
        let mut both = StreamingHistogram::new();
        for v in 1..=500 {
            a.record(v as f64);
            both.record(v as f64);
        }
        for v in 501..=1000 {
            b.record(v as f64 * 3.0);
            both.record(v as f64 * 3.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        assert!((a.mean() - both.mean()).abs() < 1e-9);
        for i in 0..=10 {
            assert_eq!(a.quantile(i as f64 / 10.0), both.quantile(i as f64 / 10.0));
        }
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["model", "ppl"]);
        t.row(&["local".to_string(), "19.8".to_string()]);
        t.row(&["routing".to_string(), "15.8".to_string()]);
        let s = t.to_string();
        assert!(s.contains("model"));
        assert!(s.lines().count() == 4);
    }
}

//! Tiny benchmarking substrate (no criterion in the offline environment).
//!
//! Provides warmup + repeated timing with mean/stddev/min, throughput
//! helpers, and the table printer the per-paper-table bench harnesses use
//! to emit "paper vs measured" rows.

use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            mean,
            std: var.sqrt(),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max: samples.iter().cloned().fold(0.0, f64::max),
            n,
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(&samples)
}

/// Simple column-aligned table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

/// Column-aligned rendering (`table.to_string()` via the blanket
/// `ToString`; an inherent `to_string` would shadow this impl).
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        writeln!(f, "{}", fmt_row(&self.headers))?;
        writeln!(f, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"))?;
        for row in &self.rows {
            writeln!(f, "{}", fmt_row(row))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn timing_runs() {
        let mut count = 0;
        let s = time_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["model", "ppl"]);
        t.row(&["local".to_string(), "19.8".to_string()]);
        t.row(&["routing".to_string(), "15.8".to_string()]);
        let s = t.to_string();
        assert!(s.contains("model"));
        assert!(s.lines().count() == 4);
    }
}

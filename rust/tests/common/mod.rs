//! Shared seeded property-test harness with checked-in regression seeds.
//!
//! The offline environment ships no `proptest`, so the property suites
//! (`proptests.rs`, `stateful.rs`, `coordinator.rs`) roll their own
//! seeded-case loop.  This module is that loop plus the missing
//! proptest feature: **persisted shrink seeds**.  Each suite checks in a
//! `proptest-regressions/<suite>.txt` file of `property 0xSEED` lines;
//! [`check_with_regressions`] replays every matching recorded seed
//! *before* the fresh seeded sweep, so a once-seen failure can never
//! silently stop reproducing.  On a new failure the harness appends the
//! seed to the suite's regression file (best-effort — CI uploads the
//! directory as an artifact on failure) and panics with the seed.
//!
//! File format: one `property_name 0xHEXSEED` per line; blank lines and
//! `#` comments ignored.  Unknown property names are fine — they belong
//! to other tests in the suite.

#![allow(dead_code)] // each test binary uses a subset of this module

use routing_transformer::util::rng::Rng;

/// Parse a regression file's text into `(property, seed)` pairs.
pub fn parse_seeds(text: &str) -> Vec<(String, u64)> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (name, seed) = l.split_once(char::is_whitespace)?;
            let seed = seed.trim();
            let seed = seed.strip_prefix("0x").or_else(|| seed.strip_prefix("0X"))?;
            Some((name.to_string(), u64::from_str_radix(seed, 16).ok()?))
        })
        .collect()
}

fn record_regression(suite: &str, name: &str, seed: u64) {
    use std::io::Write;
    let path = format!("{}/proptest-regressions/{suite}.txt", env!("CARGO_MANIFEST_DIR"));
    if let Ok(mut file) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = writeln!(file, "{name} {seed:#x}");
    }
}

fn run_case<F: Fn(&mut Rng)>(suite: &str, name: &str, seed: u64, replayed: bool, f: &F) {
    let mut rng = Rng::new(seed);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
    if let Err(e) = result {
        if !replayed {
            record_regression(suite, name, seed);
        }
        let kind = if replayed { "regression seed" } else { "seed" };
        panic!(
            "property '{name}' ({suite}) failed at {kind} {seed:#x} \
             (recorded in proptest-regressions/{suite}.txt): {e:?}"
        );
    }
}

/// Run `f` over every recorded regression seed for `name`, then over `n`
/// fresh seeded cases (`base_seed + case`); panic with the failing seed,
/// appending new failures to `proptest-regressions/<suite>.txt`.
pub fn check_with_regressions<F: Fn(&mut Rng)>(
    suite: &str,
    regressions: &str,
    name: &str,
    n: usize,
    base_seed: u64,
    f: F,
) {
    for (prop, seed) in parse_seeds(regressions) {
        if prop == name {
            run_case(suite, name, seed, true, &f);
        }
    }
    for case in 0..n {
        run_case(suite, name, base_seed + case as u64, false, &f);
    }
}
